//! End-to-end distributed tracing over the serving tier.
//!
//! The acceptance path: a router fanning out over two loopback slice
//! backends at `trace_sample 1.0` must yield ONE trace tree — a single
//! root span for the client-facing op, one hop child per backend
//! carrying the backend's self-reported server-side duration, and the
//! backends' own adopted root spans sharing those hop span IDs (two
//! views of one RPC). The tree must be retrievable both ways: the
//! `/debug/traces` HTTP route on the router's metrics endpoint and the
//! `{"op":"trace_dump"}` wire op.
//!
//! The protocol edges ride along: a garbled or missing `trace` field
//! must never error (the request simply runs untraced), and at
//! `trace_sample 0` no spans are recorded while an error request still
//! forces its trace into the ring.
//!
//! All servers here share one process and therefore ONE global span
//! ring; every assertion filters by root op so concurrently-running
//! tests cannot pollute each other.

// Miri cannot emulate this (binds TCP listeners); the miri CI job
// covers the pure-logic trace unit tests instead.
#![cfg(not(miri))]

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::json::{self, Value};
use lshbloom::service::{DedupClient, DedupRouter, DedupServer, RouterOptions, ServeOptions};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

fn base_cfg(sample: f64) -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        engine: EngineMode::Concurrent,
        trace_sample: sample,
        ..Default::default()
    }
}

fn start_server(
    cfg: PipelineConfig,
    opts: ServeOptions,
) -> (std::thread::JoinHandle<()>, String) {
    let server = DedupServer::bind_with_opts("127.0.0.1:0", &cfg, &opts).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve().expect("serve"));
    (handle, addr)
}

fn start_fleet(
    cfg: &PipelineConfig,
    count: usize,
) -> (Vec<std::thread::JoinHandle<()>>, Vec<String>) {
    let mut handles = Vec::with_capacity(count);
    let mut addrs = Vec::with_capacity(count);
    for slice in 0..count {
        let opts = ServeOptions { slice: Some((slice, count)), ..ServeOptions::default() };
        let (handle, addr) = start_server(cfg.clone(), opts);
        handles.push(handle);
        addrs.push(addr);
    }
    (handles, addrs)
}

fn shutdown(addr: &str) {
    DedupClient::connect(addr).unwrap().shutdown().unwrap();
}

/// One-shot HTTP GET against a metrics endpoint, returning the body.
fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("200"), "status: {line}");
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if line.trim().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    reader.read_to_string(&mut body).unwrap();
    body
}

/// One raw request line over a fresh connection, parsed reply back —
/// for requests a well-behaved client cannot produce (garbled trace
/// context, hand-stamped context).
fn raw_round_trip(addr: &str, line: &str) -> Value {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    json::parse(&resp).expect("reply must be JSON")
}

/// The traces whose root op is `op`, from a `{"traces": [...]}` doc.
fn traces_for_op(doc: &Value, op: &str) -> Vec<Value> {
    doc.get("traces")
        .and_then(|t| t.as_arr())
        .map(|arr| {
            arr.iter()
                .filter(|t| t.get("op").and_then(Value::as_str) == Some(op))
                .cloned()
                .collect()
        })
        .unwrap_or_default()
}

/// Structural check on one fan-out trace tree; returns its trace id.
fn assert_fan_out_tree(trace: &Value, backend_count: usize) -> String {
    let spans = trace.get("spans").unwrap().as_arr().unwrap();
    let field = |s: &Value, k: &str| s.get(k).and_then(Value::as_u64).unwrap_or(0);

    // Exactly one root: the router's client-facing span.
    let roots: Vec<&Value> = spans.iter().filter(|&s| field(s, "parent_id") == 0).collect();
    assert_eq!(roots.len(), 1, "one root span, got {spans:?}");
    let root = roots[0];
    let root_span = field(root, "span_id");
    let root_dur = field(root, "dur_ns");
    assert_eq!(root.get("name").and_then(Value::as_str), Some("check_batch"));

    // One hop child per backend, each parented at the root and
    // carrying both sides of the RPC timing: the local wall (includes
    // the wire) and the backend's self-reported server duration.
    let hops: Vec<&Value> = spans
        .iter()
        .filter(|&s| s.get("name").and_then(Value::as_str).is_some_and(|n| n.starts_with("hop ")))
        .collect();
    assert_eq!(hops.len(), backend_count, "one hop per backend: {spans:?}");
    for &hop in &hops {
        assert_eq!(field(hop, "parent_id"), root_span, "hops parent at the root");
        let server_ns = field(hop, "server_dur_ns");
        assert!(server_ns > 0, "hop must carry the server-side duration: {hop:?}");
        assert!(field(hop, "dur_ns") >= server_ns, "client wall includes the wire: {hop:?}");
        assert!(field(hop, "dur_ns") <= root_dur, "a hop cannot outlast its root: {hop:?}");
        // The backend's own adopted root shares this span id — the
        // in-process fleet writes both views into the same ring.
        let views = spans.iter().filter(|&s| field(s, "span_id") == field(hop, "span_id"));
        assert!(views.count() >= 2, "hop + backend view of one RPC: {spans:?}");
    }
    assert!(
        spans.iter().any(|s| {
            s.get("name").and_then(Value::as_str) == Some("check_bands_batch")
                && field(s, "parent_id") == root_span
        }),
        "backend adopted roots join the tree: {spans:?}"
    );
    trace.get("trace_id").and_then(Value::as_str).unwrap().to_string()
}

#[test]
fn router_fan_out_yields_one_trace_tree_via_http_and_wire() {
    let cfg = base_cfg(1.0);
    let (backend_handles, backend_addrs) = start_fleet(&cfg, 2);
    let opts = RouterOptions {
        metrics_addr: Some("127.0.0.1:0".to_string()),
        ..RouterOptions::default()
    };
    let router = DedupRouter::bind("127.0.0.1:0", &cfg, backend_addrs.clone(), &opts)
        .expect("bind router");
    let router_addr = router.local_addr().unwrap().to_string();
    let metrics_addr = router.metrics_addr().expect("router metrics endpoint");
    let router_handle = std::thread::spawn(move || router.serve().expect("route"));

    // The router is ready the moment its bind-time handshake passed.
    assert_eq!(http_get(metrics_addr, "/healthz"), "ok\n");
    assert_eq!(http_get(metrics_addr, "/readyz"), "ready\n");

    let mut client = DedupClient::connect(&router_addr).unwrap();
    let verdicts = client
        .check_batch(&["traced fan-out alpha", "traced fan-out beta", "traced fan-out alpha"])
        .unwrap();
    assert_eq!(verdicts, [false, false, true]);

    // Retrieval path 1: the /debug/traces explorer on the router's
    // metrics endpoint, filtered to the client-facing op.
    let body = http_get(metrics_addr, "/debug/traces?op=check_batch");
    let doc = json::parse(body.trim()).unwrap();
    let traces = traces_for_op(&doc, "check_batch");
    assert!(!traces.is_empty(), "sampled fan-out must be in the ring: {body}");
    let http_trace_id = assert_fan_out_tree(&traces[0], backend_addrs.len());

    // Retrieval path 2: the trace_dump wire op returns the same tree.
    let dump = client.trace_dump().unwrap();
    let wire = traces_for_op(&dump, "check_batch");
    let wire_ids: Vec<&str> =
        wire.iter().filter_map(|t| t.get("trace_id").and_then(Value::as_str)).collect();
    assert!(wire_ids.contains(&http_trace_id.as_str()), "wire dump must hold the same trace");
    assert_fan_out_tree(&wire[0], backend_addrs.len());

    // The slowest view serves from the same ring.
    let body = http_get(metrics_addr, "/debug/traces/slowest?limit=4");
    let slowest = json::parse(body.trim()).unwrap();
    assert!(slowest.get("traces").unwrap().as_arr().is_some_and(|t| !t.is_empty()));

    shutdown(&router_addr);
    router_handle.join().unwrap();
    for addr in &backend_addrs {
        shutdown(addr);
    }
    for handle in backend_handles {
        handle.join().unwrap();
    }
}

#[test]
fn garbled_or_missing_trace_context_never_errors() {
    let (handle, addr) = start_server(base_cfg(0.0), ServeOptions::default());

    // Garbled contexts of every shape: the request runs untraced and
    // the reply carries no trace echo (nothing to correlate against).
    let overlong = "f".repeat(49);
    let garbled = ["zzz", "", "123", overlong.as_str(), "00000000000000000000000000000000-dead"];
    for garbage in garbled {
        let req = json::obj(vec![
            ("op", Value::str("check")),
            ("text", Value::str("garbled context doc")),
            ("trace", Value::str(garbage)),
        ]);
        let resp = raw_round_trip(&addr, &req.to_json());
        assert!(resp.get("error").is_none(), "garbled trace must not error: {resp:?}");
        assert!(resp.get("duplicate").is_some(), "verdict must still arrive: {resp:?}");
        assert!(resp.get("trace").is_none(), "no echo for an unparseable context: {resp:?}");
    }

    // No trace field at all: same untraced behavior.
    let resp = raw_round_trip(&addr, r#"{"op":"query","text":"untraced doc"}"#);
    assert!(resp.get("error").is_none() && resp.get("trace").is_none(), "{resp:?}");

    // A well-formed context gets the timing echo even when the server
    // itself samples at 0 — the caller owns the record decision.
    let ctx = format!("{:032x}-{:016x}", 0xfeed_beef_u128, 0x1234_u64);
    let req = json::obj(vec![
        ("op", Value::str("query")),
        ("text", Value::str("hand-stamped context doc")),
        ("trace", Value::str(&ctx)),
    ]);
    let resp = raw_round_trip(&addr, &req.to_json());
    let echo = resp.get("trace").expect("well-formed context earns a timing echo");
    assert!(echo.get("span_id").and_then(Value::as_u64).is_some_and(|s| s > 0), "{resp:?}");
    assert!(echo.get("dur_ns").and_then(Value::as_u64).is_some(), "{resp:?}");

    shutdown(&addr);
    handle.join().unwrap();
}

#[test]
fn sampling_zero_records_nothing_but_errors_force_traces() {
    let (handle, addr) = start_server(base_cfg(0.0), ServeOptions::default());
    let mut client = DedupClient::connect(&addr).unwrap();

    // A healthy workload at sample 0 must leave no trace behind.
    for i in 0..30 {
        let _ = client.check(&format!("sample-zero workload doc {i}")).unwrap();
    }
    let dump = client.trace_dump().unwrap();
    assert!(
        traces_for_op(&dump, "check").is_empty(),
        "sample 0 must record no check traces: {dump:?}"
    );

    // An error reply forces its trace into the ring regardless.
    assert!(client.check_bands(&[1, 2, 3]).is_err(), "wrong band count must error");
    let dump = client.trace_dump().unwrap();
    let forced = traces_for_op(&dump, "check_bands");
    assert!(!forced.is_empty(), "error traces must appear at sample 0: {dump:?}");
    let spans = forced[0].get("spans").unwrap().as_arr().unwrap();
    assert!(
        spans.iter().any(|s| s.get("parent_id").and_then(Value::as_u64) == Some(0)),
        "forced trace still has a root: {spans:?}"
    );

    shutdown(&addr);
    handle.join().unwrap();
}
