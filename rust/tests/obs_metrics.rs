//! End-to-end tests for the observability layer: a live server scraped
//! over `--metrics-addr` under concurrent `check_batch` traffic, and a
//! router fleet whose per-backend histograms and error counters are
//! verified through the router's own metrics endpoint.
//!
//! Both tests share one process-global registry (they run as threads of
//! one test binary), so every assertion targets series that only its
//! own test can touch: exact counts go through per-op / per-backend
//! labels (backend addresses are ephemeral ports, unique per run), and
//! the fill gauges are only ever refreshed by the server test — the
//! router owns no filters and the fleet's slice servers are never asked
//! to refresh (no `metrics` op, no state dir, so no checkpoint either).

// Miri cannot emulate this (binds TCP listeners); the miri CI job
// covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::corpus::Doc;
use lshbloom::service::{DedupClient, DedupRouter, DedupServer, RouterOptions, ServeOptions};
use std::io::{BufRead, BufReader, Read, Write};

fn base_cfg() -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        engine: EngineMode::Concurrent,
        ..Default::default()
    }
}

/// Minimal HTTP/1.1 GET against the metrics endpoint: status line plus
/// body (the responder closes the connection after one response).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
        .unwrap();
    let mut reader = BufReader::new(stream);
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    let mut line = String::new();
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if line.trim().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    reader.read_to_string(&mut body).unwrap();
    (status.trim().to_string(), body)
}

/// The sample value of one exact series (name + label block) in a
/// Prometheus text exposition, if present.
fn prom_value(text: &str, series: &str) -> Option<f64> {
    let prefix = format!("{series} ");
    text.lines()
        .find_map(|l| l.strip_prefix(&prefix))
        .map(|v| v.parse().unwrap_or_else(|e| panic!("bad sample for {series}: {e}")))
}

fn shutdown(addr: &str) {
    DedupClient::connect(addr).unwrap().shutdown().unwrap();
}

const TRAFFIC_THREADS: u64 = 4;
const BATCHES_PER_THREAD: u64 = 5;
const DOCS_PER_BATCH: u64 = 8;

/// Globally unique per (thread, batch, item) — no duplicates anywhere,
/// so the server's filters hold exactly this document set afterwards.
fn traffic_doc(t: u64, b: u64, i: u64) -> String {
    format!("obs metrics corpus doc thread {t} batch {b} item {i}")
}

#[test]
fn server_metrics_end_to_end() {
    let cfg = base_cfg();
    let opts = ServeOptions {
        metrics_addr: Some("127.0.0.1:0".to_string()),
        ..ServeOptions::default()
    };
    let server = DedupServer::bind_with_opts("127.0.0.1:0", &cfg, &opts).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let maddr = server.metrics_addr().expect("metrics endpoint must be bound");
    let handle = std::thread::spawn(move || server.serve().expect("serve"));

    // Concurrent check_batch traffic: 4 clients × 5 batches × 8 docs,
    // all globally unique (every verdict must be "fresh").
    let mut drivers = Vec::new();
    for t in 0..TRAFFIC_THREADS {
        let addr = addr.clone();
        drivers.push(std::thread::spawn(move || {
            let mut client = DedupClient::connect(&addr).unwrap();
            for b in 0..BATCHES_PER_THREAD {
                let texts: Vec<String> =
                    (0..DOCS_PER_BATCH).map(|i| traffic_doc(t, b, i)).collect();
                let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
                let verdicts = client.check_batch(&refs).unwrap();
                assert!(verdicts.iter().all(|&d| !d), "unique docs must not collide");
            }
        }));
    }
    for d in drivers {
        d.join().unwrap();
    }
    let requests_sent = TRAFFIC_THREADS * BATCHES_PER_THREAD;

    // The wire twin first: `{"op":"metrics"}` refreshes the fill gauges
    // and returns the registry as JSON.
    let mut client = DedupClient::connect(&addr).unwrap();
    let json = client.metrics_json().unwrap();

    // Then the HTTP scrape (its refresh hook runs again; the filters
    // are quiescent, so both views must agree).
    let (status, text) = http_get(maddr, "/metrics");
    assert!(status.contains("200"), "scrape failed: {status}");

    // Every sample line must parse: `name{labels} value` with a numeric
    // value (label values never contain spaces in this registry).
    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("unparseable line: {line}"));
        assert!(series.starts_with("lshbloom_"), "unprefixed series: {line}");
        value
            .parse::<f64>()
            .unwrap_or_else(|e| panic!("non-numeric sample in '{line}': {e}"));
        samples += 1;
    }
    assert!(samples > 0, "scrape returned no samples:\n{text}");

    // Request-latency histogram: the per-op count equals the requests
    // this test sent — exactly. Control ops (stats/metrics/shutdown)
    // and the router test's traffic (check_bands on its own backends)
    // never land in the check_batch series.
    assert_eq!(
        prom_value(&text, "lshbloom_server_request_seconds_count{op=\"check_batch\"}"),
        Some(requests_sent as f64),
        "histogram count must equal requests sent"
    );
    let aggregate = prom_value(&text, "lshbloom_server_request_seconds_count")
        .expect("aggregate request histogram missing");
    assert!(aggregate >= requests_sent as f64, "aggregate {aggregate} < {requests_sent}");
    assert!(
        prom_value(&text, "lshbloom_server_requests_total").unwrap_or(0.0)
            >= requests_sent as f64
    );

    // Popcount verification: the engine is deterministic, so a local
    // replica fed the same unique document set holds byte-identical
    // filters — its exact fill ratios are the ground truth for the
    // scraped gauges (sampled popcounts are exact at this filter size).
    let replica = lshbloom::engine::ConcurrentEngine::from_config(&cfg);
    let mut docs = Vec::new();
    for t in 0..TRAFFIC_THREADS {
        for b in 0..BATCHES_PER_THREAD {
            for i in 0..DOCS_PER_BATCH {
                docs.push(Doc { id: docs.len() as u64, text: traffic_doc(t, b, i) });
            }
        }
    }
    replica.submit(docs);
    let fills = replica.index().fill_ratios();
    assert!(!fills.is_empty());
    for (band, expect) in fills.iter().enumerate() {
        let series = format!("lshbloom_engine_band_fill_ratio{{band=\"{band}\"}}");
        let got = prom_value(&text, &series)
            .unwrap_or_else(|| panic!("missing fill gauge {series}:\n{text}"));
        assert!(got > 0.0, "band {band} fill gauge must be nonzero after ingest");
        assert!(
            (got - expect).abs() < 1e-9,
            "band {band}: scraped fill {got}, popcount ground truth {expect}"
        );
    }
    let fp = prom_value(&text, "lshbloom_engine_fp_estimate").expect("fp estimate missing");
    assert!(fp > 0.0 && fp < 1.0, "any-band FP estimate out of range: {fp}");

    // The wire JSON and the scrape expose the same registry.
    let jfill = json
        .get("gauges")
        .and_then(|g| g.get("engine.band_fill_ratio{band=\"0\"}"))
        .and_then(|v| v.as_f64())
        .expect("band-0 fill gauge missing from {\"op\":\"metrics\"}");
    let sfill = prom_value(&text, "lshbloom_engine_band_fill_ratio{band=\"0\"}").unwrap();
    assert!((jfill - sfill).abs() < 1e-9, "JSON {jfill} vs scrape {sfill}");
    let hist = json
        .get("histograms")
        .and_then(|h| h.get("server.request.seconds{op=\"check_batch\"}"))
        .expect("check_batch histogram missing from JSON");
    assert_eq!(hist.get("count").and_then(|v| v.as_u64()), Some(requests_sent));
    assert!(json.get("uptime_seconds").and_then(|v| v.as_f64()).unwrap() >= 0.0);
    assert_eq!(
        json.get("version").and_then(|v| v.as_str()),
        Some(env!("CARGO_PKG_VERSION"))
    );

    // `/metrics.json` serves the same document over HTTP.
    let (jstatus, jbody) = http_get(maddr, "/metrics.json");
    assert!(jstatus.contains("200"), "json scrape failed: {jstatus}");
    let parsed = lshbloom::json::parse(&jbody).expect("metrics.json must parse");
    assert_eq!(
        parsed.get("version").and_then(|v| v.as_str()),
        Some(env!("CARGO_PKG_VERSION"))
    );

    drop(client);
    shutdown(&addr);
    handle.join().unwrap();
}

#[test]
fn router_backend_metrics_and_error_counter() {
    let cfg = base_cfg();

    // Two slice backends (no state dir: shutdown writes no checkpoint,
    // so this fleet never refreshes the global fill gauges the server
    // test asserts on).
    let mut backend_handles = Vec::new();
    let mut backend_addrs = Vec::new();
    for slice in 0..2 {
        let opts = ServeOptions { slice: Some((slice, 2)), ..ServeOptions::default() };
        let server = DedupServer::bind_with_opts("127.0.0.1:0", &cfg, &opts).expect("bind slice");
        backend_addrs.push(server.local_addr().unwrap().to_string());
        backend_handles.push(std::thread::spawn(move || server.serve().expect("serve slice")));
    }

    let ropts = RouterOptions {
        metrics_addr: Some("127.0.0.1:0".to_string()),
        ..RouterOptions::default()
    };
    let router = DedupRouter::bind("127.0.0.1:0", &cfg, backend_addrs.clone(), &ropts)
        .expect("bind router");
    let router_addr = router.local_addr().unwrap().to_string();
    let maddr = router.metrics_addr().expect("router metrics endpoint must be bound");
    let router_handle = std::thread::spawn(move || router.serve().expect("route"));

    // Exactly 10 routed checks → 10 fan-outs → 10 samples per backend.
    let requests = 10u64;
    let mut client = DedupClient::connect(&router_addr).unwrap();
    for i in 0..requests {
        assert!(!client.check(&format!("router metrics fleet doc {i}")).unwrap());
    }

    let (status, text) = http_get(maddr, "/metrics");
    assert!(status.contains("200"), "router scrape failed: {status}");
    for addr in &backend_addrs {
        let series = format!("lshbloom_router_backend_seconds_count{{backend=\"{addr}\"}}");
        assert_eq!(
            prom_value(&text, &series),
            Some(requests as f64),
            "per-backend fan-out histogram for {addr}:\n{text}"
        );
    }
    assert_eq!(
        prom_value(&text, "lshbloom_router_fan_out_seconds_count"),
        Some(requests as f64),
        "one fan-out span per routed request"
    );
    assert_eq!(
        prom_value(&text, "lshbloom_router_request_seconds_count{op=\"check\"}"),
        Some(requests as f64)
    );

    // Kill backend 1 and wait until it is fully gone, then drive a
    // request into the hole: the labeled error counter must move.
    shutdown(&backend_addrs[1]);
    backend_handles.remove(1).join().unwrap();
    let mut fresh = DedupClient::connect(&router_addr).unwrap();
    let err = fresh.check("document after the backend died").unwrap_err();
    assert!(err.to_string().contains("backend"), "got: {err}");

    let (_, text2) = http_get(maddr, "/metrics");
    let series = format!(
        "lshbloom_router_backend_errors_total{{backend=\"{}\"}}",
        backend_addrs[1]
    );
    let errors = prom_value(&text2, &series).unwrap_or(0.0);
    assert!(errors >= 1.0, "dead backend must increment {series}:\n{text2}");
    assert!(
        prom_value(&text2, "lshbloom_router_backend_errors_total").unwrap_or(0.0) >= 1.0,
        "aggregate backend-error counter must move"
    );
    // The healthy backend took no new sample from the failed fan-out's
    // reply phase — but whether its send raced the abort is timing-
    // dependent, so only the dead backend's counter is asserted.

    drop(client);
    drop(fresh);
    shutdown(&router_addr);
    router_handle.join().unwrap();
    shutdown(&backend_addrs[0]);
    for handle in backend_handles {
        handle.join().unwrap();
    }
}
