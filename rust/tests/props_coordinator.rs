//! Property tests on coordinator invariants: routing (band slicing),
//! batching/ordering, and index state management.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::Doc;
use lshbloom::hash::band::{band_hash_mod_n, band_hash_wrapping};
use lshbloom::hash::pybigint::band_hash_pybigint;
use lshbloom::index::lshbloom::{LshBloomConfig, LshBloomIndex};
use lshbloom::index::{BandIndex, MinHashLshIndex};
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::minhash::{optimal_param, LshParams, PermFamily};
use lshbloom::perf::prop::{check, Gen};
use lshbloom::pipeline::{run_stream, PipelineOptions};

/// The streaming SAMQ contract: for any document stream, a document is
/// flagged duplicate iff some earlier document collided with it — and
/// re-running the identical stream yields identical verdicts.
#[test]
fn prop_pipeline_verdicts_deterministic_across_schedules() {
    check("pipeline-determinism", 25, |g: &mut Gen| {
        let n = g.size(5, 60);
        let vocab = ["alpha", "beta", "gamma", "delta", "epsilon", "zeta"];
        let docs: Vec<Doc> = (0..n)
            .map(|i| {
                let words: Vec<&str> =
                    (0..g.size(3, 30)).map(|_| *g.choose(&vocab)).collect();
                Doc { id: i as u64, text: words.join(" ") }
            })
            .collect();
        let cfg = PipelineConfig { num_perms: 32, expected_docs: 1000, ..Default::default() };

        let mut reference = lshbloom_method(&cfg, PermFamily::Mix64);
        let expected: Vec<bool> = docs
            .iter()
            .map(|d| {
                let prep = reference.preparer.prepare_batch(std::slice::from_ref(d));
                reference.decider.decide(&prep[0])
            })
            .collect();

        let workers = 1 + g.size(0, 3);
        let batch = 1 + g.size(0, 7);
        let mut m = lshbloom_method(&cfg, PermFamily::Mix64);
        let stats = run_stream(
            &mut m,
            docs.clone(),
            PipelineOptions { workers, batch_size: batch, channel_depth: 2 },
        );
        assert_eq!(stats.verdicts, expected, "workers={workers} batch={batch}");
    });
}

/// Bloom-layer soundness: the index never yields a false negative — any
/// inserted band vector is reported as a duplicate forever after.
#[test]
fn prop_lshbloom_index_no_false_negatives() {
    check("no-false-negatives", 40, |g: &mut Gen| {
        let bands = 1 + g.size(0, 15);
        let mut idx = LshBloomIndex::new(LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: 1 + g.size(0, 7) },
            p_effective: 1e-6,
            expected_docs: 2000,
            blocked: false,
        });
        let docs: Vec<Vec<u64>> = (0..g.size(1, 200))
            .map(|_| (0..bands).map(|_| g.u64()).collect())
            .collect();
        for d in &docs {
            idx.insert_if_new(d);
        }
        for (i, d) in docs.iter().enumerate() {
            assert!(idx.query(d), "doc {i} lost");
        }
    });
}

/// Structural agreement: on identical band-hash inputs, LSHBloom may add
/// false positives over the exact hashmap index but never misses a
/// duplicate the hashmap finds.
#[test]
fn prop_lshbloom_dominates_hashmap_duplicates() {
    check("bloom-superset-of-exact", 30, |g: &mut Gen| {
        let bands = 1 + g.size(1, 11);
        let mut bloom = LshBloomIndex::new(LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: 4 },
            p_effective: 1e-6,
            expected_docs: 1000,
            blocked: false,
        });
        let mut exact = MinHashLshIndex::new(bands, 4);
        // Low-entropy band values force genuine collisions.
        let n = g.size(2, 120);
        for _ in 0..n {
            let d: Vec<u64> = (0..bands).map(|_| g.below(12)).collect();
            let bloom_dup = bloom.insert_if_new(&d);
            let exact_dup = exact.insert_if_new(&d);
            if exact_dup {
                assert!(bloom_dup, "bloom missed a true band collision");
            }
        }
    });
}

/// Band-hash routing: all three implementations agree, and band hashes
/// are invariant under permutation of values within a band but sensitive
/// to moving values across bands (the multiset-per-band contract).
#[test]
fn prop_band_hash_implementations_agree() {
    check("band-hash-agreement", 60, |g: &mut Gen| {
        let band = g.vec_u64(40);
        let n = 1 + g.u64() % ((1 << 61) - 1);
        let wrap = band_hash_wrapping(&band);
        let modn = band_hash_mod_n(&band, n);
        // pybigint simulation must agree with the exact u128 path.
        assert_eq!(band_hash_pybigint(&band, n), modn);
        // wrapping == mod 2^64
        let total: u128 = band.iter().map(|&x| x as u128).sum();
        assert_eq!(wrap, (total & u64::MAX as u128) as u64);
    });
}

/// Optimal-param routing invariants: geometry always fits the
/// permutation budget and responds monotonically to threshold.
#[test]
fn prop_optimal_param_invariants() {
    check("optimal-param", 40, |g: &mut Gen| {
        let t = 0.05 + g.f64() * 0.9;
        let p = 8 + g.size(0, 248);
        let params = optimal_param(t, p);
        assert!(params.num_bands >= 1 && params.rows_per_band >= 1);
        assert!(params.rows_used() <= p, "t={t} p={p} -> {params:?}");
        // Higher thresholds favor longer bands (more rows) — verify the
        // weak form: r at T+0.3 is >= r at T.
        if t + 0.3 < 1.0 {
            let hi = optimal_param(t + 0.3, p);
            assert!(
                hi.rows_per_band >= params.rows_per_band,
                "r not monotone: T={t} -> {params:?}, T+0.3 -> {hi:?}"
            );
        }
    });
}

/// Index persistence is lossless for duplicate detection state.
#[test]
fn prop_index_persistence_roundtrip() {
    check("index-save-load", 10, |g: &mut Gen| {
        let bands = 2 + g.size(0, 8);
        let dir = std::env::temp_dir()
            .join(format!("lshbloom-prop-{}-{:x}", std::process::id(), g.seed()));
        let mut idx = LshBloomIndex::new(LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: 3 },
            p_effective: 1e-5,
            expected_docs: 500,
            blocked: false,
        });
        let docs: Vec<Vec<u64>> = (0..g.size(1, 80))
            .map(|_| (0..bands).map(|_| g.u64()).collect())
            .collect();
        for d in &docs {
            idx.insert_if_new(d);
        }
        idx.save_dir(&dir).unwrap();
        let loaded = LshBloomIndex::load_dir(&dir).unwrap();
        for d in &docs {
            assert!(loaded.query(d));
        }
        assert_eq!(loaded.len(), idx.len());
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Sharded aggregation preserves the survivor count of sequential dedup.
#[test]
fn prop_sharded_matches_sequential_survivors() {
    check("shard-aggregation", 8, |g: &mut Gen| {
        let cfg = PipelineConfig { num_perms: 32, expected_docs: 2000, ..Default::default() };
        // Stream with guaranteed exact duplicates.
        let uniques = g.size(5, 30);
        let n = uniques * 3;
        let docs: Vec<Doc> = (0..n)
            .map(|i| {
                let u = g.below(uniques as u64);
                Doc { id: i as u64, text: format!("document body number {u} with shared words") }
            })
            .collect();
        let mut seq = lshbloom_method(&cfg, PermFamily::Mix64);
        let survivors_seq = docs
            .iter()
            .filter(|d| {
                let prep = seq.preparer.prepare_batch(std::slice::from_ref(*d));
                !seq.decider.decide(&prep[0])
            })
            .count();
        let shards = 1 + g.size(0, 5);
        let stats = lshbloom::pipeline::shard::dedup_sharded(&cfg, docs, shards);
        assert_eq!(stats.survivors.len(), survivors_seq, "shards={shards}");
    });
}
