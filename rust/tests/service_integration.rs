//! Integration tests for the network deduplication service.

// Miri cannot emulate this (binds TCP listeners); the miri CI job
// covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::service::{DedupClient, DedupServer};

fn test_cfg(engine: EngineMode) -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        engine,
        ..Default::default()
    }
}

fn start_server() -> (std::thread::JoinHandle<()>, String) {
    start_server_with(test_cfg(EngineMode::Classic), None)
}

fn start_server_with(
    cfg: PipelineConfig,
    state_dir: Option<&std::path::Path>,
) -> (std::thread::JoinHandle<()>, String) {
    let server = DedupServer::bind_with_state("127.0.0.1:0", &cfg, state_dir).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve().expect("serve"));
    (handle, addr)
}

#[test]
fn check_query_stats_shutdown_roundtrip() {
    let (handle, addr) = start_server();
    let mut client = DedupClient::connect(&addr).unwrap();

    // Fresh doc, then duplicate.
    assert!(!client.check("the first document in the stream").unwrap());
    assert!(client.check("the first document in the stream").unwrap());
    // Query-only does not mutate.
    assert!(!client.query("an unseen document right here").unwrap());
    assert!(!client.query("an unseen document right here").unwrap());

    let (docs, dups, disk) = client.stats().unwrap();
    assert_eq!(docs, 2);
    assert_eq!(dups, 1);
    assert!(disk > 0);

    // Operators correlate counter resets with restarts through these.
    let stats = client.stats_json().unwrap();
    let uptime = stats.get("uptime_seconds").and_then(|v| v.as_f64());
    assert!(uptime.is_some_and(|u| u >= 0.0), "uptime_seconds missing: {stats:?}");
    assert_eq!(
        stats.get("version").and_then(|v| v.as_str()),
        Some(env!("CARGO_PKG_VERSION")),
        "stats must report the crate version"
    );

    client.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn multiple_clients_share_one_index() {
    let (handle, addr) = start_server();
    let mut a = DedupClient::connect(&addr).unwrap();
    let mut b = DedupClient::connect(&addr).unwrap();

    assert!(!a.check("shared corpus state across connections").unwrap());
    // Client B sees A's insert.
    assert!(b.check("shared corpus state across connections").unwrap());

    // Concurrent load from two clients.
    let t = std::thread::spawn(move || {
        for i in 0..50 {
            a.check(&format!("client a document number {i}")).unwrap();
        }
        a
    });
    for i in 0..50 {
        b.check(&format!("client b document number {i}")).unwrap();
    }
    let mut a = t.join().unwrap();
    let (docs, dups, _) = a.stats().unwrap();
    // 2 checks of the shared doc + 50 per worker = 102 total inserts,
    // of which at least the second shared check was a duplicate.
    assert_eq!(docs, 102);
    assert!(dups >= 1);

    a.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn check_batch_amortized_roundtrip_on_both_backends() {
    for engine in [EngineMode::Classic, EngineMode::Concurrent] {
        let (handle, addr) = start_server_with(test_cfg(engine), None);
        let mut client = DedupClient::connect(&addr).unwrap();

        // One round trip, three verdicts; the twin inside the batch must
        // be caught (classic decides sequentially under one lock,
        // concurrent through the engine's intra-batch reconcile).
        let verdicts = client
            .check_batch(&[
                "batched wire protocol first document",
                "batched wire protocol first document",
                "a completely different second document",
            ])
            .unwrap();
        assert_eq!(verdicts, vec![false, true, false], "engine={engine:?}");

        // Cross-batch state is shared with the single-document path.
        assert!(client.check("batched wire protocol first document").unwrap());

        // Batch counters land in stats like per-document checks do.
        let (docs, dups, disk) = client.stats().unwrap();
        assert_eq!(docs, 4, "engine={engine:?}");
        assert_eq!(dups, 2, "engine={engine:?}");
        assert!(disk > 0);

        // Empty batch is a no-op, not an error.
        assert!(client.check_batch(&[]).unwrap().is_empty());

        client.shutdown().unwrap();
        handle.join().unwrap();
    }
}

#[test]
fn state_dir_warm_start_preserves_index_and_counters() {
    let dir = std::env::temp_dir().join(format!("lshbloom-svc-state-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = test_cfg(EngineMode::Concurrent);

    // Cold start: ingest, then orderly shutdown (writes the checkpoint).
    {
        let (handle, addr) = start_server_with(cfg.clone(), Some(dir.as_path()));
        let mut client = DedupClient::connect(&addr).unwrap();
        assert!(!client.check("durable document the server must remember").unwrap());
        assert!(!client.check("second durable document").unwrap());
        assert!(client.check("second durable document").unwrap());
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    // Warm start: same dir, fresh process-equivalent server.
    {
        let (handle, addr) = start_server_with(cfg, Some(dir.as_path()));
        let mut client = DedupClient::connect(&addr).unwrap();
        // Restored filters answer for documents ingested pre-restart.
        assert!(client.query("durable document the server must remember").unwrap());
        assert!(client.check("durable document the server must remember").unwrap());
        let (docs, dups, disk) = client.stats().unwrap();
        // 3 pre-restart + 1 post-restart checks; 1 + 1 duplicates.
        assert_eq!(docs, 4, "warm-start must resume the counters");
        assert_eq!(dups, 2);
        // disk_bytes reports the *persisted* footprint: band files plus
        // manifest, so strictly more than the bare filter bytes.
        let filter_bytes = lshbloom::engine::ConcurrentEngine::from_config(&test_cfg(
            EngineMode::Concurrent,
        ))
        .disk_bytes();
        assert!(
            disk > filter_bytes,
            "persisted footprint {disk} should exceed filter bytes {filter_bytes} \
             (manifest included)"
        );
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_requests_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (handle, addr) = start_server();
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let mut send = |line: &str| -> String {
        stream.write_all(format!("{line}\n").as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };

    assert!(send("this is not json").contains("error"));
    assert!(send(r#"{"op": "frobnicate"}"#).contains("unknown op"));
    assert!(send(r#"{"op": "check"}"#).contains("missing 'text'"));
    assert!(send(r#"{"text": "no op"}"#).contains("missing 'op'"));
    assert!(send(r#"{"op": "check_batch"}"#).contains("missing 'texts'"));
    assert!(send(r#"{"op": "check_batch", "texts": "not an array"}"#).contains("missing 'texts'"));
    assert!(send(r#"{"op": "check_batch", "texts": ["ok", 42]}"#).contains("texts[1]"));

    let mut client = DedupClient::connect(&addr).unwrap();
    client.shutdown().unwrap();
    handle.join().unwrap();
}
