//! Integration tests for the network deduplication service.

use lshbloom::config::PipelineConfig;
use lshbloom::service::{DedupClient, DedupServer};

fn start_server() -> (std::thread::JoinHandle<()>, String) {
    let cfg = PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        ..Default::default()
    };
    let server = DedupServer::bind("127.0.0.1:0", &cfg).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve().expect("serve"));
    (handle, addr)
}

#[test]
fn check_query_stats_shutdown_roundtrip() {
    let (handle, addr) = start_server();
    let mut client = DedupClient::connect(&addr).unwrap();

    // Fresh doc, then duplicate.
    assert!(!client.check("the first document in the stream").unwrap());
    assert!(client.check("the first document in the stream").unwrap());
    // Query-only does not mutate.
    assert!(!client.query("an unseen document right here").unwrap());
    assert!(!client.query("an unseen document right here").unwrap());

    let (docs, dups, disk) = client.stats().unwrap();
    assert_eq!(docs, 2);
    assert_eq!(dups, 1);
    assert!(disk > 0);

    client.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn multiple_clients_share_one_index() {
    let (handle, addr) = start_server();
    let mut a = DedupClient::connect(&addr).unwrap();
    let mut b = DedupClient::connect(&addr).unwrap();

    assert!(!a.check("shared corpus state across connections").unwrap());
    // Client B sees A's insert.
    assert!(b.check("shared corpus state across connections").unwrap());

    // Concurrent load from two clients.
    let t = std::thread::spawn(move || {
        for i in 0..50 {
            a.check(&format!("client a document number {i}")).unwrap();
        }
        a
    });
    for i in 0..50 {
        b.check(&format!("client b document number {i}")).unwrap();
    }
    let mut a = t.join().unwrap();
    let (docs, dups, _) = a.stats().unwrap();
    // 2 checks of the shared doc + 50 per worker = 102 total inserts,
    // of which at least the second shared check was a duplicate.
    assert_eq!(docs, 102);
    assert!(dups >= 1);

    a.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn malformed_requests_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let (handle, addr) = start_server();
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let mut send = |line: &str| -> String {
        stream.write_all(format!("{line}\n").as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };

    assert!(send("this is not json").contains("error"));
    assert!(send(r#"{"op": "frobnicate"}"#).contains("unknown op"));
    assert!(send(r#"{"op": "check"}"#).contains("missing 'text'"));
    assert!(send(r#"{"text": "no op"}"#).contains("missing 'op'"));

    let mut client = DedupClient::connect(&addr).unwrap();
    client.shutdown().unwrap();
    handle.join().unwrap();
}
