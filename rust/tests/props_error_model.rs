//! Property tests on the analytic error model (§4.3) and its empirical
//! agreement with the real index.

use lshbloom::index::ErrorModel;
use lshbloom::minhash::params::collision_probability;
use lshbloom::minhash::{optimal_param, LshParams};
use lshbloom::perf::prop::{check, Gen};

#[test]
fn prop_error_model_basic_bounds() {
    check("error-model-bounds", 60, |g: &mut Gen| {
        let t = 0.05 + g.f64() * 0.9;
        let lsh = LshParams {
            num_bands: 1 + g.size(0, 60),
            rows_per_band: 1 + g.size(0, 20),
        };
        let p_eff = 10f64.powf(-(1.0 + g.f64() * 11.0));
        let m = ErrorModel::evaluate_u64(t, lsh, p_eff);
        assert!((0.0..=1.0).contains(&m.fp_lsh), "{m:?}");
        assert!((0.0..=1.0).contains(&m.fn_lsh), "{m:?}");
        // Eq. 3: bloom only adds FPs. Eq. 4: bloom only removes FNs.
        assert!(m.fp_bloom >= m.fp_lsh);
        assert!(m.fn_bloom <= m.fn_lsh);
        assert!(m.fp_bloom <= 1.0 && m.fn_bloom >= 0.0);
    });
}

#[test]
fn prop_error_model_monotone_in_p_effective() {
    check("error-model-monotone", 40, |g: &mut Gen| {
        let t = 0.2 + g.f64() * 0.6;
        let lsh = optimal_param(t, 128);
        let lo = ErrorModel::evaluate_u64(t, lsh, 1e-10);
        let hi = ErrorModel::evaluate_u64(t, lsh, 1e-3);
        assert!(hi.fp_bloom >= lo.fp_bloom);
        assert!(hi.fn_bloom <= lo.fn_bloom);
    });
}

#[test]
fn prop_s_curve_monotone_and_bounded() {
    check("s-curve", 50, |g: &mut Gen| {
        let lsh = LshParams {
            num_bands: 1 + g.size(0, 50),
            rows_per_band: 1 + g.size(0, 15),
        };
        let mut prev = 0.0;
        for i in 0..=10 {
            let s = i as f64 / 10.0;
            let c = collision_probability(s, lsh);
            assert!((0.0..=1.0 + 1e-12).contains(&c));
            assert!(c + 1e-12 >= prev, "not monotone at s={s}");
            prev = c;
        }
        // Endpoints.
        assert!(collision_probability(0.0, lsh) < 1e-12);
        assert!((collision_probability(1.0, lsh) - 1.0).abs() < 1e-9);
    });
}

/// Empirical check that the S-curve predicts real LSHBloom collision
/// behaviour: documents engineered to a target Jaccard similarity
/// collide at roughly the modeled rate.
#[test]
fn s_curve_matches_empirical_collisions() {
    use lshbloom::hash::band::band_hashes_for_doc;
    use lshbloom::index::lshbloom::{LshBloomConfig, LshBloomIndex};
    use lshbloom::index::BandIndex;
    use lshbloom::minhash::{MinHasher, PermFamily};
    use lshbloom::rng::Xoshiro256pp;

    let lsh = optimal_param(0.5, 128); // (25, 5)
    let mh = MinHasher::new(PermFamily::Mix64, lsh.rows_used(), 1);
    let mut rng = Xoshiro256pp::seeded(0x5C);

    for (target_j, expect_band) in [(0.3, collision_probability(0.3, lsh)), (0.7, collision_probability(0.7, lsh))] {
        let trials = 300;
        let mut collided = 0u64;
        for _ in 0..trials {
            // Two token-hash sets with expected Jaccard `target_j`:
            // shared fraction s where s/(2-s) = J  =>  s = 2J/(1+J).
            let s = 2.0 * target_j / (1.0 + target_j);
            let total = 200usize;
            let shared = (total as f64 * s) as usize;
            let base: Vec<u64> = (0..total).map(|_| rng.next_u64()).collect();
            let mut a = base.clone();
            let mut b: Vec<u64> = base[..shared].to_vec();
            for _ in shared..total {
                b.push(rng.next_u64());
            }
            a.truncate(total);
            let mut idx = LshBloomIndex::new(LshBloomConfig {
                lsh,
                p_effective: 1e-10,
                expected_docs: 10,
                blocked: false,
            });
            let mut bands = Vec::new();
            let sig_a = mh.signature_of_hashes(&a);
            band_hashes_for_doc(&sig_a, lsh.num_bands, lsh.rows_per_band, &mut bands);
            idx.insert_if_new(&bands);
            let sig_b = mh.signature_of_hashes(&b);
            band_hashes_for_doc(&sig_b, lsh.num_bands, lsh.rows_per_band, &mut bands);
            collided += idx.query(&bands) as u64;
        }
        let observed = collided as f64 / trials as f64;
        assert!(
            (observed - expect_band).abs() < 0.15,
            "J={target_j}: observed {observed:.3} vs modeled {expect_band:.3}"
        );
    }
}
