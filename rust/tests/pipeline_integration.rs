//! Integration tests across modules: corpus -> pipeline -> eval, JSONL
//! round trips, failure injection (corrupt inputs, capacity overflow,
//! panicking preparers), and fidelity sanity on labeled data.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{DatasetSpec, Doc, LabeledCorpus};
use lshbloom::eval::{run_method, Confusion};
use lshbloom::methods::{MethodKind, MethodSpec, Prepared, Preparer};
use lshbloom::minhash::PermFamily;
use lshbloom::pipeline::{run_stream, PipelineOptions};

#[test]
fn full_fidelity_flow_on_labeled_corpus() {
    let corpus = LabeledCorpus::build(DatasetSpec::testing(61, 400, 0.5));
    let sample: Vec<Doc> = corpus.docs.iter().take(100).map(|ld| ld.doc.clone()).collect();
    let mut results = Vec::new();
    for kind in MethodKind::ALL {
        let mut m = MethodSpec::best(kind, 400).build(&sample);
        let r = run_method(&mut m, &corpus.docs, PipelineOptions::default());
        results.push(r);
    }
    // Paper-shape assertions (Fig. 5 at 50% duplication):
    let get = |n: &str| results.iter().find(|r| r.method == n).unwrap();
    let lshb = get("lshbloom");
    let mlsh = get("minhashlsh");
    assert!((lshb.confusion.f1() - mlsh.confusion.f1()).abs() < 0.02, "LSH parity");
    assert!(lshb.confusion.f1() > 0.85, "lshbloom F1 {}", lshb.confusion.f1());
    // LSH methods beat paragraph methods on F1.
    for para in ["dolma", "ccnet"] {
        assert!(
            lshb.confusion.f1() > get(para).confusion.f1(),
            "lshbloom must beat {para}"
        );
    }
    // Paragraph methods have the worst recall (paper finding).
    let worst_recall = results
        .iter()
        .min_by(|a, b| a.confusion.recall().partial_cmp(&b.confusion.recall()).unwrap())
        .unwrap();
    assert!(
        worst_recall.method == "dolma" || worst_recall.method == "ccnet",
        "worst recall was {}",
        worst_recall.method
    );
    // LSHBloom's index is the smallest among the LSH methods by far.
    assert!(mlsh.disk_bytes > lshb.disk_bytes * 2, "disk advantage missing");
}

#[test]
fn jsonl_corpus_roundtrip_preserves_fidelity_labels() {
    let corpus = LabeledCorpus::build(DatasetSpec::testing(67, 120, 0.4));
    let dir = std::env::temp_dir().join(format!("lshbloom-int-{}", std::process::id()));
    let path = dir.join("corpus.jsonl");
    corpus.save_jsonl(&path).unwrap();
    let loaded = LabeledCorpus::load_jsonl(&path).unwrap();

    let cfg = PipelineConfig { num_perms: 64, expected_docs: 1000, ..Default::default() };
    let mut m = lshbloom::methods::lshbloom::lshbloom_method(&cfg, PermFamily::Mix64);
    let stats = run_stream(&mut m, loaded.iter().map(|ld| ld.doc.clone()), PipelineOptions::default());
    let labels: Vec<bool> = loaded.iter().map(|ld| ld.is_duplicate()).collect();
    let c = Confusion::from_verdicts(&stats.verdicts, &labels);
    assert!(c.recall() > 0.9, "recall {}", c.recall());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_jsonl_lines_are_reported_with_location() {
    let dir = std::env::temp_dir().join(format!("lshbloom-int2-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.jsonl");
    std::fs::write(&path, "{\"id\": 0, \"text\": \"ok\", \"duplicate_of\": null}\nnot json at all\n").unwrap();
    let err = LabeledCorpus::load_jsonl(&path).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("line 2"), "error should cite the line: {msg}");

    std::fs::write(&path, "{\"text\": \"missing id\"}\n").unwrap();
    let err = LabeledCorpus::load_jsonl(&path).unwrap_err();
    assert!(err.to_string().contains("missing id"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bloom_overflow_degrades_gracefully_not_catastrophically() {
    // Insert 10x the planned capacity: FP rate rises but the index must
    // keep functioning and never produce a false negative.
    use lshbloom::index::lshbloom::{LshBloomConfig, LshBloomIndex};
    use lshbloom::index::BandIndex;
    use lshbloom::minhash::LshParams;
    use lshbloom::rng::Xoshiro256pp;

    let mut idx = LshBloomIndex::new(LshBloomConfig {
        lsh: LshParams { num_bands: 9, rows_per_band: 13 },
        p_effective: 1e-6,
        expected_docs: 1_000,
        blocked: false,
    });
    let mut rng = Xoshiro256pp::seeded(71);
    let docs: Vec<Vec<u64>> = (0..10_000)
        .map(|_| (0..9).map(|_| rng.next_u64()).collect())
        .collect();
    for d in &docs {
        idx.insert_if_new(d);
    }
    for d in &docs {
        assert!(idx.query(d), "no false negatives even at 10x overload");
    }
    // Predicted FP rate at 10x capacity is large; verify the model says so
    // (operators can monitor this).
    assert!(idx.predicted_filter_fp() > 1e-6);
}

/// A preparer that panics mid-stream must not deadlock the pipeline —
/// the scope propagates the panic.
struct PanickingPreparer;
impl Preparer for PanickingPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        if docs.iter().any(|d| d.text.contains("poison")) {
            panic!("injected preparer failure");
        }
        docs.iter().map(|_| Prepared::Bands(vec![0])).collect()
    }
}

#[test]
fn worker_panic_propagates_instead_of_hanging() {
    struct NullDecider(u64);
    impl lshbloom::methods::Decider for NullDecider {
        fn decide(&mut self, _p: &Prepared) -> bool {
            self.0 += 1;
            false
        }
        fn disk_bytes(&self) -> u64 {
            0
        }
        fn len(&self) -> u64 {
            self.0
        }
    }
    let mut method = lshbloom::methods::Method {
        name: "panicky".into(),
        preparer: std::sync::Arc::new(PanickingPreparer),
        decider: Box::new(NullDecider(0)),
    };
    let docs: Vec<Doc> = (0..50)
        .map(|i| Doc {
            id: i,
            text: if i == 25 { "poison pill".into() } else { format!("doc {i}") },
        })
        .collect();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_stream(
            &mut method,
            docs,
            PipelineOptions { workers: 2, batch_size: 4, channel_depth: 2 },
        )
    }));
    assert!(outcome.is_err(), "panic must propagate to the caller");
}

#[test]
fn xla_and_datasketch_families_disagree_but_both_work() {
    // Different permutation families produce different signatures but
    // equivalent dedup quality on exact duplicates.
    let cfg = PipelineConfig { num_perms: 64, expected_docs: 1000, ..Default::default() };
    for family in [PermFamily::Mix64, PermFamily::Datasketch] {
        let mut m = lshbloom::methods::lshbloom::lshbloom_method(&cfg, family);
        let d = Doc { id: 0, text: "family agnostic duplicate detection".into() };
        assert!(!m.process(&d));
        assert!(m.process(&d));
    }
}
