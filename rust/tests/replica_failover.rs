//! Chaos tests for the replicated serving tier.
//!
//! The load-bearing assertion is recall survival under replica death:
//! a router over 2 band slices x 2 replicas each, with every replica a
//! real `serve --slice-index` subprocess owning a durable state dir,
//! must keep its verdict vector byte-identical to a single unsharded
//! concurrent-engine oracle while a replica is SIGKILLed mid-stream,
//! while it re-converges through `--sync-from` anti-entropy, and after
//! its *peer* is killed too (double fault — the restarted copy is then
//! the only holder of slice 0).
//!
//! The rest is fault injection on the recovery path itself: a crash
//! mid-merge (`LSHBLOOM_REPLICA_CRASH_AFTER_DOCS`) followed by an
//! idempotent retry, a geometry-mismatched sync peer refused as a hard
//! bind error, and a torn (truncated) slice checkpoint refused at
//! restart with a named error.

// Miri cannot emulate this (TCP listeners + subprocesses); the miri CI
// job covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::methods::lshbloom::BandPreparer;
use lshbloom::service::{DedupClient, DedupRouter, DedupServer, RouterOptions, ServeOptions};
use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};

fn base_cfg() -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        engine: EngineMode::Concurrent,
        ..Default::default()
    }
}

/// Fresh per-test temp root (removes any stale leftover first).
fn tmp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lshbloom-failover-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// `serve` invocation for one slice-server replica of the test fleet.
/// Geometry flags must mirror [`base_cfg`] exactly — the router's
/// bind-time handshake (and the sync handshake) verify they do.
fn serve_cmd(
    addr: &str,
    perms: &str,
    slice: usize,
    count: usize,
    state_dir: &Path,
    sync_from: Option<&str>,
) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_lshbloom"));
    cmd.arg("serve")
        .args(["--addr", addr, "--engine", "concurrent"])
        .args(["--perms", perms, "--expected-docs", "10000"])
        .args(["--slice-index", &slice.to_string()])
        .args(["--slice-count", &count.to_string()])
        .args(["--state-dir", state_dir.to_str().unwrap()]);
    if let Some(peers) = sync_from {
        cmd.args(["--sync-from", peers]);
    }
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    cmd
}

/// One replica subprocess; SIGKILLed on drop so a failed assertion
/// never leaks servers.
struct SliceProc {
    child: Child,
    addr: String,
    // Held so the server's stdout pipe stays open for its lifetime.
    _stdout: BufReader<ChildStdout>,
}

impl SliceProc {
    /// SIGKILL — the chaos event. No shutdown op, no checkpoint.
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for SliceProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Spawn a slice server and block until it prints its listening line
/// (which resolves `--addr 127.0.0.1:0` to the real port).
fn spawn_slice(
    addr: &str,
    slice: usize,
    count: usize,
    state_dir: &Path,
    sync_from: Option<&str>,
) -> SliceProc {
    let mut child = serve_cmd(addr, "64", slice, count, state_dir, sync_from)
        .spawn()
        .expect("spawn slice server");
    let mut reader = BufReader::new(child.stdout.take().expect("child stdout"));
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read slice server stdout");
        if n == 0 {
            let _ = child.wait();
            let mut err = String::new();
            if let Some(mut e) = child.stderr.take() {
                let _ = e.read_to_string(&mut err);
            }
            panic!("slice server exited before listening: {err}");
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            let addr = rest.split_whitespace().next().expect("listen addr token").to_string();
            return SliceProc { child, addr, _stdout: reader };
        }
    }
}

/// Run a `serve` invocation expected to die before it listens; returns
/// (exit code, stderr).
fn serve_expect_death(mut cmd: Command) -> (Option<i32>, String) {
    let out = cmd.output().expect("run slice server to completion");
    assert!(
        !out.status.success(),
        "server unexpectedly survived: stdout={}",
        String::from_utf8_lossy(&out.stdout)
    );
    (out.status.code(), String::from_utf8_lossy(&out.stderr).into_owned())
}

fn start_router(cfg: &PipelineConfig, backends: Vec<String>) -> (std::thread::JoinHandle<()>, String) {
    let router = DedupRouter::bind("127.0.0.1:0", cfg, backends, &RouterOptions::default())
        .expect("bind router");
    let addr = router.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || router.serve().expect("route"));
    (handle, addr)
}

fn start_oracle(cfg: &PipelineConfig) -> (std::thread::JoinHandle<()>, String) {
    let server = DedupServer::bind_with_opts("127.0.0.1:0", cfg, &ServeOptions::default())
        .expect("bind oracle");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve().expect("serve oracle"));
    (handle, addr)
}

/// Band hashes for one document, bit-identical to what every serving
/// path computes (shared preparer construction).
fn bands_for(preparer: &BandPreparer, text: &str) -> Vec<u64> {
    let sig = preparer.hasher.signature(&lshbloom::text::normalize(text));
    let mut bands = Vec::new();
    lshbloom::hash::band::band_hashes_for_doc(
        &sig,
        preparer.lsh.num_bands,
        preparer.lsh.rows_per_band,
        &mut bands,
    );
    bands
}

/// `pull_bands` one band of one generation: `Some((filter words,
/// inserted))` when the server owns it, `None` when it answers
/// "outside this slice's range".
fn pull_words(client: &mut DedupClient, band: usize, gen: usize) -> Option<(Vec<u64>, u64)> {
    let reply = client.pull_band(band, gen).ok()?;
    let words: Vec<u64> = reply
        .get("words")
        .and_then(|v| v.as_arr())
        .expect("pull_bands reply words")
        .iter()
        .map(|w| w.as_u64().expect("u64 filter word"))
        .collect();
    let inserted = reply.get("inserted").and_then(|v| v.as_u64()).unwrap_or(0);
    Some((words, inserted))
}

fn inserted_of(client: &mut DedupClient) -> u64 {
    client
        .stats_json()
        .unwrap()
        .get("inserted")
        .and_then(|v| v.as_u64())
        .expect("slice stats carries 'inserted'")
}

/// Assert two replicas hold bit-for-bit identical filters over every
/// band either of them owns, across every index generation, and agree
/// on the insert counter — the convergence contract anti-entropy must
/// reach.
fn assert_band_parity(addr_a: &str, addr_b: &str) {
    let mut a = DedupClient::connect(addr_a).unwrap();
    let mut b = DedupClient::connect(addr_b).unwrap();
    let stats = a.stats_json().unwrap();
    let num_bands = stats
        .get("num_bands")
        .and_then(|v| v.as_u64())
        .expect("slice stats carries 'num_bands'") as usize;
    let gens_a = stats.get("generations").and_then(|v| v.as_u64()).unwrap_or(1);
    let gens_b = b
        .stats_json()
        .unwrap()
        .get("generations")
        .and_then(|v| v.as_u64())
        .unwrap_or(1);
    assert_eq!(gens_a, gens_b, "replica generation counts diverge");
    let mut compared = 0;
    for gen in 0..gens_a as usize {
        for band in 0..num_bands {
            match (pull_words(&mut a, band, gen), pull_words(&mut b, band, gen)) {
                (Some((wa, ia)), Some((wb, ib))) => {
                    assert_eq!(wa, wb, "gen {gen} band {band}: replica filter words diverge");
                    assert_eq!(
                        ia, ib,
                        "gen {gen} band {band}: replica insert counters diverge"
                    );
                    compared += 1;
                }
                (None, None) => {}
                _ => panic!("gen {gen} band {band}: replicas disagree on slice ownership"),
            }
        }
    }
    assert!(compared > 0, "replicas own no bands in common");
    assert_eq!(inserted_of(&mut a), inserted_of(&mut b), "slice insert counters diverge");
}

enum Op {
    Check(String),
    Batch(Vec<String>),
}

/// Deterministic interleaved traffic with twins inside batches, across
/// batches, and across the single/batched ops — the `i % 37` cycle
/// guarantees duplicates that straddle the kill/restart phase
/// boundaries, so recall loss would surface as a verdict mismatch.
fn traffic() -> Vec<Op> {
    let doc = |i: u64| format!("replica failover parity document number {}", i % 37);
    let mut ops = Vec::new();
    let mut i = 0u64;
    while i < 200 {
        match i % 5 {
            0 | 3 => {
                ops.push(Op::Check(doc(i)));
                i += 1;
            }
            1 => {
                let batch: Vec<String> = (0..7).map(|j| doc(i + j)).collect();
                i += 7;
                ops.push(Op::Batch(batch));
            }
            2 => {
                // In-batch twin: first element repeated at the end.
                let mut batch: Vec<String> = (0..5).map(|j| doc(i + j)).collect();
                batch.push(doc(i));
                i += 5;
                ops.push(Op::Batch(batch));
            }
            _ => {
                ops.push(Op::Check(format!("one-off failover document {i}")));
                i += 1;
            }
        }
    }
    ops
}

/// Drive one op through the router and the oracle, asserting verdict
/// parity — the router must never degrade a verdict, whatever the
/// fleet's health.
fn drive_parity(router: &mut DedupClient, oracle: &mut DedupClient, op: &Op, opno: usize) {
    match op {
        Op::Check(text) => {
            assert_eq!(
                router.check(text).unwrap(),
                oracle.check(text).unwrap(),
                "op {opno}: check verdict diverged from the oracle"
            );
        }
        Op::Batch(texts) => {
            let refs: Vec<&str> = texts.iter().map(String::as_str).collect();
            assert_eq!(
                router.check_batch(&refs).unwrap(),
                oracle.check_batch(&refs).unwrap(),
                "op {opno}: batch verdict vector diverged from the oracle"
            );
        }
    }
}

fn revived_addrs(resp: &lshbloom::json::Value) -> Vec<String> {
    resp.get("revived")
        .and_then(|v| v.as_arr())
        .expect("revive reply carries 'revived'")
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect()
}

fn failed_addrs(resp: &lshbloom::json::Value) -> Vec<(String, String)> {
    resp.get("failed")
        .and_then(|v| v.as_arr())
        .expect("revive reply carries 'failed'")
        .iter()
        .map(|v| {
            (
                v.get("addr").and_then(|a| a.as_str()).unwrap().to_string(),
                v.get("error").and_then(|e| e.as_str()).unwrap().to_string(),
            )
        })
        .collect()
}

/// The tentpole chaos test: 2 slices x 2 replicas over loopback, kill
/// one replica mid-stream, restart it with `--sync-from` anti-entropy,
/// revive it through the router, prove it is bit-identical to its
/// peer, then kill the peer — verdicts stay byte-identical to an
/// unsharded oracle through every phase (double-fault recall survival).
#[test]
fn kill_a_replica_under_load_never_degrades_verdicts() {
    let cfg = base_cfg();
    let root = tmp_root("chaos");
    let dirs: Vec<PathBuf> =
        ["s0r0", "s0r1", "s1r0", "s1r1"].iter().map(|n| root.join(n)).collect();

    // Fleet: replicas 0/1 serve slice 0, replicas 2/3 serve slice 1.
    let mut reps: Vec<SliceProc> = dirs
        .iter()
        .enumerate()
        .map(|(i, dir)| spawn_slice("127.0.0.1:0", i / 2, 2, dir, None))
        .collect();
    let addrs: Vec<String> = reps.iter().map(|r| r.addr.clone()).collect();

    let backends = vec![
        format!("{}|{}", addrs[0], addrs[1]),
        format!("{}|{}", addrs[2], addrs[3]),
    ];
    let (router_handle, router_addr) = start_router(&cfg, backends);
    let (oracle_handle, oracle_addr) = start_oracle(&cfg);
    let mut rc = DedupClient::connect(&router_addr).unwrap();
    let mut oc = DedupClient::connect(&oracle_addr).unwrap();

    let ops = traffic();
    let kill_at = ops.len() / 4;
    let restart_at = ops.len() / 2;
    for (i, op) in ops.iter().enumerate() {
        if i == kill_at {
            // Chaos: SIGKILL one replica of slice 0 mid-stream.
            reps[1].kill();
        }
        if i == kill_at + 3 {
            // By now a broadcast has failed against the corpse and the
            // router holds it out of rotation. Reviving it while it is
            // still dead must fail with the address named — and must
            // not disturb the live fleet.
            let resp = rc.revive().unwrap();
            assert!(revived_addrs(&resp).is_empty(), "a dead replica was revived");
            let failed = failed_addrs(&resp);
            assert!(
                failed.iter().any(|(a, e)| a == &addrs[1] && !e.is_empty()),
                "revive did not report the dead replica: {failed:?}"
            );
        }
        if i == restart_at {
            // Recovery: rebind the same port over the surviving durable
            // state, anti-entropy the missed inserts from the healthy
            // peer, then re-admit it through the router handshake.
            reps[1] = spawn_slice(&addrs[1], 0, 2, &dirs[1], Some(&addrs[0]));
            let resp = rc.revive().unwrap();
            assert!(
                revived_addrs(&resp).contains(&addrs[1]),
                "synced replica was not re-admitted: {resp:?}"
            );
            // Convergence is bit-exact, not approximate.
            assert_band_parity(&addrs[0], &addrs[1]);
            // Double fault: now kill the peer that held slice 0 alive.
            // The revived replica is the only copy left.
            reps[0].kill();
        }
        drive_parity(&mut rc, &mut oc, op, i);
    }

    rc.shutdown().unwrap();
    DedupClient::connect(&oracle_addr).unwrap().shutdown().unwrap();
    router_handle.join().unwrap();
    oracle_handle.join().unwrap();
    drop(reps);
    let _ = std::fs::remove_dir_all(&root);
}

/// Fault injection on the recovery path itself: a replica that dies
/// mid-merge (env hook) leaves a torn half-merged filter set; the
/// retried merge must converge to the same bits, because bit-OR is
/// idempotent. A second replay over already-converged state must also
/// be a no-op.
#[test]
fn crashed_anti_entropy_merge_is_idempotent_on_retry() {
    let cfg = base_cfg();
    let preparer = BandPreparer::from_config(&cfg);
    let root = tmp_root("torn-merge");
    let peer_dir = root.join("peer");
    let rep_dir = root.join("replica");

    // A healthy peer holding 40 documents (slice 0 of 1 = every band).
    let mut peer = spawn_slice("127.0.0.1:0", 0, 1, &peer_dir, None);
    let mut pc = DedupClient::connect(&peer.addr).unwrap();
    for i in 0..40u64 {
        let bands = bands_for(&preparer, &format!("anti entropy corpus doc {}", i % 17));
        pc.check_bands(&bands).unwrap();
    }

    // Sync attempt 1: the crash hook kills the process mid-merge, after
    // at least one band has been folded but before the walk completes.
    let mut cmd = serve_cmd("127.0.0.1:0", "64", 0, 1, &rep_dir, Some(&peer.addr));
    cmd.env("LSHBLOOM_REPLICA_CRASH_AFTER_DOCS", "1");
    let (code, _) = serve_expect_death(cmd);
    assert_eq!(code, Some(42), "crash hook must exit 42 mid-merge");

    // Retry without the hook: replays the whole merge over the torn
    // state and must converge bit-for-bit with the peer.
    let mut rep = spawn_slice("127.0.0.1:0", 0, 1, &rep_dir, Some(&peer.addr));
    assert_band_parity(&peer.addr, &rep.addr);

    // Replay once more over fully-converged state (crash + resync):
    // the merge is idempotent, so nothing may change.
    rep.kill();
    let mut rep = spawn_slice("127.0.0.1:0", 0, 1, &rep_dir, Some(&peer.addr));
    assert_band_parity(&peer.addr, &rep.addr);

    DedupClient::connect(&rep.addr).unwrap().shutdown().unwrap();
    DedupClient::connect(&peer.addr).unwrap().shutdown().unwrap();
    let _ = rep.child.wait();
    let _ = peer.child.wait();
    let _ = std::fs::remove_dir_all(&root);
}

/// A reachable sync peer running a different filter geometry is
/// operator error, not a transient fault: merging it would corrupt the
/// membership contract, so bind must fail hard with the reason named.
#[test]
fn geometry_mismatched_sync_peer_is_a_hard_bind_error() {
    let root = tmp_root("geometry");
    let peer = spawn_slice("127.0.0.1:0", 0, 1, &root.join("peer"), None);

    // 128 permutations -> different band geometry than the peer's 64.
    let cmd = serve_cmd("127.0.0.1:0", "128", 0, 1, &root.join("replica"), Some(&peer.addr));
    let (code, stderr) = serve_expect_death(cmd);
    assert_eq!(code, Some(1));
    assert!(
        stderr.contains("different index geometry"),
        "geometry rejection not named: {stderr}"
    );

    drop(peer);
    let _ = std::fs::remove_dir_all(&root);
}

/// A torn slice checkpoint (band file truncated after a crash, e.g. by
/// a dying disk) must be refused at restart with the file and size
/// named — never silently reopened as a smaller filter, which would
/// turn missing bits into false "never seen" verdicts.
#[test]
fn truncated_slice_checkpoint_is_refused_at_restart() {
    let cfg = base_cfg();
    let preparer = BandPreparer::from_config(&cfg);
    let root = tmp_root("torn-checkpoint");
    let dir = root.join("replica");

    let mut rep = spawn_slice("127.0.0.1:0", 0, 1, &dir, None);
    let mut client = DedupClient::connect(&rep.addr).unwrap();
    for i in 0..10u64 {
        client.check_bands(&bands_for(&preparer, &format!("torn checkpoint doc {i}"))).unwrap();
    }
    rep.kill();

    // Tear the checkpoint: halve the first band's backing file.
    let band0 = dir.join("band000.bits");
    let len = std::fs::metadata(&band0).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&band0).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);

    let cmd = serve_cmd("127.0.0.1:0", "64", 0, 1, &dir, None);
    let (code, stderr) = serve_expect_death(cmd);
    assert_eq!(code, Some(1));
    assert!(
        stderr.contains("band000.bits") && stderr.contains("bytes"),
        "torn checkpoint rejection not named: {stderr}"
    );

    let _ = std::fs::remove_dir_all(&root);
}
