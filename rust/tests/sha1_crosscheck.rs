//! Cross-check the from-scratch SHA-1 against the RustCrypto crate
//! (dev-dependency only) over random inputs of many lengths.

use lshbloom::hash::sha1::Sha1;
use lshbloom::rng::Xoshiro256pp;
use sha1::Digest;

#[test]
fn matches_rustcrypto_on_random_inputs() {
    let mut rng = Xoshiro256pp::seeded(0xCAFE);
    for len in [0usize, 1, 3, 55, 56, 57, 63, 64, 65, 127, 128, 1000, 4096, 100_000] {
        let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let ours = Sha1::digest(&data);
        let theirs = sha1::Sha1::digest(&data);
        assert_eq!(ours.as_slice(), theirs.as_slice(), "len={len}");
    }
}

#[test]
fn matches_rustcrypto_streaming() {
    let mut rng = Xoshiro256pp::seeded(0xBEEF);
    let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
    let mut ours = Sha1::new();
    let mut theirs = sha1::Sha1::new();
    let mut off = 0usize;
    while off < data.len() {
        let chunk = (rng.below(200) + 1) as usize;
        let end = (off + chunk).min(data.len());
        ours.update(&data[off..end]);
        theirs.update(&data[off..end]);
        off = end;
    }
    assert_eq!(ours.finalize().as_slice(), theirs.finalize().as_slice());
}
