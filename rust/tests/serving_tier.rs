//! Integration tests for the band-partitioned serving tier and the
//! service-protocol hardening.
//!
//! The load-bearing assertion is verdict parity: for one connection's
//! interleaved `check`/`check_batch` traffic, `serve --serve-shards N`
//! and a router over N loopback slice backends must produce verdict
//! vectors byte-identical to a single concurrent-engine server. The
//! rest covers the protocol edges: oversized request lines, server EOF
//! mid-request, wrong band counts, slice servers rejecting text ops,
//! a backend killed mid-stream, and slice-aware warm starts.

// Miri cannot emulate this (binds TCP listeners); the miri CI job
// covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::corpus::Doc;
use lshbloom::service::{DedupClient, DedupRouter, DedupServer, RouterOptions, ServeOptions};

fn base_cfg() -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        engine: EngineMode::Concurrent,
        ..Default::default()
    }
}

fn start_server(
    cfg: PipelineConfig,
    opts: ServeOptions,
) -> (std::thread::JoinHandle<()>, String) {
    let server = DedupServer::bind_with_opts("127.0.0.1:0", &cfg, &opts).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve().expect("serve"));
    (handle, addr)
}

/// Start `count` slice servers, one per contiguous band slice.
fn start_fleet(
    cfg: &PipelineConfig,
    count: usize,
    state_dir: Option<&std::path::Path>,
) -> (Vec<std::thread::JoinHandle<()>>, Vec<String>) {
    let mut handles = Vec::with_capacity(count);
    let mut addrs = Vec::with_capacity(count);
    for slice in 0..count {
        let opts = ServeOptions {
            state_dir: state_dir.map(|p| p.to_path_buf()),
            slice: Some((slice, count)),
            ..ServeOptions::default()
        };
        let (handle, addr) = start_server(cfg.clone(), opts);
        handles.push(handle);
        addrs.push(addr);
    }
    (handles, addrs)
}

fn start_router(
    cfg: &PipelineConfig,
    backends: Vec<String>,
) -> (std::thread::JoinHandle<()>, String) {
    let router = DedupRouter::bind("127.0.0.1:0", cfg, backends, &RouterOptions::default())
        .expect("bind router");
    let addr = router.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || router.serve().expect("route"));
    (handle, addr)
}

fn shutdown(addr: &str) {
    DedupClient::connect(addr).unwrap().shutdown().unwrap();
}

enum Op {
    Check(String),
    Batch(Vec<String>),
}

/// Deterministic interleaved traffic with exact twins inside batches,
/// across batches, and across the single/batched ops.
fn traffic() -> Vec<Op> {
    let doc = |i: u64| format!("serving tier parity document number {}", i % 37);
    let mut ops = Vec::new();
    let mut i = 0u64;
    while i < 200 {
        match i % 5 {
            0 | 3 => {
                ops.push(Op::Check(doc(i)));
                i += 1;
            }
            1 => {
                let batch: Vec<String> = (0..7).map(|j| doc(i + j)).collect();
                i += 7;
                ops.push(Op::Batch(batch));
            }
            2 => {
                // Batch with an in-batch twin (first element repeated):
                // exercises the reconcile rule on every serving path.
                let mut batch: Vec<String> = (0..5).map(|j| doc(i + j)).collect();
                batch.push(doc(i));
                i += 5;
                ops.push(Op::Batch(batch));
            }
            _ => {
                // Occasionally a fresh never-repeated document.
                ops.push(Op::Check(format!("one-off document {i}")));
                i += 1;
            }
        }
    }
    // An empty batch is a no-op on every path, not an error.
    ops.push(Op::Batch(Vec::new()));
    ops
}

/// Run the ops on one connection, collecting the flat verdict vector.
fn drive(addr: &str, ops: &[Op]) -> Vec<bool> {
    let mut client = DedupClient::connect(addr).unwrap();
    let mut verdicts = Vec::new();
    for op in ops {
        match op {
            Op::Check(text) => verdicts.push(client.check(text).unwrap()),
            Op::Batch(texts) => {
                let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
                verdicts.extend(client.check_batch(&refs).unwrap());
            }
        }
    }
    verdicts
}

#[test]
fn serve_shards_and_router_match_single_engine_verdicts() {
    let ops = traffic();

    // Reference: a single concurrent-engine server.
    let (handle, addr) = start_server(base_cfg(), ServeOptions::default());
    let expected = drive(&addr, &ops);
    let (ref_docs, ref_dups, _) = DedupClient::connect(&addr).unwrap().stats().unwrap();
    shutdown(&addr);
    handle.join().unwrap();
    // The traffic must exercise both verdicts or parity proves nothing.
    assert!(expected.iter().any(|&d| d) && expected.iter().any(|&d| !d));

    for count in [2usize, 4] {
        // In-process band shards: byte-identical verdict vector.
        let cfg = PipelineConfig { serve_shards: count, ..base_cfg() };
        let (handle, addr) = start_server(cfg, ServeOptions::default());
        let got = drive(&addr, &ops);
        assert_eq!(got, expected, "serve-shards={count}");
        let (docs, dups, _) = DedupClient::connect(&addr).unwrap().stats().unwrap();
        assert_eq!((docs, dups), (ref_docs, ref_dups), "serve-shards={count} counters");
        shutdown(&addr);
        handle.join().unwrap();

        // Router over `count` loopback slice backends: byte-identical
        // verdict vector again, and the router's counters match too.
        let (backend_handles, backend_addrs) = start_fleet(&base_cfg(), count, None);
        let (router_handle, router_addr) = start_router(&base_cfg(), backend_addrs.clone());
        let got = drive(&router_addr, &ops);
        assert_eq!(got, expected, "router count={count}");
        let (docs, dups, disk) = DedupClient::connect(&router_addr).unwrap().stats().unwrap();
        assert_eq!((docs, dups), (ref_docs, ref_dups), "router count={count} counters");
        assert!(disk > 0, "router stats must aggregate backend disk bytes");
        shutdown(&router_addr);
        router_handle.join().unwrap();
        for addr in &backend_addrs {
            shutdown(addr);
        }
        for handle in backend_handles {
            handle.join().unwrap();
        }
    }
}

#[test]
fn router_surfaces_backend_failure_instead_of_wrong_verdicts() {
    let cfg = base_cfg();
    let (mut backend_handles, backend_addrs) = start_fleet(&cfg, 2, None);
    let (router_handle, router_addr) = start_router(&cfg, backend_addrs.clone());
    let mut client = DedupClient::connect(&router_addr).unwrap();
    assert!(!client.check("healthy fan-out document").unwrap());
    assert!(client.check("healthy fan-out document").unwrap());

    // Kill backend 1 mid-stream and wait until its process-equivalent
    // thread is fully gone.
    shutdown(&backend_addrs[1]);
    backend_handles.remove(1).join().unwrap();

    // The next fan-out must fail fast with an error naming the backend
    // — never a verdict computed from half the bands.
    let err = client.check("document after the backend died").unwrap_err();
    assert!(err.to_string().contains("backend"), "got: {err}");
    // The router closed this connection (its fan-out state is torn).
    assert!(client.check("next request on the torn stream").is_err());

    // A fresh connection still fails (the backend is still dead), again
    // with a backend-scoped error rather than a wrong verdict.
    let mut fresh = DedupClient::connect(&router_addr).unwrap();
    let err = fresh.check("fresh connection, dead backend").unwrap_err();
    assert!(err.to_string().contains("backend"), "got: {err}");

    shutdown(&router_addr);
    router_handle.join().unwrap();
    shutdown(&backend_addrs[0]);
    for handle in backend_handles {
        handle.join().unwrap();
    }
}

#[test]
fn oversized_request_line_gets_error_then_close() {
    use std::io::{BufRead, BufReader, Write};
    let opts = ServeOptions { max_line_bytes: 1024, ..ServeOptions::default() };
    let (handle, addr) = start_server(base_cfg(), opts);

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // Stream bytes with no newline, well past the cap — the attack that
    // would previously grow the server's line buffer without bound.
    stream.write_all(&[b'a'; 8 * 1024]).unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.contains("error") && resp.contains("byte cap"), "got: {resp}");
    // After replying, the server closes (the stream is mid-line; no
    // further framing is trustworthy).
    resp.clear();
    assert_eq!(reader.read_line(&mut resp).unwrap(), 0, "connection must close");

    // The listener itself is unaffected.
    let mut client = DedupClient::connect(&addr).unwrap();
    assert!(!client.check("normal traffic still works").unwrap());
    client.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn client_reports_server_eof_as_unexpected_eof() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        // Read the request fully, then hang up without replying — a
        // clean FIN mid-request.
        let (stream, _) = listener.accept().unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let mut line = String::new();
        std::io::BufRead::read_line(&mut reader, &mut line).unwrap();
    });
    let mut client = DedupClient::connect(&addr).unwrap();
    let err = client.check("the server hangs up before responding").unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "got: {err}");
    assert!(err.to_string().contains("server closed connection"), "got: {err}");
    server.join().unwrap();
}

#[test]
fn check_bands_rejects_wrong_band_count_and_works_at_the_right_one() {
    let (handle, addr) = start_server(base_cfg(), ServeOptions::default());
    let mut client = DedupClient::connect(&addr).unwrap();

    let err = client.check_bands(&[1, 2, 3]).unwrap_err();
    assert!(err.to_string().contains("wrong band count"), "got: {err}");

    // At the right band count the op inserts and detects like check.
    let stats = client.stats_json().unwrap();
    let bands_len = stats.get("num_bands").unwrap().as_usize().unwrap();
    assert!(bands_len >= 4, "test geometry must have enough bands");
    let bands: Vec<u64> = (0..bands_len as u64).map(|i| i * 7 + 3).collect();
    assert!(!client.check_bands(&bands).unwrap());
    assert!(client.check_bands(&bands).unwrap());

    client.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn slice_server_rejects_text_ops_and_reports_its_layout() {
    let opts = ServeOptions { slice: Some((1, 2)), ..ServeOptions::default() };
    let (handle, addr) = start_server(base_cfg(), opts);
    let mut client = DedupClient::connect(&addr).unwrap();

    let err = client.check("text op against a lone slice").unwrap_err();
    assert!(err.to_string().contains("band slice"), "got: {err}");
    let err = client.check_batch(&["a", "b"]).unwrap_err();
    assert!(err.to_string().contains("band slice"), "got: {err}");

    let stats = client.stats_json().unwrap();
    assert_eq!(stats.get("slice_index").unwrap().as_usize(), Some(1));
    assert_eq!(stats.get("slice_count").unwrap().as_usize(), Some(2));

    client.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn router_rejects_a_misconfigured_fleet() {
    let cfg = base_cfg();
    // Two backends that both claim slice 0 of 2: the handshake must
    // fail fast instead of serving half-covered bands.
    let opts = ServeOptions { slice: Some((0, 2)), ..ServeOptions::default() };
    let (h1, a1) = start_server(cfg.clone(), opts.clone());
    let (h2, a2) = start_server(cfg.clone(), opts);
    let err = DedupRouter::bind(
        "127.0.0.1:0",
        &cfg,
        vec![a1.clone(), a2.clone()],
        &RouterOptions::default(),
    )
    .unwrap_err();
    assert!(err.to_string().contains("already claimed"), "got: {err}");

    // A fleet whose slice count disagrees with the backend list.
    let err = DedupRouter::bind("127.0.0.1:0", &cfg, vec![a1.clone()], &RouterOptions::default())
        .unwrap_err();
    assert!(err.to_string().contains("slice count"), "got: {err}");

    // A classic (text-only) backend is rejected at bind, not on the
    // first routed request.
    let classic = PipelineConfig { engine: EngineMode::Classic, ..base_cfg() };
    let (h3, a3) = start_server(classic, ServeOptions::default());
    let err = DedupRouter::bind("127.0.0.1:0", &cfg, vec![a3.clone()], &RouterOptions::default())
        .unwrap_err();
    assert!(err.to_string().contains("classic"), "got: {err}");

    shutdown(&a1);
    shutdown(&a2);
    shutdown(&a3);
    h1.join().unwrap();
    h2.join().unwrap();
    h3.join().unwrap();
}

#[test]
fn sharded_and_router_serving_warm_start_from_one_checkpoint() {
    let dir = std::env::temp_dir().join(format!("lshbloom-servewarm-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = base_cfg();

    // Build corpus state with a single engine and checkpoint it — the
    // same artifact a `dedup --checkpoint-dir` / `--distributed` run
    // leaves at its state root.
    let engine = lshbloom::engine::ConcurrentEngine::from_config(&cfg);
    let docs: Vec<Doc> = (0..50)
        .map(|i| Doc { id: i, text: format!("warm start corpus doc {i}") })
        .collect();
    engine.submit(docs.clone());
    engine.checkpoint(&dir).unwrap();

    // Band-sharded server slice-restores the checkpoint: every
    // checkpointed document is recognized and counters resume.
    let sharded_cfg = PipelineConfig { serve_shards: 2, ..cfg.clone() };
    let opts = ServeOptions { state_dir: Some(dir.clone()), ..ServeOptions::default() };
    let (handle, addr) = start_server(sharded_cfg, opts);
    let mut client = DedupClient::connect(&addr).unwrap();
    for doc in &docs {
        assert!(client.query(&doc.text).unwrap(), "sharded server lost doc {}", doc.id);
    }
    assert!(!client.query("a document that was never ingested").unwrap());
    let (docs_count, _, _) = client.stats().unwrap();
    assert_eq!(docs_count, 50, "warm-started counters must resume");
    client.shutdown().unwrap();
    handle.join().unwrap();

    // Router over two slice backends, each restoring its own band range
    // from the same full-index checkpoint.
    let (backend_handles, backend_addrs) = start_fleet(&cfg, 2, Some(dir.as_path()));
    let (router_handle, router_addr) = start_router(&cfg, backend_addrs.clone());
    let mut client = DedupClient::connect(&router_addr).unwrap();
    for doc in &docs {
        assert!(client.query(&doc.text).unwrap(), "router fleet lost doc {}", doc.id);
    }
    assert!(!client.query("a document that was never ingested").unwrap());
    shutdown(&router_addr);
    router_handle.join().unwrap();
    for addr in &backend_addrs {
        shutdown(addr);
    }
    for handle in backend_handles {
        handle.join().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}
