//! Distributed shard workers: the supervisor spawns REAL worker OS
//! processes via the self-exec path (`CARGO_BIN_EXE_lshbloom worker …`),
//! aggregates their published checkpoint directories, and — when a
//! worker is killed mid-ingest — restart-and-resume reproduces the
//! crash-free result exactly.

// Miri cannot emulate this (spawns real worker OS processes); the miri CI job
// covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{Doc, LabeledDoc};
use lshbloom::json::{obj, Value};
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::minhash::PermFamily;
use lshbloom::persist::{worker_dir_name, CheckpointManifest, WorkerManifest};
use lshbloom::pipeline::supervisor::{CRASH_AFTER_ENV, CRASH_SHARD_ENV};
use lshbloom::pipeline::{dedup_sharded, run_distributed, SupervisorOptions};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn cfg() -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        threshold: 0.5,
        expected_docs: 10_000,
        workers: 2,
        batch_size: 16,
        shards: 4,
        distributed: true,
        ..Default::default()
    }
}

fn opts() -> SupervisorOptions {
    SupervisorOptions {
        // Our own current_exe is the test harness, so the self-exec
        // target must be named explicitly.
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_lshbloom"))),
        ..Default::default()
    }
}

/// Corpus where every duplicate is an *exact* copy of an earlier
/// document (the regime where sharded and sequential survivor sets must
/// agree strictly), with copy distances that land both same-shard and
/// cross-shard under 4-way round-robin.
fn exact_dup_corpus(n: usize) -> Vec<Doc> {
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 3 == 2 && i >= 17 {
            // 2 and 5 are cross-shard for 4 shards; 16 is same-shard.
            let dist = [2u64, 16, 5, 16][((i / 3) % 4) as usize];
            let src = docs[(i - dist) as usize].clone();
            docs.push(Doc { id: i, ..src });
        } else {
            docs.push(Doc {
                id: i,
                text: format!(
                    "unique document alpha{i} beta{i} gamma{i} delta{i} \
                     epsilon{i} zeta{i} eta{i} theta{i}"
                ),
            });
        }
    }
    docs
}

fn save_jsonl(docs: &[Doc], path: &Path) {
    let mut out = String::new();
    for d in docs {
        out.push_str(
            &obj(vec![
                ("id", Value::u64(d.id)),
                ("text", Value::str(d.text.clone())),
                ("duplicate_of", Value::Null),
            ])
            .to_json(),
        );
        out.push('\n');
    }
    std::fs::write(path, out).unwrap();
}

/// `run_distributed` takes the CLI's already-loaded labeled corpus;
/// these tests drive it with unlabeled docs.
fn labeled(docs: &[Doc]) -> Vec<LabeledDoc> {
    docs.iter().map(|d| LabeledDoc { doc: d.clone(), duplicate_of: None }).collect()
}

fn tmp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lshbloom-dist-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn in_process_reference(config: &PipelineConfig, docs: &[Doc]) -> lshbloom::pipeline::ShardedStats {
    let mut mem_cfg = config.clone();
    mem_cfg.distributed = false;
    mem_cfg.checkpoint_dir = String::new();
    mem_cfg.checkpoint_every = 0;
    dedup_sharded(&mem_cfg, docs.to_vec(), config.shards)
}

#[test]
fn distributed_run_matches_in_process_sharded_run() {
    let root = tmp_root("clean");
    let docs = exact_dup_corpus(400);
    let input = root.join("corpus.jsonl");
    save_jsonl(&docs, &input);
    let state = root.join("state");
    let mut config = cfg();
    config.checkpoint_dir = state.display().to_string();

    let run = run_distributed(&config, &input, &labeled(&docs), &state, &opts()).unwrap();
    assert_eq!(run.restarts, 0, "clean run must not restart anything");
    assert_eq!(run.stats.docs, 400);

    let mem = in_process_reference(&config, &docs);
    assert_eq!(run.stats.verdicts, mem.verdicts, "verdict vector must be byte-identical");
    assert_eq!(run.stats.phase1_dropped, mem.phase1_dropped);
    assert_eq!(run.stats.phase2_dropped, mem.phase2_dropped);
    let dist_ids: Vec<u64> = run.stats.survivors.iter().map(|d| d.id).collect();
    let mem_ids: Vec<u64> = mem.survivors.iter().map(|d| d.id).collect();
    assert_eq!(dist_ids, mem_ids, "survivor set (and order) must be identical");
    assert!(run.stats.phase2_dropped > 0, "corpus was built with cross-shard duplicates");

    // Every worker left a complete publish directory…
    for s in 0..config.shards {
        let wdir = state.join(worker_dir_name(s));
        assert!(WorkerManifest::exists(&wdir), "worker {s} left no completion manifest");
        let m = WorkerManifest::load(&wdir).unwrap();
        assert_eq!(m.docs, 100);
        assert!(wdir.join("worker.log").is_file(), "worker {s} left no log");
    }
    // …and the supervisor published the aggregate at the state root for
    // `serve --state-dir`.
    assert!(CheckpointManifest::exists(&state), "aggregate checkpoint missing");
    let agg = CheckpointManifest::load(&state).unwrap();
    assert_eq!(agg.docs, 400);
    assert_eq!(agg.duplicates, mem.phase1_dropped + mem.phase2_dropped);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn killed_worker_restarts_resumes_and_matches_sequential() {
    let root = tmp_root("crash");
    let docs = exact_dup_corpus(400);
    let input = root.join("corpus.jsonl");
    save_jsonl(&docs, &input);
    let state = root.join("state");
    let mut config = cfg();
    config.checkpoint_dir = state.display().to_string();
    // Workers snapshot every 25 shard documents, so the injected crash
    // at >= 40 (shard 2 holds 100) lands after a checkpoint but before
    // the next one — the resume path must truncate the outcome tail and
    // re-process it.
    config.checkpoint_every = 25;
    let mut o = opts();
    o.worker_env = vec![
        (CRASH_SHARD_ENV.to_string(), "2".to_string()),
        (CRASH_AFTER_ENV.to_string(), "40".to_string()),
    ];

    let run = run_distributed(&config, &input, &labeled(&docs), &state, &o).unwrap();
    assert_eq!(run.restarts, 1, "exactly one worker must have crashed and been restarted");

    // Identical to the crash-free in-process run…
    let mem = in_process_reference(&config, &docs);
    assert_eq!(run.stats.verdicts, mem.verdicts, "restart-and-resume changed verdicts");
    let dist_ids: Vec<u64> = run.stats.survivors.iter().map(|d| d.id).collect();
    let mem_ids: Vec<u64> = mem.survivors.iter().map(|d| d.id).collect();
    assert_eq!(dist_ids, mem_ids);

    // …and the surviving *content set* matches the sequential decider
    // (exact duplicates: whichever copy survives, the texts agree).
    let mut seq_cfg = config.clone();
    seq_cfg.distributed = false;
    seq_cfg.checkpoint_dir = String::new();
    seq_cfg.checkpoint_every = 0;
    seq_cfg.shards = 1;
    let mut seq = lshbloom_method(&seq_cfg, PermFamily::Mix64);
    let seq_texts: BTreeSet<String> =
        docs.iter().filter(|d| !seq.process(d)).map(|d| d.text.clone()).collect();
    let dist_texts: BTreeSet<String> =
        run.stats.survivors.iter().map(|d| d.text.clone()).collect();
    assert_eq!(dist_texts, seq_texts, "survivor content diverged from the sequential run");

    // The crashed worker's log records both attempts.
    let log = std::fs::read_to_string(state.join(worker_dir_name(2)).join("worker.log")).unwrap();
    assert!(log.contains("injected crash"), "fault injection never fired:\n{log}");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn torn_worker_directory_is_not_mistaken_for_complete() {
    // A worker dir with outcomes + checkpoint but NO completion manifest
    // (the shape a kill leaves behind) must read as incomplete.
    let root = tmp_root("torn");
    let wdir = root.join(worker_dir_name(0));
    std::fs::create_dir_all(wdir.join("checkpoint")).unwrap();
    std::fs::write(wdir.join("outcomes.jsonl"), "{\"pos\":0,\"dup\":true}\n").unwrap();
    assert!(!WorkerManifest::exists(&wdir));
    assert!(WorkerManifest::load(&wdir).is_err());
    std::fs::remove_dir_all(&root).ok();
}
