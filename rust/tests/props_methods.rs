//! Property tests over the deduplication methods: streaming semantics,
//! exact-duplicate detection, and cross-method consistency.

use lshbloom::corpus::Doc;
use lshbloom::methods::{MethodKind, MethodSpec};
use lshbloom::perf::prop::{check, Gen};

fn random_doc(g: &mut Gen, sentences: usize) -> String {
    let mut text = String::new();
    for s in 0..sentences {
        for _ in 0..(4 + g.size(0, 12)) {
            text.push_str(&g.word(9));
            text.push(' ');
        }
        text.push('.');
        if s % 2 == 1 {
            text.push('\n');
        } else {
            text.push(' ');
        }
    }
    text
}

fn sample(g: &mut Gen) -> Vec<Doc> {
    (0..8).map(|i| Doc { id: i, text: random_doc(g, 4) }).collect()
}

/// Every technique must flag a byte-identical replay of a seen document.
#[test]
fn prop_exact_duplicates_always_flagged() {
    check("exact-dup-flagged", 20, |g: &mut Gen| {
        let s = sample(g);
        for kind in MethodKind::ALL {
            let mut m = MethodSpec::best(kind, 500).build(&s);
            let doc = Doc { id: 0, text: random_doc(g, 5) };
            assert!(!m.process(&doc), "{}: fresh doc flagged", kind.name());
            assert!(m.process(&doc), "{}: exact replay missed", kind.name());
            // And it stays flagged on every subsequent replay.
            assert!(m.process(&doc), "{}: third replay missed", kind.name());
        }
    });
}

/// The first document of any stream is never a duplicate.
#[test]
fn prop_first_document_never_duplicate() {
    check("first-doc-clean", 30, |g: &mut Gen| {
        let s = sample(g);
        for kind in MethodKind::ALL {
            let mut m = MethodSpec::best(kind, 500).build(&s);
            let doc = Doc { id: 0, text: random_doc(g, 3) };
            assert!(!m.process(&doc), "{}", kind.name());
        }
    });
}

/// Method verdicts are a pure function of the stream prefix: replaying
/// the same stream into a fresh instance yields the same verdicts.
#[test]
fn prop_methods_are_deterministic() {
    check("method-determinism", 12, |g: &mut Gen| {
        let s = sample(g);
        let stream: Vec<Doc> = (0..20)
            .map(|i| {
                // Mix fresh docs with replays of earlier ones.
                if i > 3 && g.bool(0.4) {
                    Doc { id: i, text: format!("replay body {}", g.below(3)) }
                } else {
                    Doc { id: i, text: random_doc(g, 3) }
                }
            })
            .collect();
        for kind in MethodKind::ALL {
            let run = |docs: &[Doc]| -> Vec<bool> {
                let mut m = MethodSpec::best(kind, 500).build(&s);
                docs.iter().map(|d| m.process(d)).collect()
            };
            assert_eq!(run(&stream), run(&stream), "{}", kind.name());
        }
    });
}

/// LSHBloom and MinHashLSH agree on (nearly) every verdict when driven
/// by the same permutation family — the paper's fidelity-parity claim,
/// as a property over random streams. Bloom false positives are bounded
/// by p_effective, so at these sizes disagreement means a bug.
#[test]
fn prop_lshbloom_minhashlsh_parity() {
    check("lsh-parity", 10, |g: &mut Gen| {
        let s = sample(g);
        let stream: Vec<Doc> = (0..30)
            .map(|i| {
                if i > 2 && g.bool(0.35) {
                    Doc { id: i, text: format!("shared duplicate body variant {}", g.below(4)) }
                } else {
                    Doc { id: i, text: random_doc(g, 3) }
                }
            })
            .collect();
        let mut a = MethodSpec::best(MethodKind::LshBloom, 500).build(&s);
        let mut b = MethodSpec::best(MethodKind::MinHashLsh, 500).build(&s);
        for (i, d) in stream.iter().enumerate() {
            let va = a.process(d);
            let vb = b.process(d);
            assert_eq!(va, vb, "doc {i}: lshbloom={va} minhashlsh={vb}");
        }
    });
}

/// Empty and degenerate documents never crash any method and are never
/// duplicates of each other... except exact-empty matches where unit
/// methods legitimately return false (no units).
#[test]
fn prop_degenerate_documents_are_safe() {
    check("degenerate-docs", 15, |g: &mut Gen| {
        let s = sample(g);
        let degenerates = [
            String::new(),
            " ".to_string(),
            "\n\n\n".to_string(),
            "x".to_string(),
            "\u{FB03}".to_string(),
            "0 0 0 0 0".to_string(),
        ];
        for kind in MethodKind::ALL {
            let mut m = MethodSpec::best(kind, 100).build(&s);
            for text in &degenerates {
                // Must not panic; verdict itself is method-specific.
                let _ = m.process(&Doc { id: g.u64(), text: text.clone() });
            }
        }
    });
}
