//! Integration tests for the crash-safe persistence subsystem
//! (`persist`): checkpoint/restore equality, torn-checkpoint rejection,
//! and the cross-process shard-union seam.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{DatasetSpec, Doc, LabeledCorpus};
use lshbloom::engine::ConcurrentEngine;
use lshbloom::persist::{self, CheckpointManifest, CheckpointMode};
use lshbloom::pipeline::{
    dedup_sharded, dedup_sharded_with_state, run_stream_engine, run_stream_engine_checkpointed,
    CheckpointPolicy, PipelineOptions,
};
use std::path::PathBuf;

fn cfg() -> PipelineConfig {
    PipelineConfig { num_perms: 64, expected_docs: 10_000, workers: 4, ..Default::default() }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("lshbloom-persist-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn corpus(seed: u64, n: usize) -> Vec<Doc> {
    LabeledCorpus::build(DatasetSpec::testing(seed, n, 0.5))
        .docs
        .into_iter()
        .map(|ld| ld.doc)
        .collect()
}

/// The headline acceptance test: a run checkpointed mid-stream and
/// resumed in a *fresh* engine produces the identical survivor set —
/// zero false negatives, zero extra drops — as the uninterrupted run.
#[test]
fn restore_equality_checkpoint_midstream_resume_in_fresh_engine() {
    let dir = tmp_dir("equality");
    let config = cfg();
    let docs = corpus(71, 400);
    let opts = PipelineOptions { workers: 4, batch_size: 8, channel_depth: 4 };

    // Reference: one uninterrupted engine over the whole stream.
    let full_engine = ConcurrentEngine::from_config(&config);
    let full = run_stream_engine(&full_engine, docs.iter().cloned(), opts);

    // Durable run over the first half only, then "killed" (dropped).
    let cut = 200usize;
    {
        let engine = ConcurrentEngine::new_persistent(&config, &dir).unwrap();
        let first = run_stream_engine_checkpointed(
            &engine,
            docs[..cut].iter().cloned(),
            opts,
            Some(&CheckpointPolicy { dir: dir.clone(), every_docs: 64 }),
        )
        .unwrap();
        assert_eq!(first.verdicts, full.verdicts[..cut], "prefix verdicts must agree");
    }

    // Fresh engine restored from the checkpoint; continue with the rest.
    let resumed = ConcurrentEngine::restore(&config, &dir, true).unwrap();
    assert_eq!(resumed.stats().0, cut as u64, "manifest covers the exact prefix");
    let rest = run_stream_engine(&resumed, docs[cut..].iter().cloned(), opts);
    assert_eq!(
        rest.verdicts,
        full.verdicts[cut..],
        "post-restore verdicts must match the uninterrupted run exactly"
    );

    // Survivor sets are therefore identical — in particular, no
    // duplicate ever escapes (zero false negatives).
    let full_survivors: Vec<u64> = docs
        .iter()
        .zip(&full.verdicts)
        .filter(|(_, &dup)| !dup)
        .map(|(d, _)| d.id)
        .collect();
    let resumed_survivors: Vec<u64> = docs[..cut]
        .iter()
        .zip(&full.verdicts[..cut])
        .chain(docs[cut..].iter().zip(&rest.verdicts))
        .filter(|(_, &dup)| !dup)
        .map(|(d, _)| d.id)
        .collect();
    assert_eq!(resumed_survivors, full_survivors);
    std::fs::remove_dir_all(&dir).ok();
}

/// Heap restore (no mmap) answers identically to the warm mmap restore.
#[test]
fn heap_and_mmap_restore_agree() {
    let dir = tmp_dir("heapmmap");
    let config = cfg();
    let docs = corpus(73, 150);
    {
        let engine = ConcurrentEngine::new_persistent(&config, &dir).unwrap();
        for chunk in docs.chunks(32) {
            engine.submit(chunk.to_vec());
        }
        engine.checkpoint(&dir).unwrap();
    }
    let warm = ConcurrentEngine::restore(&config, &dir, true).unwrap();
    let cold = ConcurrentEngine::restore(&config, &dir, false).unwrap();
    assert_eq!(warm.stats(), cold.stats());
    for doc in &docs {
        assert_eq!(warm.query_one(doc), cold.query_one(doc), "doc {}", doc.id);
        assert!(cold.query_one(doc), "restored filter lost doc {}", doc.id);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Torn checkpoints must be rejected with a clear error, never silently
/// admitted (a truncated or corrupted filter answers `false` for keys
/// it should know — Bloom false negatives).
#[test]
fn torn_checkpoint_rejected() {
    let dir = tmp_dir("torn");
    let config = cfg();
    // A heap engine checkpoints as a cold snapshot => checksums enforced.
    let engine = ConcurrentEngine::from_config(&config);
    engine.submit(corpus(79, 120));
    engine.checkpoint(&dir).unwrap();
    let manifest = CheckpointManifest::load(&dir).unwrap();
    assert_eq!(manifest.mode, CheckpointMode::Snapshot);

    // 1) Bit-flip inside a band file -> checksum mismatch.
    let band0 = dir.join("band000.bits");
    let mut bytes = std::fs::read(&band0).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&band0, &bytes).unwrap();
    let err = ConcurrentEngine::restore(&config, &dir, false).unwrap_err();
    assert!(err.to_string().contains("checksum"), "want checksum error, got: {err}");
    // The mmap restore path verifies too.
    let err = ConcurrentEngine::restore(&config, &dir, true).unwrap_err();
    assert!(err.to_string().contains("checksum"), "want checksum error, got: {err}");

    // 2) Truncated band file -> size mismatch, flagged before checksums.
    bytes[mid] ^= 0xFF; // undo the flip
    bytes.truncate(bytes.len() - 8);
    std::fs::write(&band0, &bytes).unwrap();
    let err = ConcurrentEngine::restore(&config, &dir, false).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("torn") || msg.contains("refusing"),
        "want size-mismatch refusal, got: {msg}"
    );

    // 3) Geometry drift: same files, different run config.
    let mut other = config.clone();
    other.p_effective = 1e-6;
    let err = ConcurrentEngine::restore(&other, &dir, false).unwrap_err();
    assert!(err.to_string().contains("geometry mismatch"), "{err}");

    // 4) Truncated manifest JSON -> parse error, not a panic.
    std::fs::write(dir.join("manifest.json"), "{\"version\": 1, \"mode\": \"snap").unwrap();
    assert!(ConcurrentEngine::restore(&config, &dir, false).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// The cross-process union seam: OR-ing a persisted checkpoint into a
/// live index answers exactly like the in-memory `union_from`.
#[test]
fn union_from_checkpoint_matches_in_memory_union() {
    let dir = tmp_dir("union");
    let config = cfg();
    let docs_a = corpus(83, 120);
    let docs_b = corpus(97, 120);

    // Sibling "process" B: ingest + checkpoint.
    let engine_b = ConcurrentEngine::from_config(&config);
    engine_b.submit(docs_b.clone());
    engine_b.checkpoint(&dir).unwrap();

    // This process: ingest A, then fold B's files in.
    let engine_a = ConcurrentEngine::from_config(&config);
    engine_a.submit(docs_a.clone());
    let merged_docs = persist::union_from_checkpoint(engine_a.index(), &dir).unwrap();
    assert_eq!(merged_docs, 120);

    // Reference: in-memory union of two fresh identical ingests.
    let ref_a = ConcurrentEngine::from_config(&config);
    ref_a.submit(docs_a.clone());
    let ref_b = ConcurrentEngine::from_config(&config);
    ref_b.submit(docs_b.clone());
    let ref_index = ref_a.into_concurrent_index();
    ref_index.union_from(&ref_b.into_concurrent_index());

    assert_eq!(
        engine_a.index().fill_ratios(),
        ref_index.fill_ratios(),
        "file-union and memory-union must be bit-identical"
    );
    assert_eq!(engine_a.index().len(), ref_index.len());
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end sharded equivalence: the on-disk phase-2 aggregation
/// (shard checkpoints + union-from-files) reproduces both the in-memory
/// sharded run and, for exact duplicates, the sequential survivor set.
#[test]
fn sharded_on_disk_aggregation_no_false_negatives() {
    let dir = tmp_dir("shardfiles");
    let config = cfg();
    // Exact-duplicate corpus: every 3rd doc repeats an earlier one.
    let base = corpus(101, 90);
    let mut docs = Vec::new();
    for (i, d) in base.into_iter().enumerate() {
        docs.push(d.clone());
        if i % 3 == 0 {
            docs.push(Doc { id: 1000 + i as u64, text: d.text });
        }
    }
    let mem = dedup_sharded(&config, docs.clone(), 4);
    let disk = dedup_sharded_with_state(&config, docs.clone(), 4, Some(dir.as_path())).unwrap();
    assert_eq!(disk.verdicts, mem.verdicts);
    // No duplicate content may survive twice (zero false negatives).
    let mut seen = std::collections::HashSet::new();
    for d in &disk.survivors {
        assert!(seen.insert(d.text.clone()), "duplicate text survived: doc {}", d.id);
    }
    std::fs::remove_dir_all(&dir).ok();
}
