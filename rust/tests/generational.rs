//! Integration tests for the capacity autopilot's generational index.
//!
//! The contract under test: a stream that overruns `--expect-docs`
//! rotates the concurrent index into fresh filter generations with
//! **zero false negatives** (probes OR across every generation), the
//! rotation history round-trips checkpoint → restore, a torn
//! generational checkpoint is refused by name, and a restarted replica
//! `--sync-from`s the whole generation layout — not just generation 0 —
//! before it serves probes.

// Miri cannot emulate the subprocess/TCP halves; the miri CI job covers
// the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::{EngineMode, PipelineConfig};
use lshbloom::corpus::Doc;
use lshbloom::engine::{ConcurrentEngine, ConcurrentLshBloomIndex};
use lshbloom::index::lshbloom::LshBloomConfig;
use lshbloom::methods::lshbloom::BandPreparer;
use lshbloom::minhash::LshParams;
use lshbloom::persist::{restore_index, write_checkpoint};
use lshbloom::rng::Xoshiro256pp;
use lshbloom::service::DedupClient;
use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Stdio};

/// Fresh per-test temp root (removes any stale leftover first).
fn tmp_root(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("lshbloom-generational-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One random band-hash vector (stands in for a unique document).
fn random_doc(rng: &mut Xoshiro256pp, num_bands: usize) -> Vec<u64> {
    (0..num_bands).map(|_| rng.next_u64()).collect()
}

/// A rotated index must agree verdict-for-verdict with a single index
/// that was adequately sized up front: rotation is capacity management,
/// not a semantic change. The stream overruns the small plan 6x and
/// replays deterministic twins across generation boundaries, so a
/// frozen-generation probe miss would surface as a verdict mismatch.
#[test]
fn rotation_matches_adequately_sized_oracle_verdicts() {
    let lsh = LshParams { num_bands: 8, rows_per_band: 4 };
    // Same geometry, 6x-underestimated capacity on the rotating side;
    // the tiny FP budget keeps both sides' false-positive mass
    // negligible so verdicts are label-exact, not merely similar.
    let mut rotated = ConcurrentLshBloomIndex::new(LshBloomConfig::new(lsh, 1e-9, 300));
    rotated.enable_rotation(0.5);
    let oracle = ConcurrentLshBloomIndex::new(LshBloomConfig::new(lsh, 1e-9, 10_000));

    let mut rng = Xoshiro256pp::seeded(0x6E2A_51CE);
    let mut seen: Vec<Vec<u64>> = Vec::new();
    for i in 0..1_800usize {
        let doc = if i % 7 == 3 && !seen.is_empty() {
            // Twin of an earlier document — often one ingested into a
            // generation that has since been frozen.
            seen[(i * 31) % seen.len()].clone()
        } else {
            let d = random_doc(&mut rng, lsh.num_bands);
            seen.push(d.clone());
            d
        };
        let r = rotated.insert_if_new_shared(&doc);
        let o = oracle.insert_if_new_shared(&doc);
        assert_eq!(r, o, "doc {i}: rotated index verdict diverged from the oracle");
    }
    assert!(
        rotated.num_generations() > 1,
        "a 6x overrun never rotated ({} generations)",
        rotated.num_generations()
    );
    assert!(rotated.rotations() >= 1);
    assert_eq!(oracle.num_generations(), 1, "the adequately-sized oracle must not rotate");

    // Zero false negatives: every document ever inserted is still a
    // member, wherever its generation ended up.
    for (i, doc) in seen.iter().enumerate() {
        assert!(rotated.query(doc), "doc {i} lost across rotation");
    }
}

/// The full rotation history survives checkpoint → restore, and a
/// manifest that records a generation whose directory is gone is
/// refused with the torn-checkpoint error naming it — never silently
/// reopened smaller (which would manufacture Bloom false negatives).
#[test]
fn generational_checkpoint_roundtrips_and_refuses_torn_generations() {
    let cfg = LshBloomConfig::new(LshParams { num_bands: 6, rows_per_band: 4 }, 1e-8, 256);
    let mut index = ConcurrentLshBloomIndex::new(cfg);
    index.enable_rotation(0.5);
    let mut rng = Xoshiro256pp::seeded(0x51CE_B007);
    let docs: Vec<Vec<u64>> =
        (0..1_500).map(|_| random_doc(&mut rng, cfg.lsh.num_bands)).collect();
    for doc in &docs {
        index.insert_if_new_shared(doc);
    }
    assert!(index.num_generations() > 1, "overrun corpus must rotate");

    let dir = tmp_root("roundtrip");
    let manifest = write_checkpoint(&index, docs.len() as u64, 0, &dir).unwrap();
    assert_eq!(
        manifest.num_generations(),
        index.num_generations(),
        "manifest must record every generation"
    );

    let (restored, manifest) = restore_index(&dir, &cfg, false).unwrap();
    assert_eq!(restored.num_generations(), index.num_generations());
    assert_eq!(manifest.inserted, index.len());
    for (i, doc) in docs.iter().enumerate() {
        assert!(restored.query(doc), "doc {i} lost across checkpoint round-trip");
    }

    // Tear the checkpoint: drop a rotated generation's directory.
    std::fs::remove_dir_all(dir.join("gen001")).unwrap();
    let err = restore_index(&dir, &cfg, false).unwrap_err().to_string();
    assert!(
        err.contains("generation") && err.contains("gen001"),
        "torn generational checkpoint not refused by name: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Subprocess half: `--sync-from` anti-entropy across a rotation.
// ---------------------------------------------------------------------

fn sync_cfg() -> PipelineConfig {
    PipelineConfig {
        num_perms: 64,
        expected_docs: 512,
        engine: EngineMode::Concurrent,
        ..Default::default()
    }
}

/// One slice-server subprocess (slice 0 of 1, geometry mirroring
/// [`sync_cfg`]); SIGKILLed on drop so a failed assertion never leaks
/// servers.
struct SliceProc {
    child: Child,
    addr: String,
    // Held so the server's stdout pipe stays open for its lifetime.
    _stdout: BufReader<ChildStdout>,
}

impl Drop for SliceProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn a slice server over `state_dir` and block until it prints its
/// listening line (skipping the capacity-plan echo and anything else).
fn spawn_slice(state_dir: &Path, sync_from: Option<&str>) -> SliceProc {
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_lshbloom"));
    cmd.arg("serve")
        .args(["--addr", "127.0.0.1:0", "--engine", "concurrent"])
        .args(["--perms", "64", "--expected-docs", "512"])
        .args(["--slice-index", "0", "--slice-count", "1"])
        .args(["--state-dir", state_dir.to_str().unwrap()]);
    if let Some(peers) = sync_from {
        cmd.args(["--sync-from", peers]);
    }
    cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
    let mut child = cmd.spawn().expect("spawn slice server");
    let mut reader = BufReader::new(child.stdout.take().expect("child stdout"));
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read slice server stdout");
        if n == 0 {
            let _ = child.wait();
            let mut err = String::new();
            if let Some(mut e) = child.stderr.take() {
                let _ = e.read_to_string(&mut err);
            }
            panic!("slice server exited before listening: {err}");
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            let addr = rest.split_whitespace().next().expect("listen addr token").to_string();
            return SliceProc { child, addr, _stdout: reader };
        }
    }
}

fn generations_of(client: &mut DedupClient) -> u64 {
    client
        .stats_json()
        .unwrap()
        .get("generations")
        .and_then(|v| v.as_u64())
        .expect("slice stats carries 'generations'")
}

/// `pull_bands` one (band, generation): the filter words + insert
/// counter the anti-entropy merge transfers.
fn pull_words(client: &mut DedupClient, band: usize, gen: usize) -> (Vec<u64>, u64) {
    let reply = client.pull_band(band, gen).expect("pull_bands");
    let words: Vec<u64> = reply
        .get("words")
        .and_then(|v| v.as_arr())
        .expect("pull_bands reply words")
        .iter()
        .map(|w| w.as_u64().expect("u64 filter word"))
        .collect();
    (words, reply.get("inserted").and_then(|v| v.as_u64()).unwrap_or(0))
}

/// Band hashes for one document, bit-identical to what every serving
/// path computes (shared preparer construction).
fn bands_for(preparer: &BandPreparer, text: &str) -> Vec<u64> {
    let sig = preparer.hasher.signature(&lshbloom::text::normalize(text));
    let mut bands = Vec::new();
    lshbloom::hash::band::band_hashes_for_doc(
        &sig,
        preparer.lsh.num_bands,
        preparer.lsh.rows_per_band,
        &mut bands,
    );
    bands
}

/// A replica that `--sync-from`s a peer whose index rotated must grow
/// to the peer's generation layout and converge bit-for-bit in *every*
/// generation — syncing only generation 0 would silently drop the
/// frozen generations' membership and admit false negatives.
#[test]
fn sync_from_converges_across_a_rotation() {
    let cfg = sync_cfg();
    let root = tmp_root("sync");
    let peer_dir = root.join("peer");
    let rep_dir = root.join("replica");

    // Ingest 4x the planned capacity in-process so the index rotates,
    // then persist the rotated layout as the peer's durable state.
    // Tokens all embed the doc number, so distinct documents share no
    // shingles and the filters genuinely fill.
    let engine = ConcurrentEngine::from_config(&cfg);
    let docs: Vec<Doc> = (0..2_048u64)
        .map(|i| Doc {
            id: i,
            text: format!("t{i}x0 t{i}x1 t{i}x2 t{i}x3 t{i}x4 t{i}x5"),
        })
        .collect();
    let early_doc = docs[3].text.clone();
    engine.submit(docs);
    assert!(
        engine.index().num_generations() > 1,
        "a 4x overrun must rotate ({} generations)",
        engine.index().num_generations()
    );
    engine.checkpoint(&peer_dir).unwrap();

    let peer = spawn_slice(&peer_dir, None);
    let mut pc = DedupClient::connect(&peer.addr).unwrap();
    let peer_gens = generations_of(&mut pc);
    assert!(peer_gens > 1, "peer must re-attach the rotated layout");

    // A fresh replica (empty state dir) anti-entropies the whole
    // rotation history at bind.
    let rep = spawn_slice(&rep_dir, Some(&peer.addr));
    let mut rc = DedupClient::connect(&rep.addr).unwrap();
    assert_eq!(generations_of(&mut rc), peer_gens, "replica generation layout diverges");

    // Bit-for-bit parity in every (generation, band) cell.
    let num_bands = pc
        .stats_json()
        .unwrap()
        .get("num_bands")
        .and_then(|v| v.as_u64())
        .expect("slice stats carries 'num_bands'") as usize;
    for gen in 0..peer_gens as usize {
        for band in 0..num_bands {
            let (pw, pi) = pull_words(&mut pc, band, gen);
            let (rw, ri) = pull_words(&mut rc, band, gen);
            assert_eq!(pw, rw, "gen {gen} band {band}: filter words diverge after sync");
            assert_eq!(pi, ri, "gen {gen} band {band}: insert counters diverge after sync");
        }
    }

    // Zero false negatives across rotation + sync: a document ingested
    // before the first rotation is a duplicate on the synced replica.
    let preparer = BandPreparer::from_config(&cfg);
    assert!(
        rc.check_bands(&bands_for(&preparer, &early_doc)).unwrap(),
        "pre-rotation document lost by the synced replica"
    );

    DedupClient::connect(&rep.addr).unwrap().shutdown().unwrap();
    DedupClient::connect(&peer.addr).unwrap().shutdown().unwrap();
    drop(rep);
    drop(peer);
    let _ = std::fs::remove_dir_all(&root);
}
