//! Cross-language equivalence: the XLA artifact backend must be
//! bit-identical to the native rust mix64 backend, and both must match
//! the golden vectors emitted by the python reference oracle.

// Miri cannot emulate this (loads XLA artifacts through PJRT FFI); the miri CI job
// covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::Doc;
use lshbloom::hash::mix64::{default_seeds, PERM_MASTER_SEED};
use lshbloom::json;
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::methods::{Prepared, Preparer};
use lshbloom::minhash::{MinHasher, PermFamily};
use lshbloom::runtime::XlaBandPreparer;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("artifacts/ missing; run `make artifacts` first — skipping");
        None
    }
}

#[test]
fn golden_vectors_pin_native_backend_to_python_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let g = json::parse(&text).unwrap();

    let p = g.get("P").unwrap().as_usize().unwrap();
    let num_bands = g.get("num_bands").unwrap().as_usize().unwrap();
    let rows = g.get("rows_per_band").unwrap().as_usize().unwrap();
    assert_eq!(
        g.get("perm_master_seed").unwrap().as_u64().unwrap(),
        PERM_MASTER_SEED,
        "master seed drifted between python and rust"
    );

    // Seeds must match the rust derivation exactly.
    let seeds: Vec<u64> = g.get("seeds").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_u64().unwrap())
        .collect();
    assert_eq!(seeds, default_seeds(p), "seed stream mismatch");

    let tokens: Vec<Vec<u64>> = g.get("tokens").unwrap().as_arr().unwrap().iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_u64().unwrap()).collect())
        .collect();
    let expect_sigs: Vec<Vec<u64>> = g.get("signatures").unwrap().as_arr().unwrap().iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_u64().unwrap()).collect())
        .collect();
    let expect_bands: Vec<Vec<u64>> = g.get("band_hashes").unwrap().as_arr().unwrap().iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_u64().unwrap()).collect())
        .collect();

    let hasher = MinHasher::new(PermFamily::Mix64, p, 1);
    for (d, row) in tokens.iter().enumerate() {
        // Golden tokens use u64::MAX as padding; the rust signature path
        // treats pad values identically (the oracle masks them out —
        // replicate by filtering).
        let valid: Vec<u64> = row.iter().copied().filter(|&t| t != u64::MAX).collect();
        let sig = hasher.signature_of_hashes(&valid);
        assert_eq!(sig, expect_sigs[d], "signature row {d}");
        let mut bands = Vec::new();
        lshbloom::hash::band::band_hashes_for_doc(&sig, num_bands, rows, &mut bands);
        assert_eq!(bands, expect_bands[d], "band row {d}");
    }
}

#[test]
fn xla_backend_bit_identical_to_native_on_corpus() {
    let Some(dir) = artifacts_dir() else { return };
    // Use the "test" config artifacts: T=0.5, P=128 (fast compile).
    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        ngram: 1,
        artifacts_dir: dir.display().to_string(),
        expected_docs: 10_000,
        ..Default::default()
    };
    let xla = XlaBandPreparer::from_manifest(&dir, 0.5, 128, 1).expect("load artifacts");
    let native = lshbloom_method(&cfg, PermFamily::Mix64);

    // A mixed batch: empty doc, short docs, and one long doc exceeding
    // the artifact's L=128 so the chunked sigs path is exercised.
    let g = lshbloom::corpus::CorpusGenerator::new(lshbloom::corpus::GeneratorConfig::short());
    let mut docs: Vec<Doc> = (0..20).map(|i| g.generate(123, i)).collect();
    docs.push(Doc { id: 20, text: String::new() });
    let long_text: String = (0..600).map(|i| format!("tok{i} ")).collect();
    docs.push(Doc { id: 21, text: long_text });

    let a = xla.prepare_batch(&docs);
    let b = native.preparer.prepare_batch(&docs);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let (Prepared::Bands(xb), Prepared::Bands(yb)) = (x, y) else {
            panic!("non-bands payload");
        };
        assert_eq!(xb, yb, "doc {i}: XLA and native band hashes differ");
    }
}

#[test]
fn xla_method_end_to_end_matches_native_verdicts() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        ngram: 1,
        artifacts_dir: dir.display().to_string(),
        expected_docs: 10_000,
        ..Default::default()
    };
    let corpus = lshbloom::corpus::LabeledCorpus::build(
        lshbloom::corpus::DatasetSpec::testing(47, 80, 0.5),
    );
    let mut xla = lshbloom::runtime::lshbloom_method_xla(&cfg).expect("xla method");
    let mut native = lshbloom_method(&cfg, PermFamily::Mix64);
    let va = xla.process_all(&corpus.docs);
    let vb = native.process_all(&corpus.docs);
    assert_eq!(va, vb, "XLA-backed pipeline must reproduce native verdicts exactly");
}

#[test]
fn xla_method_works_through_parallel_pipeline() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = PipelineConfig {
        threshold: 0.5,
        num_perms: 128,
        artifacts_dir: dir.display().to_string(),
        expected_docs: 10_000,
        ..Default::default()
    };
    let corpus = lshbloom::corpus::LabeledCorpus::build(
        lshbloom::corpus::DatasetSpec::testing(53, 120, 0.5),
    );
    let mut native = lshbloom_method(&cfg, PermFamily::Mix64);
    let expected = native.process_all(&corpus.docs);

    let mut xla = lshbloom::runtime::lshbloom_method_xla(&cfg).expect("xla method");
    let stats = lshbloom::pipeline::run_stream(
        &mut xla,
        corpus.docs.iter().map(|ld| ld.doc.clone()),
        lshbloom::pipeline::PipelineOptions { workers: 3, batch_size: 16, channel_depth: 4 },
    );
    assert_eq!(stats.verdicts, expected);
}

#[test]
fn manifest_mismatch_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    // No artifact exists for this configuration.
    let Err(err) = XlaBandPreparer::from_manifest(&dir, 0.31, 128, 1) else {
        panic!("expected missing-artifact error");
    };
    let msg = err.to_string();
    assert!(msg.contains("minhash_bands"), "unhelpful error: {msg}");
}
