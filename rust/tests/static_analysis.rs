//! The in-repo soundness linter, run over the real tree at test time.
//!
//! This is the enforcement point for the repo-specific invariants
//! (SAFETY coverage, panic-free serving paths, ordering discipline,
//! wire-op/metric parity, offline build): plain `cargo test -q` fails
//! on any violation, so the invariants hold on every future change.
//! Rule catalog and escape syntax: docs/OPERATIONS.md "Lint catalog".

use lshbloom::analysis::{lint_set, lint_tree, rules, scanner, SourceSet};
use std::path::{Path, PathBuf};
use std::time::Instant;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent repo root")
        .to_path_buf()
}

/// The whole tree lints clean — zero findings, with every finding
/// printed `file:line: [rule] message` when it does not.
#[test]
fn tree_has_zero_violations() {
    let report = lint_tree(&repo_root()).expect("lint_tree walks the repo");
    for f in &report.findings {
        eprintln!("{f}");
    }
    assert!(
        report.findings.is_empty(),
        "{} lint finding(s) — see diagnostics above",
        report.findings.len()
    );
    assert!(
        report.files_scanned >= 40,
        "walker saw only {} files; the tree scan is broken",
        report.files_scanned
    );
}

/// The acceptance bound: the full-tree pass stays well under 5 seconds
/// (it is one linear scan per file plus set comparisons).
#[test]
fn full_tree_lint_completes_quickly() {
    let started = Instant::now();
    let report = lint_tree(&repo_root()).expect("lint_tree walks the repo");
    let elapsed = started.elapsed();
    assert!(report.files_scanned > 0);
    assert!(
        elapsed.as_secs_f64() < 5.0,
        "lint took {elapsed:?} over {} files; the 5s budget is blown",
        report.files_scanned
    );
}

/// Every `unsafe` site in the tree is accounted for: the count is
/// pinned so a new unsafe block is a deliberate, reviewed event (update
/// this constant in the same change that adds its SAFETY comment).
#[test]
fn unsafe_site_inventory_is_pinned() {
    const EXPECTED_UNSAFE_SITES: usize = 14;
    let src = repo_root().join("rust").join("src");
    let mut stack = vec![src];
    let mut total = 0usize;
    let mut by_file = Vec::new();
    while let Some(dir) = stack.pop() {
        for ent in std::fs::read_dir(&dir).expect("read_dir src") {
            let path = ent.expect("dir entry").path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let text = std::fs::read_to_string(&path).expect("read source");
                let scanned = scanner::scan(&path.display().to_string(), &text);
                let n = rules::count_unsafe_sites(&scanned);
                if n > 0 {
                    by_file.push((path, n));
                    total += n;
                }
            }
        }
    }
    assert_eq!(
        total, EXPECTED_UNSAFE_SITES,
        "unsafe-site inventory drifted: {by_file:?}"
    );
}

fn fixture_set(path: &str, src: &str) -> SourceSet {
    SourceSet {
        files: vec![scanner::scan(path, src)],
        operations_md: String::new(),
        cargo_toml: "# [dependencies]\n".to_string(),
    }
}

/// Known-bad source produces `file:line` diagnostics for each rule —
/// the fixture half of the acceptance criterion (the CLI exit path on
/// top of this is a thin wrapper in `main.rs`).
#[test]
fn fixture_violations_are_reported_with_file_and_line() {
    let src = "\
fn f(p: *const u64) -> u64 {
    unsafe { *p }
}
";
    let findings = lint_set(&fixture_set("src/bloom/bad.rs", src));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, rules::SAFETY_COMMENT);
    assert_eq!(findings[0].line, 2);
    assert_eq!(findings[0].to_string().lines().count(), 1);
    assert!(findings[0].to_string().starts_with("src/bloom/bad.rs:2: [safety-comment]"));

    let src = "\
pub fn handle(x: Option<u32>) -> u32 {
    x.unwrap()
}
";
    let findings = lint_set(&fixture_set("src/service/bad.rs", src));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, rules::NO_PANIC_PATHS);
    assert_eq!(findings[0].line, 2);

    let src = "\
use std::sync::atomic::{AtomicU64, Ordering};
pub fn probe(w: &AtomicU64) -> u64 {
    w.load(Ordering::Relaxed)
}
";
    let findings = lint_set(&fixture_set("src/engine/bad.rs", src));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, rules::ORDERING_DISCIPLINE);
    assert_eq!(findings[0].line, 3);

    let findings = lint_set(&fixture_set(
        "src/pipeline/bad.rs",
        "pub fn noisy() {\n    println!(\"debug\");\n}\n",
    ));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, rules::NO_STRAY_PRINT);
    assert_eq!(findings[0].line, 2);
}

/// The same violations inside comments, string literals, or test code
/// produce nothing — the scanner half of the fixture test.
#[test]
fn fixture_non_code_contexts_stay_clean() {
    let src = r##"
// x.unwrap() in a comment, and unsafe { } too
pub fn quiet() -> &'static str {
    "panic!(\"not real\") and Ordering::Relaxed .load( in a string"
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let x: Option<u32> = Some(1);
        x.unwrap();
    }
}
"##;
    let findings = lint_set(&fixture_set("src/service/ok.rs", src));
    assert!(findings.is_empty(), "{findings:?}");
}

/// An annotated exception suppresses its finding; a dead escape is
/// itself a finding — the full escape round-trip at the engine level.
#[test]
fn fixture_escape_roundtrip_and_staleness() {
    let allowed = "\
pub fn report() {
    // lint: allow(no-stray-print) operator-facing table
    println!(\"rows\");
}
";
    let findings = lint_set(&fixture_set("src/engine/esc.rs", allowed));
    assert!(findings.is_empty(), "{findings:?}");

    let stale = "\
pub fn fine() {
    // lint: allow(no-stray-print) nothing here needs it
    let _ = 1;
}
";
    let findings = lint_set(&fixture_set("src/engine/esc.rs", stale));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "stale-allow");
    assert_eq!(findings[0].line, 2);
}

/// The offline-build rule fires on an uncommented dependencies section.
#[test]
fn fixture_offline_build_violation() {
    let set = SourceSet {
        files: Vec::new(),
        operations_md: String::new(),
        cargo_toml: "[package]\nname = \"x\"\n\n[dependencies]\nserde = \"1\"\n".to_string(),
    };
    let findings = lint_set(&set);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "offline-build");
    assert_eq!(findings[0].file, "Cargo.toml");
    assert_eq!(findings[0].line, 4);
}
