//! Integration: the engine-backed sharded pipeline (§6) agrees with the
//! sequential decider on exact-duplicate corpora, and the bit-OR filter
//! union preserves membership across shards.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::Doc;
use lshbloom::engine::ConcurrentLshBloomIndex;
use lshbloom::index::lshbloom::LshBloomConfig;
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::minhash::{LshParams, PermFamily};
use lshbloom::pipeline::dedup_sharded;
use lshbloom::rng::Xoshiro256pp;
use std::collections::BTreeSet;

fn cfg() -> PipelineConfig {
    PipelineConfig { num_perms: 64, threshold: 0.5, expected_docs: 10_000, ..Default::default() }
}

/// Corpus where every duplicate is an *exact* copy of an earlier
/// document, at back-distances that land both in the same shard and in
/// different shards for the shard counts under test. Unique documents
/// use per-document token sets (pairwise Jaccard ~0.1, far below the
/// 0.5 threshold) so the only duplicate relation is exact equality —
/// the regime where sharded and sequential survivor sets must agree
/// strictly, not just within ordering drift.
fn exact_dup_corpus(n: usize) -> Vec<Doc> {
    let mut docs: Vec<Doc> = Vec::with_capacity(n);
    for i in 0..n as u64 {
        if i % 3 == 2 && i >= 17 {
            // Cycle copy distances: 2 and 5 are cross-shard for 8/16
            // shards (round-robin), 16 is same-shard for both.
            let dist = [2u64, 16, 5, 16][((i / 3) % 4) as usize];
            let src = docs[(i - dist) as usize].clone();
            docs.push(Doc { id: i, ..src });
        } else {
            docs.push(Doc {
                id: i,
                text: format!(
                    "unique document alpha{i} beta{i} gamma{i} delta{i} \
                     epsilon{i} zeta{i} eta{i} theta{i}"
                ),
            });
        }
    }
    docs
}

#[test]
fn sharded_equals_sequential_on_exact_duplicates_at_8_and_16_shards() {
    let docs = exact_dup_corpus(600);

    let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
    let seq_surviving_texts: BTreeSet<String> = docs
        .iter()
        .filter(|d| !seq.process(d))
        .map(|d| d.text.clone())
        .collect();
    let seq_survivors = seq_surviving_texts.len();

    for shards in [8usize, 16] {
        let stats = dedup_sharded(&cfg(), docs.clone(), shards);
        assert_eq!(
            stats.survivors.len(),
            seq_survivors,
            "shards={shards}: survivor count diverged from sequential"
        );
        // Exact duplicates are content-identical, so whichever copy a
        // shard keeps, the surviving *content set* must match exactly.
        let sharded_texts: BTreeSet<String> =
            stats.survivors.iter().map(|d| d.text.clone()).collect();
        assert_eq!(sharded_texts, seq_surviving_texts, "shards={shards}");
        // Counters and the stream-order verdict vector agree.
        assert_eq!(
            stats.phase1_dropped + stats.phase2_dropped + stats.survivors.len() as u64,
            600
        );
        assert_eq!(stats.verdicts.iter().filter(|&&v| !v).count(), stats.survivors.len());
        assert!(
            stats.phase2_dropped > 0,
            "shards={shards}: corpus was built to contain cross-shard duplicates"
        );
    }
}

fn index_config(expected_docs: u64) -> LshBloomConfig {
    LshBloomConfig::new(
        LshParams { num_bands: 8, rows_per_band: 8 },
        1e-8,
        expected_docs,
    )
}

#[test]
fn post_merge_union_has_no_false_negatives_across_shards() {
    // Eight independently filled shard indexes, folded into one
    // aggregate by bit-OR: every band vector inserted into ANY shard
    // must be reported present by the union (the merge must never clear
    // or miss a bit).
    let config = index_config(50_000);
    let agg = ConcurrentLshBloomIndex::new(config);
    let mut rng = Xoshiro256pp::seeded(61);
    let mut all_docs: Vec<Vec<u64>> = Vec::new();
    for _ in 0..8 {
        let shard = ConcurrentLshBloomIndex::new(config);
        for _ in 0..1_000 {
            let bands: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
            shard.insert_if_new_shared(&bands);
            all_docs.push(bands);
        }
        agg.union_from(&shard);
    }
    assert_eq!(agg.len(), 8_000);
    for (i, bands) in all_docs.iter().enumerate() {
        assert!(agg.query(bands), "doc {i} lost across the shard merge");
    }
}

#[test]
#[should_panic(expected = "geometry mismatch")]
fn union_from_panics_on_geometry_mismatch() {
    // Same band count but different planned capacity -> different
    // per-filter bit-array length; merging would scramble probes.
    let a = ConcurrentLshBloomIndex::new(index_config(1_000));
    let b = ConcurrentLshBloomIndex::new(index_config(500_000));
    a.union_from(&b);
}
