//! Integration: the concurrent engine produces the same survivor set as
//! the sequential `LshBloomDecider`, and the atomic Bloom filter keeps
//! the no-false-negative invariant under heavy thread contention.

use lshbloom::config::PipelineConfig;
use lshbloom::corpus::{DatasetSpec, Doc, LabeledCorpus};
use lshbloom::engine::{AtomicBloomFilter, ConcurrentEngine};
use lshbloom::methods::lshbloom::lshbloom_method;
use lshbloom::minhash::PermFamily;
use lshbloom::pipeline::{run_stream_engine, PipelineOptions};

fn cfg(expected_docs: u64) -> PipelineConfig {
    PipelineConfig {
        num_perms: 128,
        threshold: 0.5,
        expected_docs,
        workers: 4,
        ..Default::default()
    }
}

/// Ids of documents the method would keep (verdict = not duplicate).
fn survivors(docs: &[lshbloom::corpus::LabeledDoc], verdicts: &[bool]) -> Vec<u64> {
    docs.iter()
        .zip(verdicts)
        .filter(|(_, &dup)| !dup)
        .map(|(ld, _)| ld.doc.id)
        .collect()
}

#[test]
fn concurrent_engine_survivor_set_equals_sequential_decider() {
    // Labeled generated corpus (reuses corpus::generator under
    // DatasetSpec): half the stream is parser-noise/truncation twins.
    let corpus = LabeledCorpus::build(DatasetSpec::testing(29, 600, 0.5));
    let config = cfg(10_000);

    let mut sequential = lshbloom_method(&config, PermFamily::Mix64);
    let expected = sequential.process_all(&corpus.docs);

    // Several batch shapes, including batches much larger than the
    // worker pool and single-doc batches.
    for batch_size in [1usize, 13, 128, 600] {
        let engine = ConcurrentEngine::from_config(&config);
        let mut verdicts = Vec::with_capacity(corpus.docs.len());
        for chunk in corpus.docs.chunks(batch_size) {
            let batch: Vec<Doc> = chunk.iter().map(|ld| ld.doc.clone()).collect();
            let decisions = engine.submit(batch);
            verdicts.extend(decisions.into_iter().map(|d| d.duplicate));
        }
        assert_eq!(
            survivors(&corpus.docs, &verdicts),
            survivors(&corpus.docs, &expected),
            "survivor set diverged at batch_size={batch_size}"
        );
        // Stronger than the survivor set: the full verdict vector.
        assert_eq!(verdicts, expected, "verdicts diverged at batch_size={batch_size}");
    }
}

#[test]
fn engine_pipeline_mode_equals_sequential_decider() {
    let corpus = LabeledCorpus::build(DatasetSpec::testing(31, 400, 0.4));
    let config = cfg(10_000);

    let mut sequential = lshbloom_method(&config, PermFamily::Mix64);
    let expected = sequential.process_all(&corpus.docs);

    let engine = ConcurrentEngine::from_config(&config);
    let stats = run_stream_engine(
        &engine,
        corpus.docs.iter().map(|ld| ld.doc.clone()),
        PipelineOptions { workers: 4, batch_size: 32, channel_depth: 4 },
    );
    assert_eq!(stats.verdicts, expected);
    assert_eq!(stats.docs, 400);
    assert_eq!(
        stats.duplicates,
        expected.iter().filter(|&&v| v).count() as u64
    );
}

#[test]
fn atomic_filter_no_false_negatives_under_contention() {
    // 8 threads insert the SAME key set concurrently (maximum word-level
    // contention: every fetch_or races 7 peers on identical positions).
    // Afterwards every key must be present.
    let keys: Vec<u64> = (0..20_000u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
    let filter = AtomicBloomFilter::with_capacity(keys.len() as u64, 1e-6);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let (filter, keys) = (&filter, &keys);
            s.spawn(move || {
                for &k in keys {
                    filter.insert(k);
                }
            });
        }
    });
    for &k in &keys {
        assert!(filter.contains(k), "false negative for {k} after contended inserts");
    }
    assert_eq!(filter.inserted(), 8 * keys.len() as u64);
}

#[test]
fn concurrent_submitters_lose_no_documents() {
    // Four threads push disjoint batches into one shared engine. The
    // linearizability caveat allows cross-thread twins to both survive,
    // but every inserted document must be queryable afterwards (no false
    // negatives at the engine level either).
    let config = cfg(50_000);
    let engine = ConcurrentEngine::from_config(&config);
    let corpus = LabeledCorpus::build(DatasetSpec::testing(37, 800, 0.0));
    std::thread::scope(|s| {
        for t in 0..4usize {
            let (engine, docs) = (&engine, &corpus.docs);
            s.spawn(move || {
                let slice: Vec<Doc> =
                    docs[t * 200..(t + 1) * 200].iter().map(|ld| ld.doc.clone()).collect();
                engine.submit(slice);
            });
        }
    });
    let (docs, _) = engine.stats();
    assert_eq!(docs, 800);
    for ld in &corpus.docs {
        assert!(
            engine.query_one(&ld.doc),
            "doc {} lost after concurrent submits",
            ld.doc.id
        );
    }
}

#[test]
#[cfg_attr(miri, ignore)] // binds a real TCP listener
fn concurrent_server_mode_serves_and_reconciles_across_connections() {
    use lshbloom::config::EngineMode;
    use lshbloom::service::{DedupClient, DedupServer};

    let config = PipelineConfig {
        num_perms: 64,
        expected_docs: 10_000,
        engine: EngineMode::Concurrent,
        ..Default::default()
    };
    let server = DedupServer::bind("127.0.0.1:0", &config).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve().expect("serve"));

    let mut a = DedupClient::connect(&addr).unwrap();
    let mut b = DedupClient::connect(&addr).unwrap();
    assert!(!a.check("engine mode shared document state").unwrap());
    // Sequential across connections -> the twin is always caught.
    assert!(b.check("engine mode shared document state").unwrap());
    assert!(!b.query("but unseen text stays unseen").unwrap());

    // Stats are served lock-free; disk footprint is the static filter size.
    let (docs, dups, disk) = a.stats().unwrap();
    assert_eq!((docs, dups), (2, 1));
    assert!(disk > 0);

    a.shutdown().unwrap();
    handle.join().unwrap();
}
