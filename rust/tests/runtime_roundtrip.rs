//! Integration: the AOT bridge end-to-end.
//!
//! Loads HLO-text artifacts produced by `python -m compile.aot`, executes
//! them on the PJRT CPU client, and checks the numerics against values
//! computed directly in the test (band hashes) and against the golden
//! vectors (signatures; see `xla_backend.rs` for the full cross-check).
//!
//! Requires `make artifacts` to have run; tests are skipped (pass
//! trivially with a note) when the artifacts directory is missing so that
//! plain `cargo test` works from a fresh checkout.

// Miri cannot emulate this (loads XLA artifacts through PJRT FFI); the miri CI job
// covers the pure-logic suites instead.
#![cfg(not(miri))]

use lshbloom::runtime::PjrtEngine;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("artifacts/ missing; run `make artifacts` first — skipping");
        None
    }
}

#[test]
fn band_hash_artifact_executes_and_matches_wrapping_sums() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::cpu().expect("pjrt cpu client");
    let exe = engine
        .load_hlo_text(dir.join("band_hashes_B8_P128_T0.5.hlo.txt"))
        .expect("compile band_hashes artifact");

    // sigs[d][p] = d * 1e18 + p (exercises u64 range + wrapping).
    let (b, p) = (8usize, 128usize);
    let mut sigs = vec![0u64; b * p];
    for d in 0..b {
        for j in 0..p {
            sigs[d * p + j] = (d as u64).wrapping_mul(1_000_000_000_000_000_000) + j as u64;
        }
    }
    let lit = xla::Literal::vec1(&sigs).reshape(&[b as i64, p as i64]).unwrap();
    let out = exe.execute(&[lit]).expect("execute");
    assert_eq!(out.len(), 1);
    let vals = out[0].to_vec::<u64>().unwrap();

    // test config: T=0.5, P=128 -> (num_bands, rows_per_band) from manifest.
    let (num_bands, rows) = (25usize, 5usize);
    assert_eq!(vals.len(), b * num_bands);
    for d in 0..b {
        for band in 0..num_bands {
            let mut expect = 0u64;
            for i in 0..rows {
                expect = expect.wrapping_add(sigs[d * p + band * rows + i]);
            }
            assert_eq!(vals[d * num_bands + band], expect, "doc {d} band {band}");
        }
    }
}

#[test]
fn minhash_sigs_artifact_full_padding_yields_u64_max() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::cpu().expect("pjrt cpu client");
    let exe = engine
        .load_hlo_text(dir.join("minhash_sigs_B8_L128_P128.hlo.txt"))
        .expect("compile minhash_sigs artifact");

    // All rows fully padded -> every signature must be u64::MAX.
    let toks = vec![u64::MAX; 8 * 128];
    let seeds: Vec<u64> = (0..128u64).map(|i| i.wrapping_mul(0x9E3779B97F4A7C15)).collect();
    let toks = xla::Literal::vec1(&toks).reshape(&[8, 128]).unwrap();
    let seeds = xla::Literal::vec1(&seeds).reshape(&[128]).unwrap();
    let out = exe.execute(&[toks, seeds]).expect("execute");
    let vals = out[0].to_vec::<u64>().unwrap();
    assert_eq!(vals.len(), 8 * 128);
    assert!(vals.iter().all(|&v| v == u64::MAX));
}
