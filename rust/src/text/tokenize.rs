//! Tokenizers.
//!
//! Two flavors, matching the paper's discussion of why DCLM beats
//! Dolma-Ngram (§5.2.2):
//!
//! * [`whitespace_tokens`] — naive whitespace split (Dolma-Ngram).
//! * [`uniseg_words`] — Unicode-category word segmentation, a practical
//!   subset of UAX-29 (DCLM's UniSeg tokenizer): alphanumeric runs are
//!   words, digits group with digits, everything else separates.
//!
//! Tokenizers return byte ranges into the input so callers can hash
//! without allocating per-token `String`s (the MinHash hot path).

/// Iterator over whitespace-separated tokens as `&str` slices.
pub fn whitespace_tokens(text: &str) -> impl Iterator<Item = &str> {
    text.split_whitespace()
}

/// Word classes for the UAX-29-flavored segmenter.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Class {
    Letter,
    Digit,
    Other,
    Space,
}

fn classify(c: char) -> Class {
    if c.is_whitespace() {
        Class::Space
    } else if c.is_alphabetic() || c == '\'' || c == '\u{2019}' {
        // Apostrophes join letter runs ("don't") per UAX-29 MidLetter.
        Class::Letter
    } else if c.is_ascii_digit() || c.is_numeric() {
        Class::Digit
    } else {
        Class::Other
    }
}

/// Unicode-category word segmentation (UniSeg/UAX-29-flavored subset).
///
/// Emits maximal runs of letters (with embedded apostrophes) and maximal
/// runs of digits; each other non-space character is its own token
/// (punctuation is meaningful for n-gram overlap of parsed PDFs).
pub fn uniseg_words(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start: Option<(usize, Class)> = None;
    for (i, c) in text.char_indices() {
        let class = classify(c);
        match (start, class) {
            (None, Class::Space) => {}
            (None, Class::Other) => out.push(&text[i..i + c.len_utf8()]),
            (None, cl) => start = Some((i, cl)),
            (Some((s, run)), cl) => {
                if cl == run && cl != Class::Other {
                    // continue the run
                } else {
                    out.push(&text[s..i]);
                    start = None;
                    match cl {
                        Class::Space => {}
                        Class::Other => out.push(&text[i..i + c.len_utf8()]),
                        _ => start = Some((i, cl)),
                    }
                }
            }
        }
    }
    if let Some((s, _)) = start {
        out.push(&text[s..]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whitespace_basic() {
        let toks: Vec<&str> = whitespace_tokens("a  b\tc\nd").collect();
        assert_eq!(toks, vec!["a", "b", "c", "d"]);
    }

    #[test]
    fn whitespace_keeps_punctuation_attached() {
        let toks: Vec<&str> = whitespace_tokens("end. next,word").collect();
        assert_eq!(toks, vec!["end.", "next,word"]);
    }

    #[test]
    fn uniseg_splits_punctuation() {
        assert_eq!(uniseg_words("end. next,word"), vec!["end", ".", "next", ",", "word"]);
    }

    #[test]
    fn uniseg_groups_digits() {
        assert_eq!(uniseg_words("pi=3.14159"), vec!["pi", "=", "3", ".", "14159"]);
    }

    #[test]
    fn uniseg_keeps_apostrophe_words() {
        assert_eq!(uniseg_words("don't stop"), vec!["don't", "stop"]);
    }

    #[test]
    fn uniseg_handles_unicode() {
        assert_eq!(uniseg_words("naïve café 42"), vec!["naïve", "café", "42"]);
    }

    #[test]
    fn uniseg_empty_and_spaces() {
        assert!(uniseg_words("").is_empty());
        assert!(uniseg_words("   \n\t ").is_empty());
    }

    #[test]
    fn uniseg_vs_whitespace_difference_matters() {
        // The paper's explanation for DCLM > Dolma-Ngram: punctuation
        // variants don't perturb uniseg n-grams as much.
        let a = uniseg_words("result (p<0.05) shown");
        let b = uniseg_words("result (p < 0.05) shown");
        assert_eq!(a, b, "uniseg is robust to spacing around punctuation");
        let wa: Vec<&str> = whitespace_tokens("result (p<0.05) shown").collect();
        let wb: Vec<&str> = whitespace_tokens("result (p < 0.05) shown").collect();
        assert_ne!(wa, wb, "whitespace split is not");
    }
}
