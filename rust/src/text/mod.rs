//! Text processing substrate: normalization, tokenization, shingling,
//! paragraph splitting.
//!
//! Every dedup method consumes documents through this module so that the
//! methods differ only in *algorithm*, not in text plumbing — mirroring
//! the paper's methodology of normalizing all implementations (§5.1.2).

pub mod ngram;
pub mod normalize;
pub mod paragraph;
pub mod tokenize;

pub use ngram::{char_ngrams, word_ngrams};
pub use normalize::normalize;
pub use paragraph::paragraphs;
pub use tokenize::{uniseg_words, whitespace_tokens};
