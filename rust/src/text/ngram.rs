//! N-gram shingling.
//!
//! MinHash methods view a document as the *set* of its word n-grams
//! (shingles); n-gram Bloom methods (Dolma-Ngram, DCLM) stream the
//! multiset. Shingles are produced as joined strings ("w1 w2 ... wn") and
//! typically consumed through a hash, so the joining buffer is reused.

/// Produce word n-grams from a token list, invoking `f` with each shingle.
///
/// For `tokens.len() < n` a single shingle containing all tokens is
/// emitted (a short document is still a non-empty set — matching the
/// Dolma/DCLM behaviour of not dropping short paragraphs).
pub fn word_ngrams<'a, F: FnMut(&str)>(tokens: &[&'a str], n: usize, mut f: F) {
    assert!(n > 0, "n-gram size must be positive");
    if tokens.is_empty() {
        return;
    }
    let mut buf = String::new();
    if tokens.len() < n {
        buf.push_str(tokens[0]);
        for t in &tokens[1..] {
            buf.push(' ');
            buf.push_str(t);
        }
        f(&buf);
        return;
    }
    for start in 0..=(tokens.len() - n) {
        buf.clear();
        buf.push_str(tokens[start]);
        for t in &tokens[start + 1..start + n] {
            buf.push(' ');
            buf.push_str(t);
        }
        f(&buf);
    }
}

/// Collect word n-grams into a Vec (test/analysis convenience).
pub fn word_ngrams_vec(tokens: &[&str], n: usize) -> Vec<String> {
    let mut out = Vec::new();
    word_ngrams(tokens, n, |s| out.push(s.to_string()));
    out
}

/// Character n-grams over a string (used by noise-robustness analyses).
pub fn char_ngrams<F: FnMut(&str)>(text: &str, n: usize, mut f: F) {
    assert!(n > 0);
    let idx: Vec<usize> = text
        .char_indices()
        .map(|(i, _)| i)
        .chain(std::iter::once(text.len()))
        .collect();
    if idx.len() <= 1 {
        return;
    }
    let chars = idx.len() - 1;
    if chars < n {
        f(text);
        return;
    }
    for s in 0..=(chars - n) {
        f(&text[idx[s]..idx[s + n]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_word_ngrams() {
        assert_eq!(
            word_ngrams_vec(&["a", "b", "c", "d"], 2),
            vec!["a b", "b c", "c d"]
        );
    }

    #[test]
    fn unigrams_are_tokens() {
        assert_eq!(word_ngrams_vec(&["x", "y"], 1), vec!["x", "y"]);
    }

    #[test]
    fn short_doc_emits_single_shingle() {
        assert_eq!(word_ngrams_vec(&["a", "b"], 5), vec!["a b"]);
        assert!(word_ngrams_vec(&[], 3).is_empty());
    }

    #[test]
    fn count_is_len_minus_n_plus_1() {
        let toks: Vec<&str> = vec!["t"; 100];
        for n in [1usize, 2, 5, 7, 13, 26] {
            assert_eq!(word_ngrams_vec(&toks, n).len(), 100 - n + 1, "n={n}");
        }
    }

    #[test]
    fn char_ngrams_unicode_safe() {
        let mut grams = Vec::new();
        char_ngrams("añb", 2, |g| grams.push(g.to_string()));
        assert_eq!(grams, vec!["añ", "ñb"]);
    }

    #[test]
    fn char_ngrams_short_input() {
        let mut grams = Vec::new();
        char_ngrams("ab", 5, |g| grams.push(g.to_string()));
        assert_eq!(grams, vec!["ab"]);
        grams.clear();
        char_ngrams("", 2, |g| grams.push(g.to_string()));
        assert!(grams.is_empty());
    }
}
