//! CCNet-style text normalization (§3.3).
//!
//! Lowercases, strips accents/special unicode down to a canonical form,
//! and collapses whitespace. CCNet applies this before hashing paragraph
//! units; the LSH methods use it before shingling so that trivially
//! different byte encodings of the same text compare equal.

/// Normalize a document: lowercase, map typographic punctuation to ASCII,
/// drop non-printing/format characters, collapse runs of whitespace.
pub fn normalize(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut last_space = true; // also trims leading whitespace
    let mut rest = text;
    // §Perf: bulk ASCII fast path — printable non-space ASCII that is
    // already lowercase copies byte-wise; only the first "interesting"
    // byte falls through to the general char loop below.
    loop {
        let stop = rest
            .as_bytes()
            .iter()
            .position(|&b| !(b'!'..=b'~').contains(&b) || b.is_ascii_uppercase());
        match stop {
            None => {
                out.push_str(rest);
                return finish(out);
            }
            Some(n) => {
                if n > 0 {
                    out.push_str(&rest[..n]);
                    last_space = false;
                }
                // Handle one general char, then resume the fast scan.
                let ch = rest[n..].chars().next().unwrap();
                push_mapped(ch, &mut out, &mut last_space);
                rest = &rest[n + ch.len_utf8()..];
            }
        }
    }
}

fn finish(mut out: String) -> String {
    while out.ends_with(' ') {
        out.pop();
    }
    out
}

#[inline]
fn push_mapped(ch: char, out: &mut String, last_space: &mut bool) {
    match map_char(ch) {
        MappedChar::Drop => {}
        MappedChar::Space => {
            if !*last_space {
                out.push(' ');
                *last_space = true;
            }
        }
        MappedChar::Keep(c) => {
            for lc in c.to_lowercase() {
                out.push(lc);
            }
            *last_space = false;
        }
        MappedChar::Str(s) => {
            out.push_str(s);
            *last_space = false;
        }
    }
}

/// Reference (char-at-a-time) implementation kept for differential tests.
#[doc(hidden)]
pub fn normalize_reference(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut last_space = true; // also trims leading whitespace
    for ch in text.chars() {
        let mapped = map_char(ch);
        match mapped {
            MappedChar::Drop => {}
            MappedChar::Space => {
                if !last_space {
                    out.push(' ');
                    last_space = true;
                }
            }
            MappedChar::Keep(c) => {
                for lc in c.to_lowercase() {
                    out.push(lc);
                }
                last_space = false;
            }
            MappedChar::Str(s) => {
                out.push_str(s);
                last_space = false;
            }
        }
    }
    while out.ends_with(' ') {
        out.pop();
    }
    out
}

enum MappedChar {
    Keep(char),
    Str(&'static str),
    Space,
    Drop,
}

fn map_char(ch: char) -> MappedChar {
    match ch {
        // Whitespace classes (incl. NBSP and ideographic space).
        c if c.is_whitespace() => MappedChar::Space,
        // Typographic quotes/dashes → ASCII (common PDF-parser artifacts).
        '\u{2018}' | '\u{2019}' | '\u{201A}' | '\u{2032}' => MappedChar::Keep('\''),
        '\u{201C}' | '\u{201D}' | '\u{201E}' | '\u{2033}' => MappedChar::Keep('"'),
        '\u{2010}' | '\u{2011}' | '\u{2012}' | '\u{2013}' | '\u{2014}' | '\u{2212}' => {
            MappedChar::Keep('-')
        }
        '\u{2026}' => MappedChar::Str("..."),
        // Ligatures OCR tools emit.
        '\u{FB00}' => MappedChar::Str("ff"),
        '\u{FB01}' => MappedChar::Str("fi"),
        '\u{FB02}' => MappedChar::Str("fl"),
        '\u{FB03}' => MappedChar::Str("ffi"),
        '\u{FB04}' => MappedChar::Str("ffl"),
        // Zero-width/format/control characters: drop.
        c if c.is_control() => MappedChar::Drop,
        '\u{200B}'..='\u{200F}' | '\u{FEFF}' | '\u{00AD}' => MappedChar::Drop,
        c => MappedChar::Keep(c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowercases_and_collapses_whitespace() {
        assert_eq!(normalize("Hello   World\n\nFoo\tBar"), "hello world foo bar");
    }

    #[test]
    fn trims_edges() {
        assert_eq!(normalize("  x  "), "x");
        assert_eq!(normalize(""), "");
        assert_eq!(normalize(" \n\t "), "");
    }

    #[test]
    fn maps_typographic_characters() {
        assert_eq!(normalize("\u{201C}quoted\u{201D}"), "\"quoted\"");
        assert_eq!(normalize("em\u{2014}dash"), "em-dash");
        assert_eq!(normalize("e\u{FB03}cient"), "efficient");
    }

    #[test]
    fn drops_zero_width_and_controls() {
        assert_eq!(normalize("a\u{200B}b\u{00AD}c"), "abc");
        assert_eq!(normalize("a\u{0007}b"), "ab");
    }

    #[test]
    fn normalization_makes_parser_variants_equal() {
        // Two "parses" of the same sentence with different artifacts.
        let html = "The efficient \u{201C}method\u{201D} works";
        let pdf = "the e\u{FB03}cient \"method\"  works\n";
        assert_eq!(normalize(html), normalize(pdf));
    }

    #[test]
    fn idempotent() {
        let s = "Mixed \u{2018}Case\u{2019}\u{2026} with \u{FB01}xes";
        assert_eq!(normalize(&normalize(s)), normalize(s));
    }

    #[test]
    fn fast_path_matches_reference() {
        let cases = [
            "",
            "plain ascii text here",
            "  leading and trailing  ",
            "MIXED Case With\tTabs\nAnd\u{2014}Dashes",
            "e\u{FB03}cient \u{201C}quotes\u{201D} caf\u{00E9} \u{200B}zero",
            "all!printable@ascii#chars$%^&*()",
            "\u{0007}control\u{0007}",
            "ends with unicode \u{2026}",
        ];
        for c in cases {
            assert_eq!(normalize(c), normalize_reference(c), "case: {c:?}");
        }
    }

    #[test]
    fn fast_path_matches_reference_on_generated_docs() {
        let g = crate::corpus::CorpusGenerator::new(Default::default());
        for i in 0..10 {
            let d = g.generate(99, i);
            assert_eq!(normalize(&d.text), normalize_reference(&d.text), "doc {i}");
        }
    }
}
