//! Paragraph splitting (CCNet / Dolma / DCLM unit of deduplication).
//!
//! CCNet splits documents on newline characters (§3.3); Dolma and DCLM do
//! the same. Empty/whitespace-only units are skipped.

/// Split a document into paragraph slices on newlines, skipping blanks.
pub fn paragraphs(text: &str) -> Vec<&str> {
    text.split('\n')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_on_newlines() {
        assert_eq!(paragraphs("a\nb\nc"), vec!["a", "b", "c"]);
    }

    #[test]
    fn skips_blank_lines_and_trims() {
        assert_eq!(paragraphs("a\n\n  \n  b  \n"), vec!["a", "b"]);
    }

    #[test]
    fn empty_document() {
        assert!(paragraphs("").is_empty());
        assert!(paragraphs("\n\n\n").is_empty());
    }

    #[test]
    fn single_paragraph() {
        assert_eq!(paragraphs("only one"), vec!["only one"]);
    }
}
