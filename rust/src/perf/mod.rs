//! Performance + testing harnesses (criterion/proptest are unavailable
//! offline, so the repo carries its own).
//!
//! * [`bench`] — micro/macro benchmark runner: warmup, adaptive iteration
//!   count, median/p10/p90 reporting, throughput units.
//! * [`prop`] — property-testing mini-framework: seeded generators, many
//!   cases per property, failing-seed reporting.

pub mod bench;
pub mod prop;

pub use bench::{bench, bench_n, BenchResult, Bencher};
