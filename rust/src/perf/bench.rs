//! Micro-benchmark harness.
//!
//! Usage from a `harness = false` bench binary:
//! ```no_run
//! use lshbloom::perf::bench::Bencher;
//! let mut b = Bencher::default();
//! let r = b.run("band_hash/u128", || {
//!     // work under measurement; return a value to defeat DCE
//!     42u64
//! });
//! println!("{}", r.report());
//! ```

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median wall time per iteration.
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    /// Iterations measured.
    pub iters: u64,
    /// Optional element count per iteration for throughput reporting.
    pub elems_per_iter: Option<u64>,
}

impl BenchResult {
    /// Human-readable single-line report.
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12} median   [{} .. {}]  ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p90),
            self.iters
        );
        if let Some(n) = self.elems_per_iter {
            let per_sec = n as f64 / self.median.as_secs_f64();
            s.push_str(&format!("  {:>12}/s", fmt_count(per_sec)));
        }
        s
    }

    /// Median nanoseconds (for machine-readable output).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Format a duration with a sensible unit.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Format a count with SI suffix.
pub fn fmt_count(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2} G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2} M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2} k", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

/// Benchmark runner with warmup + adaptive iteration.
pub struct Bencher {
    /// Minimum total measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case.
    pub warmup_time: Duration,
    /// Number of samples the measurement is split into.
    pub samples: usize,
    /// Elements processed per iteration (for throughput lines).
    pub elems_per_iter: Option<u64>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Honor LSHBLOOM_BENCH_FAST=1 for CI smoke runs.
        let fast = std::env::var("LSHBLOOM_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Self {
            measure_time: if fast { Duration::from_millis(80) } else { Duration::from_millis(600) },
            warmup_time: if fast { Duration::from_millis(20) } else { Duration::from_millis(150) },
            samples: 30,
            elems_per_iter: None,
        }
    }
}

impl Bencher {
    /// Set elements/iteration for throughput reporting (builder style).
    pub fn throughput(mut self, elems: u64) -> Self {
        self.elems_per_iter = Some(elems);
        self
    }

    /// Run one case: `f` is invoked repeatedly; its return value is
    /// black-boxed to defeat dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup and iteration-count calibration.
        let warm_start = Instant::now();
        let mut calib_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_time || calib_iters == 0 {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup_time.as_secs_f64() / calib_iters as f64;
        let per_sample = (self.measure_time.as_secs_f64() / self.samples as f64).max(per_iter);
        let iters_per_sample = (per_sample / per_iter).ceil().max(1.0) as u64;

        let mut sample_times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            sample_times.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        sample_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |frac: f64| {
            let idx = ((sample_times.len() - 1) as f64 * frac).round() as usize;
            Duration::from_secs_f64(sample_times[idx])
        };
        BenchResult {
            name: name.to_string(),
            median: q(0.5),
            p10: q(0.1),
            p90: q(0.9),
            iters: iters_per_sample * self.samples as u64,
            elems_per_iter: self.elems_per_iter,
        }
    }
}

/// One-shot convenience: default bencher, print + return the result.
pub fn bench<T, F: FnMut() -> T>(name: &str, f: F) -> BenchResult {
    let r = Bencher::default().run(name, f);
    // Bench harness output is the product, not stray debugging.
    println!("{}", r.report()); // lint: allow(no-stray-print)
    r
}

/// One-shot with throughput units.
pub fn bench_n<T, F: FnMut() -> T>(name: &str, elems: u64, f: F) -> BenchResult {
    let r = Bencher::default().throughput(elems).run(name, f);
    println!("{}", r.report()); // lint: allow(no-stray-print)
    r
}

/// Time a single closure invocation (macro-benchmarks where one run is
/// seconds long; no warmup).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("LSHBLOOM_BENCH_FAST", "1");
        let mut b = Bencher::default();
        b.measure_time = Duration::from_millis(30);
        b.warmup_time = Duration::from_millis(5);
        let r = b.run("noop-ish", || {
            let mut x = 0u64;
            for i in 0..100u64 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.p10 <= r.median && r.median <= r.p90);
        assert!(r.iters > 0);
    }

    #[test]
    fn formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
        assert_eq!(fmt_count(1_500_000.0), "1.50 M");
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 7);
        assert_eq!(v, 7);
        assert!(d.as_nanos() > 0);
    }
}
