//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (seeded value source). The
//! runner executes it for many derived seeds; on panic it reports the
//! failing case index and seed so the case can be replayed with
//! `Gen::from_seed`. No shrinking — generators are kept small-biased
//! instead, which keeps failures readable in practice.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath; the same flow is
//! // covered by this module's unit tests)
//! use lshbloom::perf::prop::{check, Gen};
//! check("addition commutes", 200, |g: &mut Gen| {
//!     let (a, b) = (g.u64(), g.u64());
//!     assert_eq!(a.wrapping_add(b), b.wrapping_add(a));
//! });
//! ```

use crate::rng::Xoshiro256pp;

/// Seeded value generator handed to properties.
pub struct Gen {
    rng: Xoshiro256pp,
    seed: u64,
}

impl Gen {
    /// Rebuild the generator for a reported failing seed.
    pub fn from_seed(seed: u64) -> Self {
        Self { rng: Xoshiro256pp::seeded(seed), seed }
    }

    /// The seed of this case (for failure messages).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    /// Size-biased small usize in `[lo, hi]`: half the mass near `lo`.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        if self.rng.chance(0.5) {
            lo + (self.rng.below(span.min(8).max(1))) as usize
        } else {
            lo + self.rng.below(span) as usize
        }
    }

    /// Uniform f64 in [0,1).
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec of u64 with size-biased length in `[0, max_len]`.
    pub fn vec_u64(&mut self, max_len: usize) -> Vec<u64> {
        let len = self.size(0, max_len);
        (0..len).map(|_| self.u64()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Random ASCII-ish word (lowercase letters), len in [1, max_len].
    pub fn word(&mut self, max_len: usize) -> String {
        let len = self.size(1, max_len.max(1));
        (0..len)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }

    /// Access the underlying RNG for custom sampling.
    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }
}

/// Run `cases` instances of the property. Panics (propagating the inner
/// assertion) with seed context on the first failure.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut property: F) {
    // Derive per-case seeds from the property name so distinct properties
    // explore distinct streams but remain reproducible run-to-run.
    let base = crate::hash::fast_str_hash(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(crate::rng::GOLDEN_GAMMA));
        let mut g = Gen::from_seed(seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(payload) = outcome {
            eprintln!(
                "property '{name}' failed at case {case}/{cases} (replay: Gen::from_seed({seed:#x}))"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count-cases", 50, |_g| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_panics_with_context() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 10, |g| {
                assert!(g.u64() == 0, "boom");
            });
        });
        assert!(result.is_err());
    }

    #[test]
    fn generators_are_reproducible() {
        let mut a = Gen::from_seed(99);
        let mut b = Gen::from_seed(99);
        assert_eq!(a.vec_u64(32), b.vec_u64(32));
        assert_eq!(a.word(10), b.word(10));
    }

    #[test]
    fn size_respects_bounds() {
        let mut g = Gen::from_seed(5);
        for _ in 0..1000 {
            let s = g.size(3, 17);
            assert!((3..=17).contains(&s));
        }
    }
}
