//! `lshbloom` — leader entrypoint for the deduplication system.
//!
//! Subcommands:
//!   gen-corpus    build a labeled synthetic corpus (JSONL)
//!   dedup         deduplicate a JSONL corpus with any technique
//!   worker        one distributed shard worker (spawned by `dedup --distributed`)
//!   tune          hyperparameter grids (Figs. 2–4, Table 1)
//!   fidelity      fidelity-vs-duplication study (Fig. 5)
//!   scale         resource scaling study (Figs. 1, 7)
//!   extrapolate   runtime/storage projection (Fig. 8, Table 2)
//!   serve         the TCP deduplication service (full, band-sharded, or slice)
//!   route         band-partition router over N backend dedup servers
//!   lint          run the in-repo soundness linter over the source tree
//!   info          environment + artifact status

use lshbloom::cli::{ArgSpec, Args, Command};
use lshbloom::config::{EngineMode, MinHashBackend, PipelineConfig};
use lshbloom::corpus::{DatasetSpec, LabeledCorpus};
use lshbloom::eval::experiments::{self, Scale};
use lshbloom::methods::{MethodKind, MethodSpec};
use lshbloom::pipeline::{run_stream, PipelineOptions};
use lshbloom::report::table::{bytes, f, Table};
use std::path::{Path, PathBuf};

fn main() {
    lshbloom::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((sub, rest)) = argv.split_first() else {
        print_usage();
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    let outcome = match sub.as_str() {
        "gen-corpus" => cmd_gen_corpus(rest),
        "dedup" => cmd_dedup(rest),
        "worker" => cmd_worker(rest),
        "tune" => cmd_tune(rest),
        "fidelity" => cmd_fidelity(rest),
        "scale" => cmd_scale(rest),
        "extrapolate" => cmd_extrapolate(rest),
        "serve" => cmd_serve(rest),
        "route" => cmd_route(rest),
        "lint" => cmd_lint(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = outcome {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "lshbloom — memory-efficient extreme-scale document deduplication\n\n\
         usage: lshbloom <subcommand> [flags]\n\n\
         subcommands:\n\
           gen-corpus    build a labeled synthetic corpus (JSONL)\n\
           dedup         deduplicate a JSONL corpus\n\
           worker        one distributed shard worker (spawned by dedup --distributed)\n\
           tune          hyperparameter grids (Figs. 2-4, Table 1)\n\
           fidelity      fidelity vs duplication rate (Fig. 5)\n\
           scale         resource scaling study (Figs. 1, 7)\n\
           extrapolate   projections at extreme scale (Fig. 8, Table 2)\n\
           serve         run the TCP deduplication service\n\
           route         band-partition router over N backend dedup servers\n\
           lint          run the in-repo soundness linter over the source tree\n\
           info          environment + artifact status\n\n\
         run `lshbloom <subcommand> --help` for flags"
    );
}

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn parse(cmd: Command, rest: Vec<String>) -> Result<Args, Box<dyn std::error::Error>> {
    cmd.parse_from(rest).map_err(|e| {
        // --help lands here with the rendered help text.
        Box::new(e) as Box<dyn std::error::Error>
    })
}

fn scale_from(args: &Args) -> Scale {
    if args.get_bool("quick") {
        Scale::quick()
    } else {
        Scale::from_env()
    }
}

fn cmd_gen_corpus(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("gen-corpus", "build a labeled synthetic corpus")
        .arg(ArgSpec::req("out", "output JSONL path"))
        .arg(ArgSpec::opt("docs", "number of documents").default("10000"))
        .arg(ArgSpec::opt("dup-rate", "duplication rate in [0,0.9]").default("0.5"))
        .arg(ArgSpec::opt("seed", "corpus seed").default("42"));
    let args = parse(cmd, rest)?;
    let spec = DatasetSpec::testing(args.get_u64("seed"), args.get_usize("docs"), args.get_f64("dup-rate"));
    let corpus = LabeledCorpus::build(spec);
    let path = PathBuf::from(args.get("out"));
    corpus.save_jsonl(&path)?;
    println!(
        "wrote {} docs ({} duplicates) to {}",
        corpus.docs.len(),
        corpus.num_duplicates(),
        path.display()
    );
    Ok(())
}

fn cmd_dedup(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("dedup", "deduplicate a JSONL corpus")
        .arg(ArgSpec::req("input", "input JSONL (from gen-corpus or external)"))
        .arg(ArgSpec::opt("method", "technique: lshbloom|minhashlsh|dolma|dolma-ngram|ccnet|dclm").default("lshbloom"))
        .arg(ArgSpec::opt("backend", "minhash backend: native|xla|datasketch").default("native"))
        .arg(ArgSpec::opt("threshold", "similarity/overlap threshold").default("0.5"))
        .arg(ArgSpec::opt("perms", "minhash permutations").default("256"))
        .arg(ArgSpec::opt("ngram", "shingle size").default("1"))
        .arg(ArgSpec::opt("p-effective", "index-wide FP bound").default("1e-10"))
        .arg(ArgSpec::opt("expected-docs", "planned corpus size (filter sizing; 0 = use input size)").default("0"))
        .arg(ArgSpec::opt(
            "expect-docs",
            "capacity-planner spelling of --expected-docs (key capacity.expect_docs); \
             wins over it when both are given",
        ).default("0"))
        .arg(ArgSpec::opt(
            "fp-budget",
            "capacity-planner spelling of --p-effective (key capacity.fp_budget); \
             wins over it when non-empty",
        ).default(""))
        .arg(ArgSpec::opt(
            "rotate-watermark",
            "sampled-fill fraction in [0,1) at which the concurrent engine freezes \
             the open filter generation and opens a fresh one (0 disables rotation; \
             key capacity.rotate_watermark)",
        ).default("0.5"))
        .arg(ArgSpec::opt("workers", "worker threads (0 = all cores)").default("0"))
        .arg(ArgSpec::opt("engine", "index engine: classic|concurrent (lock-free, lshbloom only)").default("classic"))
        .arg(ArgSpec::opt("shards", "shard count for §6 sharded aggregation (>1 runs per-shard concurrent engines + bit-OR filter merge; lshbloom/native only)").default("1"))
        .arg(ArgSpec::switch(
            "distributed",
            "run each shard as its own OS worker process, supervised with \
             restart-and-resume (requires --shards >= 2; --checkpoint-dir is the \
             worker state root, defaulting to a temp dir; --checkpoint-every sets \
             worker crash-recovery granularity)",
        ))
        .arg(ArgSpec::opt("artifacts", "AOT artifacts dir (xla backend)").default("artifacts"))
        .arg(ArgSpec::opt("out", "write surviving docs to this JSONL").default(""))
        .arg(ArgSpec::opt("save-index", "persist the LSHBloom index to this dir").default(""))
        .arg(ArgSpec::opt(
            "checkpoint-dir",
            "durable state dir (concurrent engine): mmap-backed filters + checkpoint \
             manifest; with --shards, each shard persists here for the on-disk phase-2 union",
        ).default(""))
        .arg(ArgSpec::opt(
            "checkpoint-every",
            "checkpoint every N documents (0 = only at end of stream)",
        ).default("0"))
        .arg(ArgSpec::switch(
            "resume",
            "restore from the checkpoint in --checkpoint-dir and skip the documents it covers",
        ))
        .arg(ArgSpec::opt(
            "metrics-out",
            "write periodic JSONL snapshots of the metrics registry (submit-phase \
             walls, checkpoint walls, fill gauges) to this file — one line per \
             second plus a final one, for offline perf trajectories",
        ).default(""))
        .arg(ArgSpec::switch("shm", "host bloom filters in /dev/shm (classic engine)"))
        .arg(ArgSpec::switch("report-fidelity", "score against duplicate_of labels if present"));
    let args = parse(cmd, rest)?;

    let docs = LabeledCorpus::load_jsonl(Path::new(args.get("input")))?;
    // Capacity-planner spellings win over the legacy flags when given:
    // --expect-docs over --expected-docs, --fp-budget over --p-effective.
    let expected = match (args.get_u64("expect-docs"), args.get_u64("expected-docs")) {
        (0, 0) => docs.len() as u64,
        (0, n) => n,
        (n, _) => n,
    };
    let p_effective = match args.get_opt("fp-budget").filter(|s| !s.is_empty()) {
        Some(p) => p.parse::<f64>().map_err(|_| format!("bad --fp-budget '{p}'"))?,
        None => args.get_f64("p-effective"),
    };
    let cfg = PipelineConfig {
        threshold: args.get_f64("threshold"),
        num_perms: args.get_usize("perms"),
        ngram: args.get_usize("ngram"),
        p_effective,
        expected_docs: expected,
        rotate_watermark: args.get_f64("rotate-watermark"),
        workers: args.get_usize("workers"),
        backend: MinHashBackend::parse(args.get("backend"))?,
        artifacts_dir: args.get("artifacts").to_string(),
        use_shm: args.get_bool("shm"),
        engine: EngineMode::parse(args.get("engine"))?,
        shards: args.get_usize("shards"),
        distributed: args.get_bool("distributed"),
        checkpoint_dir: args.get("checkpoint-dir").to_string(),
        checkpoint_every: args.get_u64("checkpoint-every"),
        ..Default::default()
    };
    cfg.validate()?;

    let kind = MethodKind::parse(args.get("method"))
        .ok_or_else(|| format!("unknown method '{}'", args.get("method")))?;

    // Echo the derived geometry so every run records what the planner
    // chose (only the lshbloom method consumes the plan).
    if kind == MethodKind::LshBloom {
        let plan = lshbloom::capacity::Plan::from_config(&cfg)?;
        println!("capacity plan: {}", plan.describe());
    }

    // `--metrics-out`: a ticker thread snapshots the registry once per
    // second while the run is in flight; the error paths below just let
    // the process exit (a partial JSONL is still a valid trajectory).
    let metrics_out = Some(args.get("metrics-out").to_string()).filter(|s| !s.is_empty());
    let metrics_stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let metrics_ticker = metrics_out.map(|path| {
        lshbloom::obs::init();
        let stop = std::sync::Arc::clone(&metrics_stop);
        std::thread::spawn(move || metrics_snapshot_loop(PathBuf::from(path), stop))
    });

    let checkpoint_dir = Some(&cfg.checkpoint_dir)
        .filter(|s| !s.is_empty())
        .map(PathBuf::from);
    let resume = args.get_bool("resume");
    if resume && checkpoint_dir.is_none() {
        return Err("--resume requires --checkpoint-dir".into());
    }
    if resume && cfg.shards > 1 && !cfg.distributed {
        return Err("--resume is not supported with in-process --shards (shard \
                    checkpoints are phase-2 aggregation state, not a stream position); \
                    distributed runs (--distributed) resume automatically"
            .into());
    }
    if resume && cfg.distributed {
        // Accepted as a no-op: the supervisor always spawns workers with
        // --resume, so incomplete slices continue from their snapshots.
        eprintln!(
            "note: distributed runs always resume incomplete workers from their \
             snapshots; --resume is implied"
        );
    }

    let needs_engine = cfg.shards > 1 || cfg.engine == EngineMode::Concurrent;
    if needs_engine {
        let what = if cfg.shards > 1 { "--shards > 1" } else { "--engine concurrent" };
        if kind != MethodKind::LshBloom {
            return Err(format!(
                "{what} supports only the lshbloom method (got '{}')",
                args.get("method")
            )
            .into());
        }
        if cfg.backend != MinHashBackend::Native {
            return Err(format!(
                "{what} supports only the native backend (got '{}')",
                args.get("backend")
            )
            .into());
        }
        if cfg.use_shm {
            return Err(format!(
                "{what} does not support --shm (file-backed atomic filters go through \
                 --checkpoint-dir instead)"
            )
            .into());
        }
    }

    // Documents skipped on --resume (already processed by the run that
    // wrote the checkpoint); verdicts cover only the remainder.
    let mut skipped = 0usize;
    let verdicts = if cfg.shards > 1 {
        // Sharded §6 path: per-shard concurrent engines, cross-shard
        // bit-OR filter aggregation. Composable with --engine concurrent
        // (shard ingest is always engine-backed). With --checkpoint-dir,
        // every shard persists its filled filter there and phase 2
        // aggregates straight from the files (the cross-process seam);
        // with --distributed, each shard is a supervised OS worker
        // process and phase 2 reads ONLY those files.
        let (stats, process_info) = if cfg.distributed {
            // The state root is the only supervisor<->worker channel, so
            // one always exists: --checkpoint-dir when given (durable,
            // reusable for `serve --state-dir` and incremental re-runs),
            // else a throwaway temp dir removed after success.
            let (state_root, temp_root) = match checkpoint_dir.as_deref() {
                Some(dir) => (dir.to_path_buf(), false),
                None => {
                    let dir = std::env::temp_dir()
                        .join(format!("lshbloom-distributed-{}", std::process::id()));
                    eprintln!(
                        "note: --distributed without --checkpoint-dir: worker state \
                         root defaulting to {} (removed after a successful run; pass \
                         --checkpoint-dir for durable, resumable state)",
                        dir.display()
                    );
                    (dir, true)
                }
            };
            let run = lshbloom::pipeline::run_distributed(
                &cfg,
                Path::new(args.get("input")),
                &docs,
                &state_root,
                &lshbloom::pipeline::SupervisorOptions::default(),
            )?;
            if temp_root {
                // Corpus-scale filter files are pure garbage once the
                // run succeeded; on failure the dir survives (with its
                // path printed above) for post-mortem or manual resume.
                std::fs::remove_dir_all(&state_root).ok();
            }
            (run.stats, Some((run.restarts, run.worker_threads)))
        } else {
            let stats = lshbloom::pipeline::dedup_sharded_with_state(
                &cfg,
                docs.iter().map(|ld| ld.doc.clone()).collect(),
                cfg.shards,
                checkpoint_dir.as_deref(),
            )?;
            (stats, None)
        };
        let mut t = Table::new("sharded dedup run", &["metric", "value"]);
        t.row_disp(&[
            "method".to_string(),
            if cfg.distributed { "lshbloom-distributed" } else { "lshbloom-sharded" }
                .to_string(),
        ]);
        t.row_disp(&["shards".to_string(), cfg.shards.to_string()]);
        if let Some((restarts, worker_threads)) = process_info {
            t.row_disp(&["worker processes".to_string(), cfg.shards.to_string()]);
            t.row_disp(&["threads per worker".to_string(), worker_threads.to_string()]);
            t.row_disp(&["worker restarts".to_string(), restarts.to_string()]);
        }
        t.row_disp(&["documents".to_string(), stats.docs.to_string()]);
        t.row_disp(&[
            "phase 1 dropped (within-shard)".to_string(),
            stats.phase1_dropped.to_string(),
        ]);
        t.row_disp(&[
            "phase 2 dropped (cross-shard)".to_string(),
            stats.phase2_dropped.to_string(),
        ]);
        t.row_disp(&["survivors".to_string(), stats.survivors.len().to_string()]);
        t.row_disp(&[
            "throughput (docs/s)".to_string(),
            format!("{:.0}", stats.throughput()),
        ]);
        t.row_disp(&[
            "phase 1 wall (shard dedup)".to_string(),
            format!("{:.2}s", stats.phase1_wall.as_secs_f64()),
        ]);
        t.row_disp(&[
            "phase 2 wall (bit-OR aggregation)".to_string(),
            format!("{:.2}s", stats.phase2_wall.as_secs_f64()),
        ]);
        t.row_disp(&["index disk".to_string(), bytes(stats.disk_bytes)]);
        t.print();
        stats.verdicts
    } else {
        let (method_name, stats) = if cfg.engine == EngineMode::Concurrent {
            let engine = match &checkpoint_dir {
                Some(dir) if resume => {
                    if !lshbloom::persist::CheckpointManifest::exists(dir) {
                        return Err(format!(
                            "--resume: no checkpoint manifest in {}",
                            dir.display()
                        )
                        .into());
                    }
                    // Re-attach the persisted filters in place; the
                    // manifest counters say how much of the stream the
                    // previous run already covered.
                    let engine = lshbloom::engine::ConcurrentEngine::restore(&cfg, dir, true)?;
                    skipped = engine.stats().0 as usize;
                    println!(
                        "resumed from {} ({} documents already processed; \
                         continuing from document {})",
                        dir.display(),
                        skipped,
                        skipped
                    );
                    engine
                }
                Some(dir) => lshbloom::engine::ConcurrentEngine::new_persistent(&cfg, dir)?,
                None => lshbloom::engine::ConcurrentEngine::from_config(&cfg),
            };
            let policy = checkpoint_dir.as_ref().map(|dir| lshbloom::pipeline::CheckpointPolicy {
                dir: dir.clone(),
                every_docs: cfg.checkpoint_every,
            });
            let stats = lshbloom::pipeline::run_stream_engine_checkpointed(
                &engine,
                docs.iter().skip(skipped).map(|ld| ld.doc.clone()),
                PipelineOptions::from_config(&cfg),
                policy.as_ref(),
            )?;
            ("lshbloom-concurrent".to_string(), stats)
        } else {
            // Unit-budget estimation sample for the Bloom-unit baselines;
            // only the classic path builds a `Method`, so only it pays
            // for the clones.
            let sample: Vec<lshbloom::corpus::Doc> =
                docs.iter().take(1000).map(|ld| ld.doc.clone()).collect();
            let mut method = build_method(&cfg, kind, &sample)?;
            let stats = run_stream(
                &mut method,
                docs.iter().map(|ld| ld.doc.clone()),
                PipelineOptions::from_config(&cfg),
            );
            (method.name.clone(), stats)
        };

        let mut t = Table::new("dedup run", &["metric", "value"]);
        t.row_disp(&["method".to_string(), method_name]);
        t.row_disp(&["documents".to_string(), stats.docs.to_string()]);
        t.row_disp(&["duplicates".to_string(), stats.duplicates.to_string()]);
        t.row_disp(&["throughput (docs/s)".to_string(), format!("{:.0}", stats.throughput())]);
        t.row_disp(&["wall".to_string(), format!("{:.2}s", stats.times.wall.as_secs_f64())]);
        t.row_disp(&[
            "minhash phase (est wall)".to_string(),
            format!("{:.2}s", stats.times.prepare_wall_est(stats.workers).as_secs_f64()),
        ]);
        t.row_disp(&["index phase".to_string(), format!("{:.2}s", stats.times.decide.as_secs_f64())]);
        t.row_disp(&["index disk".to_string(), bytes(stats.disk_bytes)]);
        t.print();
        stats.verdicts
    };

    if skipped > 0 {
        // Printed unconditionally: a resumed run's fidelity AND --out
        // survivors cover only the remainder, and the first run died
        // before writing anything — the operator must know this output
        // is partial.
        eprintln!(
            "note: --resume skipped {skipped} already-processed documents; fidelity \
             and survivor output cover only the resumed remainder"
        );
    }
    if args.get_bool("report-fidelity") {
        let labels: Vec<bool> =
            docs.iter().skip(skipped).map(|ld| ld.is_duplicate()).collect();
        let c = lshbloom::eval::Confusion::from_verdicts(&verdicts, &labels);
        let mut t = Table::new("fidelity", &["precision", "recall", "f1"]);
        t.row_disp(&[f(c.precision(), 4), f(c.recall(), 4), f(c.f1(), 4)]);
        t.print();
        if cfg.shards > 1 {
            // Shard-order aggregation may keep a *different copy* of a
            // duplicate pair than stream order does (the copy's shard can
            // aggregate before the original's), which the position-based
            // labels score as an FP+FN pair even though the surviving
            // content set matches the sequential run.
            eprintln!(
                "note: sharded runs score position labels pessimistically — a duplicate \
                 pair whose copy aggregates first counts as one FP plus one FN; treat \
                 these figures as a lower bound (survivor content is checked exactly by \
                 tests/shard_union.rs)"
            );
        }
    }

    if let Some(out) = args.get_opt("out").filter(|s| !s.is_empty()) {
        let survivors: Vec<&lshbloom::corpus::LabeledDoc> = docs
            .iter()
            .skip(skipped)
            .zip(&verdicts)
            .filter(|(_, &dup)| !dup)
            .map(|(d, _)| d)
            .collect();
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(out)?);
        for ld in &survivors {
            let line = lshbloom::json::obj(vec![
                ("id", lshbloom::json::Value::u64(ld.doc.id)),
                ("text", lshbloom::json::Value::str(ld.doc.text.clone())),
            ]);
            writeln!(w, "{}", line.to_json())?;
        }
        println!("wrote {} survivors to {out}", survivors.len());
    }

    if let Some(dir) = args.get_opt("save-index").filter(|s| !s.is_empty()) {
        save_index_note(Path::new(dir))?;
    }
    if let Some(handle) = metrics_ticker {
        metrics_stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = handle.join();
    }
    Ok(())
}

/// `dedup --metrics-out`: one JSONL registry snapshot per second, plus
/// a final one once the stop flag rises — offline runs get the same
/// telemetry a served fleet exposes over HTTP.
fn metrics_snapshot_loop(path: PathBuf, stop: std::sync::Arc<std::sync::atomic::AtomicBool>) {
    use std::io::Write;
    use std::sync::atomic::Ordering;
    let file = match std::fs::File::create(&path) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("--metrics-out {}: {e}", path.display());
            return;
        }
    };
    let mut w = std::io::BufWriter::new(file);
    let mut seq = 0u64;
    loop {
        let finished = stop.load(Ordering::SeqCst);
        let line = lshbloom::obs::global().snapshot_line(seq);
        if writeln!(w, "{line}").and_then(|_| w.flush()).is_err() {
            return;
        }
        seq += 1;
        if finished {
            return;
        }
        // 1 s cadence, polled in 50 ms steps so the final snapshot
        // lands promptly after the run finishes.
        for _ in 0..20 {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }
}

fn build_method(
    cfg: &PipelineConfig,
    kind: MethodKind,
    sample: &[lshbloom::corpus::Doc],
) -> Result<lshbloom::methods::Method, Box<dyn std::error::Error>> {
    use lshbloom::minhash::PermFamily;
    if kind == MethodKind::LshBloom && cfg.backend == MinHashBackend::Xla {
        return Ok(lshbloom::runtime::lshbloom_method_xla(cfg)?);
    }
    let family = match cfg.backend {
        MinHashBackend::Datasketch => PermFamily::Datasketch,
        _ => PermFamily::Mix64,
    };
    let spec = MethodSpec {
        kind,
        threshold: cfg.threshold,
        num_perms: cfg.num_perms,
        ngram: cfg.ngram,
        p_effective: cfg.p_effective,
        unit_fp: lshbloom::methods::UnitBudget::DEFAULT_FP,
        expected_docs: cfg.expected_docs,
        family,
    };
    Ok(spec.build(sample))
}

fn save_index_note(dir: &Path) -> CliResult {
    // Downcast-free: only the lshbloom methods expose a persistable index;
    // re-building a typed decider is not possible here, so persistence is
    // provided through the example/streaming path. Emit a hint instead.
    std::fs::create_dir_all(dir)?;
    eprintln!(
        "note: index persistence is exposed through the library API \
         (LshBloomIndex::save_dir) and the streaming_ingest example; \
         the CLI run completed without saving."
    );
    Ok(())
}

fn cmd_worker(rest: Vec<String>) -> CliResult {
    let cmd = Command::new(
        "worker",
        "one distributed shard worker (normally spawned by `dedup --distributed`)",
    )
    .arg(ArgSpec::req("input", "input JSONL (the same file the supervisor read)"))
    .arg(ArgSpec::req("shard", "shard index in [0, shards)"))
    .arg(ArgSpec::req("shards", "total shard count (fixes the round-robin slice)"))
    .arg(ArgSpec::req(
        "dir",
        "worker publish directory (engine checkpoint + outcomes + completion manifest)",
    ))
    .arg(ArgSpec::opt("threshold", "similarity threshold").default("0.5"))
    .arg(ArgSpec::opt("perms", "minhash permutations").default("256"))
    .arg(ArgSpec::opt("ngram", "shingle size").default("1"))
    .arg(ArgSpec::opt("p-effective", "index-wide FP bound").default("1e-10"))
    .arg(ArgSpec::req(
        "expected-docs",
        "planned corpus size (must match the supervisor's filter sizing exactly)",
    ))
    .arg(ArgSpec::opt("workers", "worker threads (0 = all cores)").default("1"))
    .arg(ArgSpec::opt("batch-size", "documents per engine batch").default("64"))
    .arg(ArgSpec::opt(
        "checkpoint-every",
        "snapshot the engine every N shard documents (0 = only at end of stream)",
    ).default("0"))
    .arg(ArgSpec::opt(
        "rotate-watermark",
        "sampled-fill fraction in [0,1) at which this worker's engine rotates to a \
         fresh filter generation (0 disables; passed through by the supervisor)",
    ).default("0.5"))
    .arg(ArgSpec::switch(
        "resume",
        "restore the engine checkpoint in --dir/checkpoint (if any) and continue; \
         falls back to a fresh start when no checkpoint exists",
    ));
    let args = parse(cmd, rest)?;
    let dir = PathBuf::from(args.get("dir"));
    // Continue the supervisor's trace across the process boundary: when
    // it exported LSHBLOOM_TRACE_PARENT, this worker's whole run becomes
    // one (pre-forced) span in the distributed tree; absent or garbled,
    // the run is simply untraced.
    let _trace_root = lshbloom::obs::trace::root_from_env(
        &format!("worker.shard{}", args.get_usize("shard")),
        lshbloom::obs::TraceParams::default(),
    );
    let cfg = PipelineConfig {
        threshold: args.get_f64("threshold"),
        num_perms: args.get_usize("perms"),
        ngram: args.get_usize("ngram"),
        p_effective: args.get_f64("p-effective"),
        expected_docs: args.get_u64("expected-docs"),
        workers: args.get_usize("workers"),
        batch_size: args.get_usize("batch-size"),
        rotate_watermark: args.get_f64("rotate-watermark"),
        engine: EngineMode::Concurrent,
        checkpoint_dir: dir
            .join(lshbloom::persist::WORKER_CHECKPOINT_DIR)
            .display()
            .to_string(),
        checkpoint_every: args.get_u64("checkpoint-every"),
        ..Default::default()
    };
    cfg.validate()?;
    let manifest = lshbloom::pipeline::run_worker(
        &cfg,
        Path::new(args.get("input")),
        args.get_usize("shard"),
        args.get_usize("shards"),
        &dir,
        args.get_bool("resume"),
    )?;
    println!(
        "worker {} complete: {} documents, {} dropped in shard, {} survivors published",
        manifest.shard, manifest.docs, manifest.dropped, manifest.survivors
    );
    Ok(())
}

fn cmd_tune(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("tune", "hyperparameter grids (Figs. 2-4, Table 1)")
        .arg(ArgSpec::opt("family", "lsh|ngram|paragraph|all").default("all"))
        .arg(ArgSpec::switch("quick", "reduced corpus for a fast pass"));
    let args = parse(cmd, rest)?;
    let scale = scale_from(&args);
    let family = args.get("family");

    if family == "lsh" || family == "all" {
        for (kind, pts) in experiments::fig2_grids(scale) {
            print_grid(&format!("Fig 2 — {} F1 (perms × threshold)", kind.name()), &pts);
        }
    }
    if family == "ngram" || family == "all" {
        for (kind, pts) in experiments::fig3_grids(scale) {
            print_grid(&format!("Fig 3 — {} F1 (ngram × threshold)", kind.name()), &pts);
        }
    }
    if family == "paragraph" || family == "all" {
        for (kind, pts) in experiments::fig4_sweeps(scale) {
            print_grid(&format!("Fig 4 — {} F1 vs threshold", kind.name()), &pts);
        }
    }
    if family == "all" {
        let best = experiments::table1(scale);
        let mut t = Table::new("Table 1 — best settings", &["technique", "ngram", "threshold", "perms", "F1"]);
        for gp in best {
            t.row_disp(&[
                gp.spec.kind.name().to_string(),
                gp.spec.ngram.to_string(),
                format!("{}", gp.spec.threshold),
                gp.spec.num_perms.to_string(),
                f(gp.f1(), 4),
            ]);
        }
        t.print();
    }
    Ok(())
}

fn print_grid(title: &str, pts: &[lshbloom::eval::tuner::GridPoint]) {
    let mut t = Table::new(title, &["threshold", "perms", "ngram", "precision", "recall", "F1"]);
    for gp in pts {
        t.row_disp(&[
            format!("{}", gp.spec.threshold),
            gp.spec.num_perms.to_string(),
            gp.spec.ngram.to_string(),
            f(gp.result.confusion.precision(), 4),
            f(gp.result.confusion.recall(), 4),
            f(gp.f1(), 4),
        ]);
    }
    t.print();
}

fn cmd_fidelity(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("fidelity", "fidelity vs duplication rate (Fig. 5)")
        .arg(ArgSpec::opt("rates", "comma-separated duplication rates").default("0.1,0.3,0.5,0.7,0.9"))
        .arg(ArgSpec::switch("quick", "reduced corpus for a fast pass"));
    let args = parse(cmd, rest)?;
    let scale = scale_from(&args);
    let rates: Vec<f64> = args
        .get("rates")
        .split(',')
        .map(|s| s.trim().parse().expect("bad rate"))
        .collect();
    for (rate, results) in experiments::fig5_fidelity(scale, &rates) {
        let mut t = Table::new(
            format!("Fig 5 — duplication rate {rate}"),
            &["method", "precision", "recall", "F1", "wall (s)", "disk"],
        );
        for r in results {
            t.row_disp(&[
                r.method.clone(),
                f(r.confusion.precision(), 4),
                f(r.confusion.recall(), 4),
                f(r.confusion.f1(), 4),
                f(r.wall_secs, 2),
                bytes(r.disk_bytes),
            ]);
        }
        t.print();
    }
    Ok(())
}

fn cmd_scale(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("scale", "resource scaling study (Figs. 1, 7)")
        .arg(ArgSpec::opt("fractions", "comma-separated corpus fractions").default("0.01,0.02,0.05,0.1,0.25,0.5,1.0"))
        .arg(ArgSpec::switch("quick", "reduced corpus for a fast pass"));
    let args = parse(cmd, rest)?;
    let scale = scale_from(&args);

    let rows = experiments::fig1_breakdown(scale);
    let mut t = Table::new(
        "Fig 1 — wall clock breakdown (10% subset)",
        &["method", "minhash (s)", "index (s)", "other (s)", "total (s)"],
    );
    for b in &rows {
        t.row_disp(&[
            b.method.clone(),
            f(b.minhash_secs, 2),
            f(b.index_secs, 2),
            f(b.other_secs, 2),
            f(b.wall_secs, 2),
        ]);
    }
    t.print();

    let fractions: Vec<f64> = args
        .get("fractions")
        .split(',')
        .map(|s| s.trim().parse().expect("bad fraction"))
        .collect();
    let pts = experiments::fig7_scaling(scale, &fractions);
    let mut t = Table::new("Fig 7 — scaling", &["method", "docs", "wall (s)", "disk"]);
    for p in &pts {
        t.row_disp(&[p.method.clone(), p.docs.to_string(), f(p.wall_secs, 2), bytes(p.disk_bytes)]);
    }
    t.print();
    Ok(())
}

fn cmd_extrapolate(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("extrapolate", "projection at extreme scale (Fig. 8, Table 2)")
        .arg(ArgSpec::opt("targets", "comma-separated doc counts").default("1000000000,5000000000"))
        .arg(ArgSpec::switch("quick", "reduced measurement corpus"));
    let args = parse(cmd, rest)?;
    let scale = scale_from(&args);
    let targets: Vec<u64> = args
        .get("targets")
        .split(',')
        .map(|s| s.trim().parse().expect("bad target"))
        .collect();

    let pts = experiments::fig7_scaling(scale, &[0.25, 0.5, 0.75, 1.0]);
    let proj = experiments::fig8_extrapolate(&pts, &targets);
    let mut t = Table::new("Fig 8 — extrapolated runtime", &["method", "docs", "projected"]);
    for (m, targets) in &proj {
        for (n, secs) in targets {
            let days = secs / 86_400.0;
            t.row_disp(&[m.clone(), n.to_string(), format!("{secs:.0}s (~{days:.1} days)")]);
        }
    }
    t.print();

    let rows = experiments::table2_rows();
    let mut t = Table::new(
        "Table 2 — extrapolated index storage",
        &["N", "bloom FP", "lshbloom", "minhashlsh", "advantage"],
    );
    for r in rows {
        t.row_disp(&[
            r.n.to_string(),
            format!("{:.1e}", r.p_effective),
            bytes(r.lshbloom_bytes),
            bytes(r.minhashlsh_bytes),
            format!("{:.1}x", r.advantage()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("serve", "run the TCP deduplication service")
        .arg(ArgSpec::opt("addr", "listen address").default("127.0.0.1:7878"))
        .arg(ArgSpec::opt("threshold", "Jaccard threshold").default("0.5"))
        .arg(ArgSpec::opt("perms", "minhash permutations").default("256"))
        .arg(ArgSpec::opt("p-effective", "index-wide FP bound").default("1e-10"))
        .arg(ArgSpec::opt("expected-docs", "planned corpus size").default("1000000"))
        .arg(ArgSpec::opt(
            "expect-docs",
            "capacity-planner spelling of --expected-docs (key capacity.expect_docs); \
             wins over it when > 0",
        ).default("0"))
        .arg(ArgSpec::opt(
            "fp-budget",
            "capacity-planner spelling of --p-effective (key capacity.fp_budget); \
             wins over it when non-empty",
        ).default(""))
        .arg(ArgSpec::opt(
            "rotate-watermark",
            "sampled-fill fraction in [0,1) at which the concurrent engine rotates to \
             a fresh filter generation (0 disables; key capacity.rotate_watermark)",
        ).default("0.5"))
        .arg(ArgSpec::opt("engine", "index engine: classic|concurrent (lock-free ingest)").default("classic"))
        .arg(ArgSpec::opt(
            "serve-shards",
            "run N in-process band-slice engines probed in parallel and OR-reduced \
             (concurrent engine; verdicts identical to a single engine); with \
             --state-dir they slice-restore from its checkpoint and write a \
             full-index snapshot back on orderly shutdown",
        ).default("1"))
        .arg(ArgSpec::opt(
            "slice-index",
            "serve ONE band slice as a router backend (0-based; requires --slice-count \
             and --engine concurrent; text ops are rejected — only band-level ops)",
        ))
        .arg(ArgSpec::opt(
            "slice-count",
            "total slice count of the router deployment this backend belongs to",
        ))
        .arg(ArgSpec::opt(
            "max-line-bytes",
            "per-connection request-line cap in bytes (oversized lines get an error \
             response and the connection closes)",
        ).default("16777216"))
        .arg(ArgSpec::opt(
            "state-dir",
            "durable index dir (concurrent engine): warm-start from its checkpoint when \
             present, else create state there; checkpointed on shutdown. Band-sharded \
             servers slice-restore from it; slice servers own it as live mmap-backed \
             filters, so every acknowledged insert survives a crash-restart",
        ).default(""))
        .arg(ArgSpec::opt(
            "sync-from",
            "comma-separated healthy replica addresses to anti-entropy from at bind \
             (slice servers): each owned band is pulled (`pull_bands`) and bit-OR \
             merged before the listener opens, so a restarted replica re-converges \
             with its peers before it serves probes",
        ).default(""))
        .arg(ArgSpec::opt(
            "metrics-addr",
            "HOST:PORT for a Prometheus metrics endpoint (GET /metrics for text \
             exposition, /metrics.json for JSON, plus /healthz, /readyz, and the \
             /debug/traces explorer; port 0 = ephemeral; empty = off)",
        ).default(""))
        .arg(ArgSpec::opt(
            "trace-sample",
            "probability in [0,1] that a request records a distributed trace \
             (errors and slow requests always record; 0 = off)",
        ).default("0"))
        .arg(ArgSpec::opt(
            "trace-slow-ms",
            "slow-request threshold in ms: at or above it a request always records \
             a trace and logs a WARN line with the per-hop breakdown (0 = off)",
        ).default("0"))
        .arg(ArgSpec::switch("shm", "host bloom filters in /dev/shm (classic engine)"))
        .arg(ArgSpec::switch("blocked", "use blocked bloom filters (classic engine)"));
    let args = parse(cmd, rest)?;
    let expected = match args.get_u64("expect-docs") {
        0 => args.get_u64("expected-docs"),
        n => n,
    };
    let p_effective = match args.get_opt("fp-budget").filter(|s| !s.is_empty()) {
        Some(p) => p.parse::<f64>().map_err(|_| format!("bad --fp-budget '{p}'"))?,
        None => args.get_f64("p-effective"),
    };
    let cfg = PipelineConfig {
        threshold: args.get_f64("threshold"),
        num_perms: args.get_usize("perms"),
        p_effective,
        expected_docs: expected,
        rotate_watermark: args.get_f64("rotate-watermark"),
        use_shm: args.get_bool("shm"),
        blocked_bloom: args.get_bool("blocked"),
        engine: EngineMode::parse(args.get("engine"))?,
        checkpoint_dir: args.get("state-dir").to_string(),
        serve_shards: args.get_usize("serve-shards"),
        metrics_addr: args.get("metrics-addr").to_string(),
        trace_sample: args.get_f64("trace-sample"),
        trace_slow_ms: args.get_u64("trace-slow-ms"),
        ..Default::default()
    };
    // Catches --state-dir / --serve-shards without --engine concurrent,
    // among the rest.
    cfg.validate()?;
    // Same rule as `dedup`: these flags are classic-engine knobs, and
    // silently ignoring them would let an operator believe the index is
    // shm-persisted/blocked when it is not.
    if cfg.engine == EngineMode::Concurrent && (cfg.use_shm || cfg.blocked_bloom) {
        return Err(
            "--engine concurrent does not support --shm/--blocked (atomic filters are \
             classic layout; use --state-dir for file-backed persistence)"
                .into(),
        );
    }
    // Echo the derived geometry so the served layout is on record next
    // to the listen line (router backends must all print the same plan).
    let plan = lshbloom::capacity::Plan::from_config(&cfg)?;
    println!("capacity plan: {}", plan.describe());
    let slice = match (args.get_opt("slice-index"), args.get_opt("slice-count")) {
        (Some(i), Some(n)) => {
            let i: usize = i.parse().map_err(|_| format!("bad --slice-index '{i}'"))?;
            let n: usize = n.parse().map_err(|_| format!("bad --slice-count '{n}'"))?;
            Some((i, n))
        }
        (None, None) => None,
        _ => return Err("--slice-index and --slice-count must be given together".into()),
    };
    let state_dir = Some(&cfg.checkpoint_dir)
        .filter(|s| !s.is_empty())
        .map(PathBuf::from);
    let warm = state_dir
        .as_deref()
        .is_some_and(lshbloom::persist::CheckpointManifest::exists);
    let sync_from: Vec<String> = args
        .get("sync-from")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if !sync_from.is_empty() && slice.is_none() {
        return Err("--sync-from is a slice-server flag (requires --slice-index)".into());
    }
    let opts = lshbloom::service::ServeOptions {
        state_dir,
        slice,
        sync_from,
        max_line_bytes: args.get_usize("max-line-bytes"),
        metrics_addr: Some(&cfg.metrics_addr).filter(|s| !s.is_empty()).cloned(),
    };
    let server = lshbloom::service::DedupServer::bind_with_opts(args.get("addr"), &cfg, &opts)?;
    let mode = match slice {
        Some((i, n)) => format!("band slice {i} of {n}"),
        None if cfg.serve_shards > 1 => format!("{} band slices", cfg.serve_shards),
        None => format!("{} engine", args.get("engine")),
    };
    println!(
        "lshbloom dedup service listening on {} ({mode}{}; send {{\"op\":\"shutdown\"}} to stop)",
        server.local_addr()?,
        match (&opts.state_dir, warm) {
            (Some(d), true) => format!("; warm-started from {}", d.display()),
            (Some(d), false) => format!("; durable state in {}", d.display()),
            (None, _) => String::new(),
        },
    );
    if let Some(maddr) = server.metrics_addr() {
        println!(
            "metrics: http://{maddr}/metrics (Prometheus text), /metrics.json, \
             /healthz, /readyz, /debug/traces"
        );
    }
    server.serve()?;
    Ok(())
}

fn cmd_route(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("route", "band-partition router over N backend dedup servers")
        .arg(ArgSpec::opt("addr", "listen address").default("127.0.0.1:7879"))
        .arg(ArgSpec::req(
            "backends",
            "comma-separated slice specs, each a `|`-separated replica group \
             (`a:7001|b:7001,a:7002|b:7002` = 2 slices x 2 replicas); every replica \
             must be `serve --slice-index I --slice-count N` with N = number of \
             comma groups (one full --engine concurrent server also works as the \
             degenerate 1-backend fleet). Inserts fan to all live replicas; probes \
             fail over when one dies",
        ))
        .arg(ArgSpec::opt("threshold", "Jaccard threshold (must match the backends)").default("0.5"))
        .arg(ArgSpec::opt("perms", "minhash permutations (must match the backends)").default("256"))
        .arg(ArgSpec::opt("p-effective", "index-wide FP bound (must match the backends)").default("1e-10"))
        .arg(ArgSpec::opt(
            "expected-docs",
            "planned corpus size (must match the backends' filter sizing)",
        ).default("1000000"))
        .arg(ArgSpec::opt(
            "expect-docs",
            "capacity-planner spelling of --expected-docs (key capacity.expect_docs); \
             wins over it when > 0",
        ).default("0"))
        .arg(ArgSpec::opt(
            "fp-budget",
            "capacity-planner spelling of --p-effective (key capacity.fp_budget); \
             wins over it when non-empty",
        ).default(""))
        .arg(ArgSpec::opt(
            "max-line-bytes",
            "per-connection request-line cap in bytes",
        ).default("16777216"))
        .arg(ArgSpec::opt(
            "backend-connect-timeout",
            "seconds to wait for a backend to accept a connection before treating \
             it as down (fractions allowed)",
        ).default("5"))
        .arg(ArgSpec::opt(
            "backend-read-timeout",
            "seconds to wait for one backend reply before failing fast (fractions \
             allowed)",
        ).default("30"))
        .arg(ArgSpec::opt(
            "metrics-addr",
            "HOST:PORT for a Prometheus metrics endpoint (GET /metrics for text \
             exposition, /metrics.json for JSON, plus /healthz, /readyz — ready only \
             while the backend fleet is healthy — and the /debug/traces explorer; \
             port 0 = ephemeral; empty = off)",
        ).default(""))
        .arg(ArgSpec::opt(
            "trace-sample",
            "probability in [0,1] that a request records a distributed trace with \
             one hop span per backend (errors and slow requests always record; \
             0 = off)",
        ).default("0"))
        .arg(ArgSpec::opt(
            "trace-slow-ms",
            "slow-request threshold in ms: at or above it a request always records \
             a trace and logs a WARN line with the per-hop breakdown (0 = off)",
        ).default("0"));
    let args = parse(cmd, rest)?;
    let expected = match args.get_u64("expect-docs") {
        0 => args.get_u64("expected-docs"),
        n => n,
    };
    let p_effective = match args.get_opt("fp-budget").filter(|s| !s.is_empty()) {
        Some(p) => p.parse::<f64>().map_err(|_| format!("bad --fp-budget '{p}'"))?,
        None => args.get_f64("p-effective"),
    };
    let cfg = PipelineConfig {
        threshold: args.get_f64("threshold"),
        num_perms: args.get_usize("perms"),
        p_effective,
        expected_docs: expected,
        metrics_addr: args.get("metrics-addr").to_string(),
        trace_sample: args.get_f64("trace-sample"),
        trace_slow_ms: args.get_u64("trace-slow-ms"),
        ..Default::default()
    };
    cfg.validate()?;
    let connect_timeout = args.get_f64("backend-connect-timeout");
    let read_timeout = args.get_f64("backend-read-timeout");
    for (flag, v) in [
        ("backend-connect-timeout", connect_timeout),
        ("backend-read-timeout", read_timeout),
    ] {
        if !v.is_finite() || v <= 0.0 {
            return Err(format!("--{flag} must be a positive number of seconds (got {v})").into());
        }
    }
    let backends: Vec<String> = args
        .get("backends")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let opts = lshbloom::service::RouterOptions {
        max_line_bytes: args.get_usize("max-line-bytes"),
        connect_timeout: std::time::Duration::from_secs_f64(connect_timeout),
        read_timeout: std::time::Duration::from_secs_f64(read_timeout),
        metrics_addr: Some(&cfg.metrics_addr).filter(|s| !s.is_empty()).cloned(),
    };
    let router =
        lshbloom::service::DedupRouter::bind(args.get("addr"), &cfg, backends, &opts)?;
    println!(
        "lshbloom dedup router listening on {} ({} backends, one MinHash per request, \
         OR-reduced verdicts; backend timeouts: connect {:.3}s, read {:.3}s; send \
         {{\"op\":\"shutdown\"}} to stop)",
        router.local_addr()?,
        router.num_backends(),
        opts.connect_timeout.as_secs_f64(),
        opts.read_timeout.as_secs_f64(),
    );
    if let Some(maddr) = router.metrics_addr() {
        println!(
            "metrics: http://{maddr}/metrics (Prometheus text), /metrics.json, \
             /healthz, /readyz, /debug/traces"
        );
    }
    router.serve()?;
    Ok(())
}

fn cmd_lint(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("lint", "run the in-repo soundness linter over the source tree")
        .arg(ArgSpec::opt("root", "repository root (directory containing rust/ and docs/)"));
    let args = parse(cmd, rest)?;
    let root = match args.get_opt("root") {
        Some(r) => PathBuf::from(r),
        // Auto-detect: run from the repo root (has rust/) or from
        // rust/ itself (has Cargo.toml, repo root is the parent).
        None if Path::new("rust").is_dir() => PathBuf::from("."),
        None if Path::new("Cargo.toml").is_file() => PathBuf::from(".."),
        None => return Err("cannot locate the repository root; pass --root".into()),
    };
    let started = std::time::Instant::now();
    let report = lshbloom::analysis::lint_tree(&root)?;
    for finding in &report.findings {
        println!("{finding}");
    }
    println!(
        "lint: {} file(s) scanned, {} finding(s) in {:.2}s",
        report.files_scanned,
        report.findings.len(),
        started.elapsed().as_secs_f64()
    );
    if report.findings.is_empty() {
        Ok(())
    } else {
        Err(format!("{} lint finding(s)", report.findings.len()).into())
    }
}

fn cmd_info(rest: Vec<String>) -> CliResult {
    let cmd = Command::new("info", "environment + artifact status")
        .arg(ArgSpec::opt("artifacts", "artifacts directory").default("artifacts"));
    let args = parse(cmd, rest)?;
    let dir = PathBuf::from(args.get("artifacts"));
    println!("lshbloom {}", env!("CARGO_PKG_VERSION"));
    println!("cores: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
    println!("shm dir: {}", lshbloom::bloom::shm::default_shm_dir().display());
    let manifest = dir.join("manifest.json");
    if manifest.exists() {
        println!("artifacts: {} (present)", dir.display());
        match lshbloom::runtime::PjrtEngine::cpu() {
            Ok(engine) => println!(
                "pjrt: platform={} devices={}",
                engine.platform_name(),
                engine.device_count()
            ),
            Err(e) => println!("pjrt: UNAVAILABLE ({e:#})"),
        }
    } else {
        println!("artifacts: missing — run `make artifacts`");
    }
    Ok(())
}
