//! Crate-level error type.
//!
//! A small hand-rolled enum (no `thiserror` offline); `anyhow` is used at
//! binary boundaries, this type at library boundaries where callers may
//! want to match on the failure class.

use std::fmt;

/// Errors produced by the lshbloom library.
#[derive(Debug)]
pub enum Error {
    /// I/O failure with context path.
    Io { path: String, source: std::io::Error },
    /// Malformed input (corpus line, config file, artifact manifest, …).
    Parse { what: String, detail: String },
    /// Invalid configuration or parameter combination.
    Config(String),
    /// Index persistence format problems.
    Format(String),
    /// PJRT / XLA runtime failures (stringified — xla::Error is not `Sync`).
    Runtime(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
            Error::Parse { what, detail } => write!(f, "parse error in {what}: {detail}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Format(msg) => write!(f, "format error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Convenience constructor for I/O errors with a path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Convenience constructor for parse errors.
    pub fn parse(what: impl Into<String>, detail: impl Into<String>) -> Self {
        Error::Parse { what: what.into(), detail: detail.into() }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::parse("corpus", "bad line 3");
        assert_eq!(e.to_string(), "parse error in corpus: bad line 3");
        let e = Error::Config("b*r > num_perm".into());
        assert!(e.to_string().contains("b*r"));
    }

    #[test]
    fn io_error_preserves_source() {
        use std::error::Error as _;
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.source().is_some());
        assert!(e.to_string().contains("/tmp/x"));
    }
}
