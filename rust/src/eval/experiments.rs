//! Paper experiment drivers — one function per figure/table.
//!
//! Shared by the `cargo bench` binaries and the CLI so a figure is
//! regenerated identically from either entry point. Each driver takes a
//! [`Scale`] so CI can run a shrunken version (`LSHBLOOM_BENCH_QUICK=1`)
//! while full runs populate EXPERIMENTS.md.

use crate::corpus::{DatasetSpec, LabeledCorpus, LabeledDoc, StreamSpec};
use crate::eval::runner::{run_method, EvalResult};
use crate::eval::tuner::{self, GridPoint};
use crate::methods::{MethodKind, MethodSpec};
use crate::minhash::{optimal_param, LshParams};
use crate::pipeline::{run_stream, PipelineOptions, RunStats};

/// Experiment sizing.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Tuning-corpus documents (paper: 24 000).
    pub tuning_docs: usize,
    /// Testing-corpus documents (paper: 50 000).
    pub testing_docs: usize,
    /// Largest peS2o-sim subset (paper: 39 M).
    pub scale_docs: u64,
    /// Master seed for every corpus.
    pub seed: u64,
}

impl Scale {
    /// Paper-sized fidelity corpora (24 k / 50 k), 200 k scale cap.
    pub fn paper() -> Self {
        Self { tuning_docs: 24_000, testing_docs: 50_000, scale_docs: 200_000, seed: 0xE5C0 }
    }

    /// Default bench scale: same shapes, sized for a single-node run.
    pub fn standard() -> Self {
        Self { tuning_docs: 8_000, testing_docs: 15_000, scale_docs: 100_000, seed: 0xE5C0 }
    }

    /// Reduced scale for interactive/CI runs.
    pub fn quick() -> Self {
        Self { tuning_docs: 1_200, testing_docs: 2_000, scale_docs: 10_000, seed: 0xE5C0 }
    }

    /// Select via env: `LSHBLOOM_BENCH_QUICK=1` (or the micro-bench
    /// smoke switch `LSHBLOOM_BENCH_FAST=1`) → quick,
    /// `LSHBLOOM_SCALE=paper` → paper-sized, otherwise standard.
    pub fn from_env() -> Self {
        let flag = |name: &str| std::env::var(name).map(|v| v == "1").unwrap_or(false);
        if flag("LSHBLOOM_BENCH_QUICK") || flag("LSHBLOOM_BENCH_FAST") {
            return Self::quick();
        }
        match std::env::var("LSHBLOOM_SCALE").as_deref() {
            Ok("paper") => Self::paper(),
            _ => Self::standard(),
        }
    }
}

fn default_opts() -> PipelineOptions {
    PipelineOptions::default()
}

/// Build (and cache per process) the tuning corpus.
pub fn tuning_corpus(scale: Scale) -> LabeledCorpus {
    LabeledCorpus::build(DatasetSpec::tuning(scale.seed, scale.tuning_docs))
}

/// Build a testing corpus at a duplication rate.
pub fn testing_corpus(scale: Scale, dup_rate: f64) -> LabeledCorpus {
    LabeledCorpus::build(DatasetSpec::testing(
        scale.seed ^ (dup_rate * 1000.0) as u64,
        scale.testing_docs,
        dup_rate,
    ))
}

// ---------------------------------------------------------------- Fig. 1

/// One method's phase breakdown on a peS2o-sim subset.
#[derive(Clone, Debug)]
pub struct Breakdown {
    pub method: String,
    pub minhash_secs: f64,
    pub index_secs: f64,
    pub other_secs: f64,
    pub wall_secs: f64,
    pub docs: u64,
}

impl Breakdown {
    fn from_stats(method: &str, stats: &RunStats) -> Self {
        let prep = stats.times.prepare_wall_est(stats.workers).as_secs_f64();
        let decide = stats.times.decide.as_secs_f64();
        let wall = stats.times.wall.as_secs_f64();
        Self {
            method: method.to_string(),
            minhash_secs: prep,
            index_secs: decide,
            other_secs: (wall - prep - decide).max(0.0),
            wall_secs: wall,
            docs: stats.docs,
        }
    }
}

/// Fig. 1: wall-clock breakdown of MinHashLSH vs LSHBloom on a 10% subset.
///
/// Emits three rows: the honest rust-normalized baseline, the
/// paper-calibrated datasketch cost simulation (see
/// `methods::minhashlsh::PySimCosts`), and LSHBloom.
pub fn fig1_breakdown(scale: Scale) -> Vec<Breakdown> {
    let docs = (scale.scale_docs / 10).max(1000);
    let mut out = Vec::new();
    for kind in [MethodKind::MinHashLsh, MethodKind::LshBloom] {
        let spec = StreamSpec::pes2o_sim(scale.seed, docs);
        let sample: Vec<crate::corpus::Doc> =
            spec.stream().take(200).map(|ld| ld.doc).collect();
        let mut method = MethodSpec::best(kind, docs).build(&sample);
        let stats = run_stream(
            &mut method,
            spec.stream().map(|ld| ld.doc),
            default_opts(),
        );
        out.push(Breakdown::from_stats(kind.name(), &stats));
    }
    // The datasketch-calibrated baseline (paper's actual comparator).
    {
        let spec = StreamSpec::pes2o_sim(scale.seed, docs);
        let cfg = crate::config::PipelineConfig {
            threshold: 0.5,
            num_perms: 256,
            expected_docs: docs,
            ..Default::default()
        };
        let mut method = crate::methods::minhashlsh::minhashlsh_pysim_method(
            &cfg,
            crate::minhash::PermFamily::Mix64,
            crate::methods::minhashlsh::PySimCosts::paper_calibrated(),
        );
        let stats = run_stream(&mut method, spec.stream().map(|ld| ld.doc), default_opts());
        out.push(Breakdown::from_stats("minhashlsh-pysim", &stats));
    }
    out
}

// ----------------------------------------------------------- Figs. 2-4

/// Fig. 2 grids (MinHashLSH + LSHBloom over permutations × threshold).
pub fn fig2_grids(scale: Scale) -> Vec<(MethodKind, Vec<GridPoint>)> {
    let corpus = tuning_corpus(scale);
    [MethodKind::MinHashLsh, MethodKind::LshBloom]
        .into_iter()
        .map(|kind| {
            let pts = tuner::tune_lsh(
                kind,
                &corpus.docs,
                &tuner::ranges::THRESHOLDS,
                &tuner::ranges::PERMS,
                default_opts(),
            );
            (kind, pts)
        })
        .collect()
}

/// Fig. 3 grids (DCLM + Dolma-Ngram over n-gram size × threshold).
pub fn fig3_grids(scale: Scale) -> Vec<(MethodKind, Vec<GridPoint>)> {
    let corpus = tuning_corpus(scale);
    [MethodKind::Dclm, MethodKind::DolmaNgram]
        .into_iter()
        .map(|kind| {
            let pts = tuner::tune_ngram(
                kind,
                &corpus.docs,
                &tuner::ranges::THRESHOLDS,
                &tuner::ranges::NGRAMS,
                default_opts(),
            );
            (kind, pts)
        })
        .collect()
}

/// Fig. 4 sweeps (Dolma + CCNet over threshold).
pub fn fig4_sweeps(scale: Scale) -> Vec<(MethodKind, Vec<GridPoint>)> {
    let corpus = tuning_corpus(scale);
    [MethodKind::Dolma, MethodKind::CcNet]
        .into_iter()
        .map(|kind| {
            let pts = tuner::tune_paragraph(
                kind,
                &corpus.docs,
                &tuner::ranges::THRESHOLDS,
                default_opts(),
            );
            (kind, pts)
        })
        .collect()
}

/// Table 1: best setting per technique from the tuning grids.
pub fn table1(scale: Scale) -> Vec<GridPoint> {
    let mut best = Vec::new();
    for (_, pts) in fig2_grids(scale) {
        best.push(tuner::best(&pts).clone());
    }
    for (_, pts) in fig3_grids(scale) {
        best.push(tuner::best(&pts).clone());
    }
    for (_, pts) in fig4_sweeps(scale) {
        best.push(tuner::best(&pts).clone());
    }
    best
}

// ---------------------------------------------------------------- Fig. 5

/// Fig. 5: fidelity of all six methods across duplication rates.
pub fn fig5_fidelity(scale: Scale, rates: &[f64]) -> Vec<(f64, Vec<EvalResult>)> {
    let mut out = Vec::new();
    for &rate in rates {
        let corpus = testing_corpus(scale, rate);
        let results = run_all_methods(&corpus.docs, scale);
        out.push((rate, results));
    }
    out
}

/// Run every technique at its Table-1 best settings on a labeled corpus.
pub fn run_all_methods(docs: &[LabeledDoc], _scale: Scale) -> Vec<EvalResult> {
    let sample: Vec<crate::corpus::Doc> =
        docs.iter().take(1000).map(|ld| ld.doc.clone()).collect();
    MethodKind::ALL
        .into_iter()
        .map(|kind| {
            let mut m = MethodSpec::best(kind, docs.len() as u64).build(&sample);
            run_method(&mut m, docs, default_opts())
        })
        .collect()
}

/// Fig. 6: the balanced-corpus (50 % dup) pareto data.
pub fn fig6_pareto(scale: Scale) -> Vec<EvalResult> {
    let corpus = testing_corpus(scale, 0.5);
    run_all_methods(&corpus.docs, scale)
}

// ---------------------------------------------------------------- Fig. 7

/// One scaling measurement.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub method: String,
    pub docs: u64,
    pub wall_secs: f64,
    pub disk_bytes: u64,
    pub duplicates: u64,
}

/// Methods included in the scaling study (paper: n-gram methods excluded
/// as prohibitively slow).
pub const SCALE_METHODS: [MethodKind; 4] = [
    MethodKind::MinHashLsh,
    MethodKind::LshBloom,
    MethodKind::Dolma,
    MethodKind::CcNet,
];

/// Fig. 7: runtime + disk over peS2o-sim subsets.
///
/// Includes the datasketch-calibrated baseline (`minhashlsh-pysim`) on
/// the smaller fractions only — its simulated 2.9 ms/doc index cost is
/// the point being measured, so larger subsets are extrapolated (as the
/// paper itself does for 5 B docs).
pub fn fig7_scaling(scale: Scale, fractions: &[f64]) -> Vec<ScalePoint> {
    let mut out = Vec::new();
    for &frac in fractions {
        let docs = ((scale.scale_docs as f64 * frac) as u64).max(500);
        for kind in SCALE_METHODS {
            let spec = StreamSpec::pes2o_sim(scale.seed, docs);
            let sample: Vec<crate::corpus::Doc> =
                spec.stream().take(200).map(|ld| ld.doc).collect();
            let mut method = MethodSpec::best(kind, docs).build(&sample);
            let stats = run_stream(&mut method, spec.stream().map(|ld| ld.doc), default_opts());
            out.push(ScalePoint {
                method: kind.name().to_string(),
                docs,
                wall_secs: stats.times.wall.as_secs_f64(),
                disk_bytes: stats.disk_bytes,
                duplicates: stats.duplicates,
            });
        }
        if frac <= 0.25 {
            let spec = StreamSpec::pes2o_sim(scale.seed, docs);
            let cfg = crate::config::PipelineConfig {
                threshold: 0.5,
                num_perms: 256,
                expected_docs: docs,
                ..Default::default()
            };
            let mut method = crate::methods::minhashlsh::minhashlsh_pysim_method(
                &cfg,
                crate::minhash::PermFamily::Mix64,
                crate::methods::minhashlsh::PySimCosts::paper_calibrated(),
            );
            let stats = run_stream(&mut method, spec.stream().map(|ld| ld.doc), default_opts());
            out.push(ScalePoint {
                method: "minhashlsh-pysim".to_string(),
                docs,
                wall_secs: stats.times.wall.as_secs_f64(),
                disk_bytes: stats.disk_bytes,
                duplicates: stats.duplicates,
            });
        }
    }
    out
}

// ------------------------------------------------------- Fig. 8 / Table 2

/// Fig. 8: per-method linear runtime fits extrapolated to target sizes.
pub fn fig8_extrapolate(
    points: &[ScalePoint],
    targets: &[u64],
) -> Vec<(String, Vec<(u64, f64)>)> {
    use crate::eval::extrapolate::LinearFit;
    let mut methods: Vec<String> = points.iter().map(|p| p.method.clone()).collect();
    methods.sort();
    methods.dedup();
    methods
        .into_iter()
        .filter_map(|m| {
            let samples: Vec<(f64, f64)> = points
                .iter()
                .filter(|p| p.method == m)
                .map(|p| (p.docs as f64, p.wall_secs))
                .collect();
            if samples.len() < 2 {
                return None; // not enough measurements to fit
            }
            let fit = LinearFit::fit(&samples);
            let proj = targets.iter().map(|&n| (n, fit.at(n as f64))).collect();
            Some((m, proj))
        })
        .collect()
}

/// Table 2: extrapolated index storage (closed-form LSHBloom vs linear
/// MinHashLSH) using the Table-1 tuned geometry.
pub fn table2_rows() -> Vec<crate::eval::extrapolate::StorageRow> {
    let lsh: LshParams = optimal_param(0.5, 256); // Table-1 best: (42, 6)
    let ns = [5_000_000_000u64, 100_000_000_000];
    let mut rows = Vec::new();
    for n in ns {
        for (p, _label) in [(1e-5, "1e-5"), (1e-8, "1e-8"), (1.0 / n as f64, "1/N")] {
            rows.push(crate::eval::extrapolate::StorageRow {
                p_effective: p,
                n,
                lshbloom_bytes: crate::eval::extrapolate::lshbloom_index_bytes(n, p, lsh),
                // 8-byte hashes (our u64 pipeline) + 24B entry overhead.
                minhashlsh_bytes: crate::eval::extrapolate::minhashlsh_index_bytes(n, lsh, 8, 24),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale { tuning_docs: 120, testing_docs: 150, scale_docs: 2_000, seed: 7 }
    }

    #[test]
    fn fig1_runs_and_shows_index_gap() {
        let rows = fig1_breakdown(tiny());
        assert_eq!(rows.len(), 3);
        let mlsh = rows.iter().find(|r| r.method == "minhashlsh").unwrap();
        let lshb = rows.iter().find(|r| r.method == "lshbloom").unwrap();
        assert_eq!(mlsh.docs, lshb.docs);
        // LSHBloom's index phase must be cheaper than MinHashLSH's.
        assert!(lshb.index_secs < mlsh.index_secs, "{rows:?}");
    }

    #[test]
    fn fig5_runs_all_methods() {
        let results = fig5_fidelity(tiny(), &[0.5]);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].1.len(), 6);
        for r in &results[0].1 {
            assert_eq!(r.docs, 150, "{}", r.method);
        }
    }

    #[test]
    fn fig7_and_fig8_pipeline() {
        let pts = fig7_scaling(tiny(), &[0.25, 0.5, 1.0]);
        // 3 fractions x 4 real methods + 1 pysim row (fraction 0.25 only).
        assert_eq!(pts.len(), 3 * SCALE_METHODS.len() + 1);
        let proj = fig8_extrapolate(&pts, &[100_000]);
        // pysim has a single point -> excluded from fits.
        assert_eq!(proj.len(), SCALE_METHODS.len());
        for (m, targets) in &proj {
            assert!(targets[0].1.is_finite(), "{m}");
        }
    }

    #[test]
    fn fig1_pysim_reproduces_paper_profile() {
        let rows = fig1_breakdown(tiny());
        let pysim = rows.iter().find(|r| r.method == "minhashlsh-pysim").unwrap();
        let mlsh = rows.iter().find(|r| r.method == "minhashlsh").unwrap();
        // Paper Fig. 1: index ops dominate the Python baseline (>85% in
        // release at scale; in debug-built tests the prepare phase is
        // inflated, so assert the calibrated gap instead of the share).
        // The rust index is debug-built and this box is shared, so its
        // absolute time is noisy; the stable claims are (a) the
        // calibrated per-doc budget is honored and (b) pysim is at
        // least several times the native index cost.
        assert!(
            pysim.index_secs > mlsh.index_secs * 4.0,
            "pysim index {} vs rust index {}",
            pysim.index_secs,
            mlsh.index_secs
        );
        assert!(pysim.index_secs >= pysim.docs as f64 * 2.9e-3 * 0.95);
    }

    #[test]
    fn table2_shape_matches_paper() {
        let rows = table2_rows();
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(
                r.advantage() > 3.0,
                "LSHBloom must win by a wide margin: {:?} adv {:.1}",
                r,
                r.advantage()
            );
        }
    }
}
