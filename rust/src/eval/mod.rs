//! Evaluation harness: fidelity metrics, method runners, hyperparameter
//! tuning grids (Figs. 2–4, Table 1), and scale extrapolation
//! (Fig. 8, Table 2).

pub mod experiments;
pub mod extrapolate;
pub mod metrics;
pub mod runner;
pub mod tuner;

pub use metrics::Confusion;
pub use runner::{run_method, EvalResult};
