//! Hyperparameter tuning grids (§5.1.5, §5.2) — Figures 2–4 and Table 1.

use crate::corpus::LabeledDoc;
use crate::eval::runner::{run_method, EvalResult};
use crate::methods::{MethodKind, MethodSpec};
use crate::pipeline::PipelineOptions;

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub spec: MethodSpec,
    pub result: EvalResult,
}

impl GridPoint {
    /// The tuning objective.
    pub fn f1(&self) -> f64 {
        self.result.confusion.f1()
    }
}

/// §5.1.5 parameter ranges.
pub mod ranges {
    /// Threshold grid (plus the finer 0.5 probe the paper added).
    pub const THRESHOLDS: [f64; 6] = [0.2, 0.4, 0.5, 0.6, 0.8, 1.0];
    /// Permutation counts (powers of two 32..256 plus the finer 48).
    pub const PERMS: [usize; 5] = [32, 48, 64, 128, 256];
    /// N-gram sizes.
    pub const NGRAMS: [usize; 6] = [1, 2, 5, 7, 13, 26];
}

fn eval_spec(spec: MethodSpec, docs: &[LabeledDoc], opts: PipelineOptions) -> GridPoint {
    let sample: Vec<crate::corpus::Doc> =
        docs.iter().take(1000).map(|ld| ld.doc.clone()).collect();
    let mut method = spec.build(&sample);
    let result = run_method(&mut method, docs, opts);
    GridPoint { spec, result }
}

/// Figure 2 grid: (permutations × threshold) for an LSH-family technique.
pub fn tune_lsh(
    kind: MethodKind,
    docs: &[LabeledDoc],
    thresholds: &[f64],
    perms: &[usize],
    opts: PipelineOptions,
) -> Vec<GridPoint> {
    assert!(matches!(kind, MethodKind::MinHashLsh | MethodKind::LshBloom));
    let mut out = Vec::new();
    for &t in thresholds {
        for &p in perms {
            let spec = MethodSpec {
                threshold: t,
                num_perms: p,
                ngram: 1,
                ..MethodSpec::best(kind, docs.len() as u64)
            };
            out.push(eval_spec(spec, docs, opts));
        }
    }
    out
}

/// Figure 3 grid: (n-gram size × threshold) for an n-gram technique.
pub fn tune_ngram(
    kind: MethodKind,
    docs: &[LabeledDoc],
    thresholds: &[f64],
    ngrams: &[usize],
    opts: PipelineOptions,
) -> Vec<GridPoint> {
    assert!(matches!(kind, MethodKind::DolmaNgram | MethodKind::Dclm));
    let mut out = Vec::new();
    for &t in thresholds {
        for &n in ngrams {
            let spec = MethodSpec {
                threshold: t,
                ngram: n,
                ..MethodSpec::best(kind, docs.len() as u64)
            };
            out.push(eval_spec(spec, docs, opts));
        }
    }
    out
}

/// Figure 4 grid: threshold sweep for a paragraph-level technique.
pub fn tune_paragraph(
    kind: MethodKind,
    docs: &[LabeledDoc],
    thresholds: &[f64],
    opts: PipelineOptions,
) -> Vec<GridPoint> {
    assert!(matches!(kind, MethodKind::Dolma | MethodKind::CcNet));
    thresholds
        .iter()
        .map(|&t| {
            let spec = MethodSpec { threshold: t, ..MethodSpec::best(kind, docs.len() as u64) };
            eval_spec(spec, docs, opts)
        })
        .collect()
}

/// Argmax by F1 (Table 1 selection).
pub fn best(points: &[GridPoint]) -> &GridPoint {
    points
        .iter()
        .max_by(|a, b| a.f1().partial_cmp(&b.f1()).unwrap())
        .expect("empty grid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};

    fn quick_corpus() -> LabeledCorpus {
        LabeledCorpus::build(DatasetSpec::tuning(41, 160))
    }

    #[test]
    fn lsh_grid_shape_and_best() {
        let c = quick_corpus();
        let pts = tune_lsh(
            MethodKind::LshBloom,
            &c.docs,
            &[0.5, 0.9],
            &[32, 64],
            PipelineOptions::default(),
        );
        assert_eq!(pts.len(), 4);
        let b = best(&pts);
        // A sane threshold should beat the absurd 0.9 on this benchmark.
        assert!(b.spec.threshold < 0.9, "best grid point {:?}", b.spec);
        assert!(b.f1() > 0.5);
    }

    #[test]
    fn paragraph_grid_runs() {
        let c = quick_corpus();
        let pts = tune_paragraph(MethodKind::Dolma, &c.docs, &[0.2, 0.8], PipelineOptions::default());
        assert_eq!(pts.len(), 2);
        // Low threshold flags more -> recall no worse than high threshold.
        assert!(pts[0].result.confusion.recall() >= pts[1].result.confusion.recall());
    }

    #[test]
    fn ngram_grid_runs() {
        let c = quick_corpus();
        let pts = tune_ngram(
            MethodKind::Dclm,
            &c.docs,
            &[0.2],
            &[1, 5],
            PipelineOptions::default(),
        );
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert_eq!(p.result.docs, 160);
        }
    }
}
