//! Run a method over a labeled corpus and collect fidelity + resources.

use crate::corpus::LabeledDoc;
use crate::eval::metrics::Confusion;
use crate::methods::Method;
use crate::pipeline::{run_stream, PipelineOptions};

/// Fidelity + resource outcome of one (method, dataset) evaluation.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub method: String,
    pub confusion: Confusion,
    /// End-to-end wall seconds.
    pub wall_secs: f64,
    /// Prepare-phase CPU seconds (summed over workers).
    pub prepare_cpu_secs: f64,
    /// Sequential decide-phase seconds.
    pub decide_secs: f64,
    /// Index footprint in bytes.
    pub disk_bytes: u64,
    /// Documents processed.
    pub docs: u64,
    /// Workers used.
    pub workers: usize,
}

impl EvalResult {
    /// Docs/second end-to-end.
    pub fn throughput(&self) -> f64 {
        self.docs as f64 / self.wall_secs.max(1e-9)
    }
}

/// Evaluate `method` on a labeled corpus through the parallel pipeline.
pub fn run_method(method: &mut Method, docs: &[LabeledDoc], opts: PipelineOptions) -> EvalResult {
    let stats = run_stream(method, docs.iter().map(|ld| ld.doc.clone()), opts);
    let labels: Vec<bool> = docs.iter().map(|ld| ld.is_duplicate()).collect();
    let confusion = Confusion::from_verdicts(&stats.verdicts, &labels);
    EvalResult {
        method: method.name.clone(),
        confusion,
        wall_secs: stats.times.wall.as_secs_f64(),
        prepare_cpu_secs: stats.times.prepare_cpu.as_secs_f64(),
        decide_secs: stats.times.decide.as_secs_f64(),
        disk_bytes: stats.disk_bytes,
        docs: stats.docs,
        workers: stats.workers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::corpus::{DatasetSpec, LabeledCorpus};
    use crate::methods::lshbloom::lshbloom_method;
    use crate::minhash::PermFamily;

    #[test]
    fn eval_produces_consistent_result() {
        let c = LabeledCorpus::build(DatasetSpec::testing(37, 200, 0.5));
        let cfg = PipelineConfig { num_perms: 128, expected_docs: 1000, ..Default::default() };
        let mut m = lshbloom_method(&cfg, PermFamily::Mix64);
        let r = run_method(&mut m, &c.docs, PipelineOptions::default());
        assert_eq!(r.docs, 200);
        assert_eq!(r.confusion.total(), 200);
        assert!(r.confusion.f1() > 0.7, "f1 {}", r.confusion.f1());
        assert!(r.confusion.precision() > 0.9, "precision {}", r.confusion.precision());
        assert!(r.wall_secs > 0.0 && r.disk_bytes > 0);
    }
}
