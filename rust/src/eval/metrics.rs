//! Fidelity metrics (§5.1.3): precision, recall, F1 over the
//! duplicate/non-duplicate confusion matrix.

/// Confusion counts; "positive" = flagged as duplicate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    /// Tally verdicts against ground-truth labels.
    pub fn from_verdicts(verdicts: &[bool], labels: &[bool]) -> Self {
        assert_eq!(verdicts.len(), labels.len());
        let mut c = Confusion::default();
        for (&v, &l) in verdicts.iter().zip(labels) {
            match (l, v) {
                (true, true) => c.tp += 1,
                (true, false) => c.fn_ += 1,
                (false, true) => c.fp += 1,
                (false, false) => c.tn += 1,
            }
        }
        c
    }

    /// Precision: TP / (TP + FP); 1.0 when nothing was flagged.
    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            1.0
        } else {
            self.tp as f64 / (self.tp + self.fp) as f64
        }
    }

    /// Recall: TP / (TP + FN); 1.0 when there were no positives.
    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            1.0
        } else {
            self.tp as f64 / (self.tp + self.fn_) as f64
        }
    }

    /// F1 (§5.1.3): `TP / (TP + (FP + FN)/2)`; 1.0 for the empty task.
    pub fn f1(&self) -> f64 {
        let denom = self.tp as f64 + 0.5 * (self.fp + self.fn_) as f64;
        if denom == 0.0 {
            1.0
        } else {
            self.tp as f64 / denom
        }
    }

    /// Accuracy.
    pub fn accuracy(&self) -> f64 {
        let total = self.tp + self.fp + self.tn + self.fn_;
        if total == 0 {
            1.0
        } else {
            (self.tp + self.tn) as f64 / total as f64
        }
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tally_and_metrics() {
        let verdicts = [true, true, false, false, true];
        let labels = [true, false, true, false, true];
        let c = Confusion::from_verdicts(&verdicts, &labels);
        assert_eq!(c, Confusion { tp: 2, fp: 1, tn: 1, fn_: 1 });
        assert!((c.precision() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.recall() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.f1() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn degenerate_cases() {
        let empty = Confusion::default();
        assert_eq!(empty.precision(), 1.0);
        assert_eq!(empty.recall(), 1.0);
        assert_eq!(empty.f1(), 1.0);

        let all_negative = Confusion { tn: 10, ..Default::default() };
        assert_eq!(all_negative.f1(), 1.0);

        let misses_everything = Confusion { fn_: 5, tn: 5, ..Default::default() };
        assert_eq!(misses_everything.recall(), 0.0);
        assert_eq!(misses_everything.f1(), 0.0);
    }

    #[test]
    fn f1_is_harmonic_mean() {
        let c = Confusion { tp: 30, fp: 10, fn_: 20, tn: 40 };
        let p = c.precision();
        let r = c.recall();
        let harmonic = 2.0 * p * r / (p + r);
        assert!((c.f1() - harmonic).abs() < 1e-12);
    }
}
