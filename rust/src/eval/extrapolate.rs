//! Scale extrapolation (§5.4.2): Figure 8 runtime projection and the
//! Table 2 index-size comparison.
//!
//! Runtime is modeled as a linear function of document count (the paper's
//! observation that each method scales ~linearly), fit by ordinary least
//! squares over the Fig. 7 measurements. Index sizes are *computed*: the
//! MinHashLSH index grows linearly (fit), while LSHBloom's size is the
//! closed-form `b · m(n, p)` of §4.5.

use crate::bloom::BloomParams;
use crate::minhash::LshParams;

/// Ordinary least-squares line `y = a + b·x`.
#[derive(Clone, Copy, Debug)]
pub struct LinearFit {
    pub intercept: f64,
    pub slope: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

impl LinearFit {
    /// Fit from (x, y) samples. Requires at least two distinct x.
    pub fn fit(points: &[(f64, f64)]) -> Self {
        assert!(points.len() >= 2, "need >= 2 points");
        let n = points.len() as f64;
        let sx: f64 = points.iter().map(|p| p.0).sum();
        let sy: f64 = points.iter().map(|p| p.1).sum();
        let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        assert!(denom.abs() > 0.0, "degenerate x values");
        let slope = (n * sxy - sx * sy) / denom;
        let intercept = (sy - slope * sx) / n;
        let mean_y = sy / n;
        let ss_tot: f64 = points.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
        let ss_res: f64 =
            points.iter().map(|p| (p.1 - (intercept + slope * p.0)).powi(2)).sum();
        let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
        Self { intercept, slope, r2 }
    }

    /// Predict y at x.
    pub fn at(&self, x: f64) -> f64 {
        self.intercept + self.slope * x
    }
}

/// LSHBloom index bytes for `n` docs at `p_effective` with `b` bands
/// (§4.5 closed form — Table 2's "computed exactly" column).
pub fn lshbloom_index_bytes(n: u64, p_effective: f64, lsh: LshParams) -> u64 {
    let p = BloomParams::per_filter_rate(p_effective, lsh.num_bands);
    BloomParams::for_capacity(n, p).bytes() * lsh.num_bands as u64
}

/// MinHashLSH index bytes for `n` docs: per-doc cost of storing each
/// band's key (r hash values of `hash_bytes` each) plus a doc id and
/// framing — the linear model the paper extrapolates. `entry_overhead`
/// defaults to 24 bytes (id + framing), matching our index accounting.
pub fn minhashlsh_index_bytes(n: u64, lsh: LshParams, hash_bytes: u64, entry_overhead: u64) -> u64 {
    let per_doc = lsh.num_bands as u64 * (lsh.rows_per_band as u64 * hash_bytes + entry_overhead);
    n * per_doc
}

/// A Table-2 row: LSHBloom size at a given p_effective vs MinHashLSH.
#[derive(Clone, Debug)]
pub struct StorageRow {
    pub p_effective: f64,
    pub n: u64,
    pub lshbloom_bytes: u64,
    pub minhashlsh_bytes: u64,
}

impl StorageRow {
    /// The space-advantage multiple.
    pub fn advantage(&self) -> f64 {
        self.minhashlsh_bytes as f64 / self.lshbloom_bytes as f64
    }
}

/// Compute Table 2 for the given corpus sizes and p_eff settings.
pub fn table2(
    ns: &[u64],
    p_effs: &[(f64, &str)],
    lsh: LshParams,
    hash_bytes: u64,
) -> Vec<StorageRow> {
    let mut rows = Vec::new();
    for &n in ns {
        for &(p, _) in p_effs {
            rows.push(StorageRow {
                p_effective: p,
                n,
                lshbloom_bytes: lshbloom_index_bytes(n, p, lsh),
                minhashlsh_bytes: minhashlsh_index_bytes(n, lsh, hash_bytes, 24),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ols_recovers_exact_line() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 3.0 + 2.0 * i as f64)).collect();
        let fit = LinearFit::fit(&pts);
        assert!((fit.slope - 2.0).abs() < 1e-9);
        assert!((fit.intercept - 3.0).abs() < 1e-9);
        assert!((fit.r2 - 1.0).abs() < 1e-9);
        assert!((fit.at(100.0) - 203.0).abs() < 1e-9);
    }

    #[test]
    fn ols_handles_noise() {
        let pts: Vec<(f64, f64)> = (0..50)
            .map(|i| {
                let x = i as f64;
                (x, 5.0 * x + if i % 2 == 0 { 1.0 } else { -1.0 })
            })
            .collect();
        let fit = LinearFit::fit(&pts);
        assert!((fit.slope - 5.0).abs() < 0.05);
        assert!(fit.r2 > 0.99);
    }

    #[test]
    fn paper_table2_n100b_column_matches_exactly() {
        // Paper Table 2's N=1e11 column (computed, per §4.5, with the
        // Table-1 tuned geometry T=0.5/P=256 -> b=42): LSHBloom needs
        // 16.66 TB at p_eff=1e-5, 24.21 TB at 1e-8, 31.76 TB at 1/N.
        // Our closed form reproduces all three to three decimals. (The
        // paper's N=5e9 column is internally inconsistent — 8.33 TB at
        // 5e9 vs 16.66 TB at 1e11 is not linear in n as §4.5 requires —
        // so we pin against the self-consistent column; see
        // EXPERIMENTS.md Table 2 notes.)
        let lsh = LshParams { num_bands: 42, rows_per_band: 6 };
        let n = 100_000_000_000u64;
        let tb = |p: f64| lshbloom_index_bytes(n, p, lsh) as f64 / 1e12;
        assert!((tb(1e-5) - 16.66).abs() < 0.05, "1e-5: {} TB", tb(1e-5));
        assert!((tb(1e-8) - 24.21).abs() < 0.05, "1e-8: {} TB", tb(1e-8));
        let inv_n = 1.0 / n as f64;
        assert!((tb(inv_n) - 31.76).abs() < 0.05, "1/N: {} TB", tb(inv_n));
        // MinHashLSH linear model dominates at any sane per-entry cost.
        let mh = minhashlsh_index_bytes(n, lsh, 4, 24) as f64 / 1e12;
        assert!(mh > tb(inv_n), "minhashlsh must dominate: {mh} TB");
    }

    #[test]
    fn advantage_grows_with_smaller_p_nonstrictly() {
        let lsh = LshParams { num_bands: 9, rows_per_band: 13 };
        let rows = table2(
            &[1_000_000_000],
            &[(1e-5, "1e-5"), (1e-8, "1e-8")],
            lsh,
            8,
        );
        assert!(rows[0].advantage() > rows[1].advantage());
        assert!(rows[1].advantage() > 1.0);
    }

    #[test]
    #[should_panic]
    fn fit_rejects_single_point() {
        LinearFit::fit(&[(1.0, 1.0)]);
    }
}
