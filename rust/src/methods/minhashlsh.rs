//! MinHashLSH (§2.3 / §3.3) — the datasketch-style baseline.
//!
//! Prepare: normalize → shingle → MinHash signature (parallel).
//! Decide: hashmap band index query + insert (sequential, pointer-heavy —
//! the structure whose cost Fig. 1 and Fig. 7 quantify).

use super::{Decider, Method, Prepared, Preparer};
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::index::minhashlsh::MinHashLshIndex;
use crate::index::BandIndex;
use crate::minhash::{optimal_param, LshParams, MinHasher, PermFamily};
use crate::text::normalize;
use std::sync::Arc;

/// Parallel stage: full signatures.
pub struct SignaturePreparer {
    pub hasher: MinHasher,
}

impl Preparer for SignaturePreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        docs.iter()
            .map(|d| Prepared::Signature(self.hasher.signature(&normalize(&d.text))))
            .collect()
    }
}

/// Sequential stage: the hashmap band index.
pub struct MinHashLshDecider {
    index: MinHashLshIndex,
    next_id: u64,
}

impl Decider for MinHashLshDecider {
    fn decide(&mut self, prep: &Prepared) -> bool {
        let Prepared::Signature(sig) = prep else {
            panic!("MinHashLshDecider fed non-signature payload");
        };
        let id = self.next_id;
        self.next_id += 1;
        self.index.insert_signature_if_new(id, sig)
    }

    fn disk_bytes(&self) -> u64 {
        self.index.disk_bytes()
    }

    fn len(&self) -> u64 {
        self.index.len()
    }
}

/// Build the MinHashLSH method from pipeline config.
///
/// `family` selects the permutation family; the paper's baseline is
/// datasketch-compatible, which is the default here.
pub fn minhashlsh_method(cfg: &PipelineConfig, family: PermFamily) -> Method {
    let params: LshParams = optimal_param(cfg.threshold, cfg.num_perms);
    let hasher = MinHasher::new(family, params.rows_used(), cfg.ngram);
    Method {
        name: "minhashlsh".to_string(),
        preparer: Arc::new(SignaturePreparer { hasher }),
        decider: Box::new(MinHashLshDecider {
            index: MinHashLshIndex::new(params.num_bands, params.rows_per_band),
            next_id: 0,
        }),
    }
}

/// Calibrated datasketch cost model (see DESIGN.md §Substitutions and
/// EXPERIMENTS.md Fig. 1 notes).
///
/// The paper benchmarks the *Python* datasketch implementation, whose
/// index ops cost ~2.9 ms/doc (37 h / 39 M docs with >85% in the index
/// per Fig. 1) — three orders of magnitude above a native hashmap.
/// Our rust port of the same structure removes that interpreter overhead,
/// which would silently change the baseline. This decider runs the REAL
/// hashmap work plus a busy-wait calibrated to the paper's measured
/// per-document index cost, so Fig. 1/7 can regenerate the paper's
/// end-to-end shape under a documented substitution. The honest
/// rust-normalized comparison is always reported alongside it.
#[derive(Clone, Copy, Debug)]
pub struct PySimCosts {
    /// Simulated index-op nanoseconds per document.
    pub per_doc_index_ns: u64,
}

impl PySimCosts {
    /// Paper-calibrated: 37 h over 39 M docs, 85% index share.
    pub fn paper_calibrated() -> Self {
        Self { per_doc_index_ns: 2_900_000 }
    }
}

/// MinHashLSH with the datasketch interpreter-cost simulation.
pub struct MinHashLshPySimDecider {
    inner: MinHashLshDecider,
    costs: PySimCosts,
}

impl Decider for MinHashLshPySimDecider {
    fn decide(&mut self, prep: &Prepared) -> bool {
        let t0 = std::time::Instant::now();
        let verdict = self.inner.decide(prep);
        // Busy-wait out the remainder of the calibrated per-doc budget
        // (datasketch's Python dict/pickle machinery has no rust analog).
        let budget = std::time::Duration::from_nanos(self.costs.per_doc_index_ns);
        while t0.elapsed() < budget {
            std::hint::spin_loop();
        }
        verdict
    }

    fn disk_bytes(&self) -> u64 {
        // datasketch persists Python-pickled entries: ~5.4 kB/doc measured
        // by the paper (200 GB / 39 M docs, §5.4.1).
        self.inner.disk_bytes().max(self.inner.len() * 5400)
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }
}

/// Build the datasketch-cost-simulated baseline.
pub fn minhashlsh_pysim_method(cfg: &PipelineConfig, family: PermFamily, costs: PySimCosts) -> Method {
    let params: LshParams = optimal_param(cfg.threshold, cfg.num_perms);
    let hasher = MinHasher::new(family, params.rows_used(), cfg.ngram);
    Method {
        name: "minhashlsh-pysim".to_string(),
        preparer: Arc::new(SignaturePreparer { hasher }),
        decider: Box::new(MinHashLshPySimDecider {
            inner: MinHashLshDecider {
                index: MinHashLshIndex::new(params.num_bands, params.rows_per_band),
                next_id: 0,
            },
            costs,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};

    fn small_cfg() -> PipelineConfig {
        PipelineConfig { num_perms: 128, threshold: 0.5, ..Default::default() }
    }

    #[test]
    fn detects_exact_duplicates() {
        let mut m = minhashlsh_method(&small_cfg(), PermFamily::Datasketch);
        let d1 = Doc { id: 0, text: "alpha beta gamma delta epsilon zeta".into() };
        let d2 = Doc { id: 1, text: "alpha beta gamma delta epsilon zeta".into() };
        let d3 = Doc { id: 2, text: "totally different words entirely here now".into() };
        assert!(!m.process(&d1));
        assert!(m.process(&d2), "exact duplicate missed");
        assert!(!m.process(&d3), "distinct doc flagged");
    }

    #[test]
    fn detects_near_duplicates_from_corpus() {
        let corpus = LabeledCorpus::build(DatasetSpec::testing(5, 120, 0.5));
        let mut m = minhashlsh_method(&small_cfg(), PermFamily::Datasketch);
        let verdicts = m.process_all(&corpus.docs);
        // Recall: most labeled duplicates detected.
        let (mut tp, mut fn_, mut fp) = (0, 0, 0);
        for (v, ld) in verdicts.iter().zip(&corpus.docs) {
            match (ld.is_duplicate(), *v) {
                (true, true) => tp += 1,
                (true, false) => fn_ += 1,
                (false, true) => fp += 1,
                _ => {}
            }
        }
        let recall = tp as f64 / (tp + fn_) as f64;
        assert!(recall > 0.6, "recall {recall} (tp={tp} fn={fn_})");
        assert!(fp <= 3, "too many false positives: {fp}");
    }

    #[test]
    fn both_families_work() {
        for fam in [PermFamily::Mix64, PermFamily::Datasketch] {
            let mut m = minhashlsh_method(&small_cfg(), fam);
            let d = Doc { id: 0, text: "repeat me please repeat me please".into() };
            assert!(!m.process(&d));
            assert!(m.process(&d));
        }
    }

    #[test]
    fn disk_grows_with_docs() {
        let mut m = minhashlsh_method(&small_cfg(), PermFamily::Datasketch);
        let g = crate::corpus::CorpusGenerator::new(crate::corpus::GeneratorConfig::short());
        let before = m.decider.disk_bytes();
        for i in 0..50 {
            m.process(&g.generate(33, i));
        }
        assert!(m.decider.disk_bytes() > before);
        assert_eq!(m.decider.len(), 50);
    }
}
