//! Uniform method construction for the evaluation harness.
//!
//! A [`MethodSpec`] names a technique plus its hyperparameters; `build`
//! materializes the [`Method`], estimating Bloom unit budgets from a
//! document sample exactly as §5.1.2 prescribes.

use super::estimate::{estimate_total_units, Unit};
use super::{Method, UnitBudget};
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::minhash::PermFamily;

/// The six techniques (plus the CCNet exact-set ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    MinHashLsh,
    LshBloom,
    Dolma,
    DolmaNgram,
    CcNet,
    CcNetExact,
    Dclm,
}

impl MethodKind {
    /// All paper-benchmarked techniques (Fig. 5 set).
    pub const ALL: [MethodKind; 6] = [
        MethodKind::MinHashLsh,
        MethodKind::LshBloom,
        MethodKind::Dolma,
        MethodKind::DolmaNgram,
        MethodKind::CcNet,
        MethodKind::Dclm,
    ];

    /// Display name (matches the paper's figures).
    pub fn name(self) -> &'static str {
        match self {
            MethodKind::MinHashLsh => "minhashlsh",
            MethodKind::LshBloom => "lshbloom",
            MethodKind::Dolma => "dolma",
            MethodKind::DolmaNgram => "dolma-ngram",
            MethodKind::CcNet => "ccnet",
            MethodKind::CcNetExact => "ccnet-exact",
            MethodKind::Dclm => "dclm",
        }
    }

    /// Parse from a CLI token.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "minhashlsh" => MethodKind::MinHashLsh,
            "lshbloom" => MethodKind::LshBloom,
            "dolma" => MethodKind::Dolma,
            "dolma-ngram" => MethodKind::DolmaNgram,
            "ccnet" => MethodKind::CcNet,
            "ccnet-exact" => MethodKind::CcNetExact,
            "dclm" => MethodKind::Dclm,
            _ => return None,
        })
    }
}

/// A technique plus hyperparameters (one grid point).
#[derive(Clone, Debug)]
pub struct MethodSpec {
    pub kind: MethodKind,
    /// Overlap / Jaccard threshold T.
    pub threshold: f64,
    /// MinHash permutations (LSH methods).
    pub num_perms: usize,
    /// N-gram size (LSH shingles and n-gram unit methods).
    pub ngram: usize,
    /// Index-wide p_effective (LSHBloom).
    pub p_effective: f64,
    /// Unit-method Bloom FP rate (§5.1.5: 1e-5).
    pub unit_fp: f64,
    /// Expected corpus size in documents.
    pub expected_docs: u64,
    /// MinHash permutation family.
    pub family: PermFamily,
}

impl MethodSpec {
    /// Table-1 best settings for a technique.
    pub fn best(kind: MethodKind, expected_docs: u64) -> Self {
        let (threshold, ngram) = match kind {
            MethodKind::MinHashLsh | MethodKind::LshBloom => (0.5, 1),
            MethodKind::DolmaNgram | MethodKind::Dclm => (0.2, 5),
            MethodKind::Dolma | MethodKind::CcNet | MethodKind::CcNetExact => (0.2, 1),
        };
        Self {
            kind,
            threshold,
            num_perms: 256,
            ngram,
            p_effective: 1e-5,
            unit_fp: UnitBudget::DEFAULT_FP,
            expected_docs,
            family: PermFamily::Mix64,
        }
    }

    /// Build the method; `sample` is used for §5.1.2 unit estimation
    /// (pass any representative slice of the corpus, e.g. the first 1000).
    pub fn build(&self, sample: &[Doc]) -> Method {
        let cfg = PipelineConfig {
            threshold: self.threshold,
            num_perms: self.num_perms,
            ngram: self.ngram,
            p_effective: self.p_effective,
            expected_docs: self.expected_docs,
            ..Default::default()
        };
        let budget = |unit: Unit| {
            UnitBudget {
                expected_units: estimate_total_units(
                    sample.iter(),
                    1000,
                    self.expected_docs,
                    unit,
                )
                .max(1),
                fp_rate: self.unit_fp,
            }
        };
        match self.kind {
            MethodKind::MinHashLsh => super::minhashlsh::minhashlsh_method(&cfg, self.family),
            MethodKind::LshBloom => super::lshbloom::lshbloom_method(&cfg, self.family),
            MethodKind::Dolma => super::dolma::dolma_method(self.threshold, budget(Unit::Paragraphs)),
            MethodKind::DolmaNgram => super::dolma_ngram::dolma_ngram_method(
                self.ngram,
                self.threshold,
                budget(Unit::WhitespaceNgrams(self.ngram)),
            ),
            MethodKind::CcNet => super::ccnet::ccnet_method(self.threshold, budget(Unit::Paragraphs)),
            MethodKind::CcNetExact => super::ccnet::ccnet_exact_method(self.threshold),
            MethodKind::Dclm => super::dclm::dclm_method(
                self.ngram,
                self.threshold,
                budget(Unit::UnisegNgrams(self.ngram)),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, GeneratorConfig};

    #[test]
    fn every_kind_builds_and_runs() {
        let g = CorpusGenerator::new(GeneratorConfig::short());
        let sample: Vec<Doc> = (0..20).map(|i| g.generate(99, i)).collect();
        for kind in MethodKind::ALL {
            let spec = MethodSpec::best(kind, 1000);
            let mut m = spec.build(&sample);
            assert_eq!(m.name, kind.name());
            let d = g.generate(99, 100);
            assert!(!m.process(&d), "{}: fresh doc flagged", m.name);
            assert!(m.process(&d), "{}: exact dup missed", m.name);
        }
    }

    #[test]
    fn kind_roundtrip() {
        for kind in MethodKind::ALL {
            assert_eq!(MethodKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(MethodKind::parse("nope"), None);
    }
}
