//! Dolma paragraph-level deduplication (§3.3), document-level extension
//! (§5.1.2).
//!
//! Paragraphs are exact-matched against a single Bloom filter; a document
//! is a duplicate when the fraction of its *text* (characters) belonging
//! to duplicated paragraphs exceeds the overlap threshold `T`.
//!
//! Within-document handling: all paragraphs are queried first, then
//! inserted, so a document repeating its own paragraph is not
//! self-matching.

use super::{Decider, Method, Prepared, Preparer, UnitBudget};
use crate::bloom::BloomFilter;
use crate::corpus::Doc;
use crate::hash::fast_str_hash;
use crate::text::{normalize, paragraphs};
use std::sync::Arc;

/// Parallel stage: normalized-paragraph keys weighted by char length.
pub struct ParagraphPreparer;

impl Preparer for ParagraphPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        docs.iter()
            .map(|d| {
                let keys: Vec<(u64, u32)> = paragraphs(&d.text)
                    .into_iter()
                    .map(|p| {
                        let norm = normalize(p);
                        (fast_str_hash(norm.as_bytes()), norm.chars().count() as u32)
                    })
                    .collect();
                Prepared::WeightedKeys(keys)
            })
            .collect()
    }
}

/// Sequential stage: single Bloom filter over paragraph keys.
pub struct DolmaDecider {
    filter: BloomFilter,
    threshold: f64,
    docs: u64,
}

impl Decider for DolmaDecider {
    fn decide(&mut self, prep: &Prepared) -> bool {
        let Prepared::WeightedKeys(keys) = prep else {
            panic!("DolmaDecider fed wrong payload");
        };
        self.docs += 1;
        if keys.is_empty() {
            return false;
        }
        // Query all first (avoid within-doc self matches) …
        let total: u64 = keys.iter().map(|&(_, w)| w as u64).sum();
        let dup: u64 = keys
            .iter()
            .filter(|&&(k, _)| self.filter.contains(k))
            .map(|&(_, w)| w as u64)
            .sum();
        // … then insert.
        for &(k, _) in keys {
            self.filter.insert(k);
        }
        total > 0 && (dup as f64 / total as f64) >= self.threshold
    }

    fn disk_bytes(&self) -> u64 {
        self.filter.size_bytes()
    }

    fn len(&self) -> u64 {
        self.docs
    }
}

/// Build Dolma (paragraph-level) with a unit budget for filter sizing.
pub fn dolma_method(threshold: f64, budget: UnitBudget) -> Method {
    Method {
        name: "dolma".to_string(),
        preparer: Arc::new(ParagraphPreparer),
        decider: Box::new(DolmaDecider {
            filter: BloomFilter::with_capacity(budget.expected_units, budget.fp_rate),
            threshold,
            docs: 0,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> Doc {
        Doc { id: 0, text: text.to_string() }
    }

    #[test]
    fn exact_duplicate_document_detected() {
        let mut m = dolma_method(0.2, UnitBudget::new(10_000));
        let d = doc("first paragraph here\nsecond paragraph text\nthird one");
        assert!(!m.process(&d));
        assert!(m.process(&d));
    }

    #[test]
    fn partial_overlap_respects_threshold() {
        let mut m = dolma_method(0.6, UnitBudget::new(10_000));
        m.process(&doc("shared paragraph alpha\nshared paragraph beta"));
        // One of three paragraphs shared (~1/3 of chars) < 0.6 threshold.
        assert!(!m.process(&doc(
            "shared paragraph alpha\nnovel paragraph content one\nnovel paragraph content two"
        )));
        // Two of two shared >= 0.6.
        assert!(m.process(&doc("shared paragraph alpha\nshared paragraph beta")));
    }

    #[test]
    fn weighting_is_by_characters_not_count() {
        let mut m = dolma_method(0.5, UnitBudget::new(10_000));
        let long = "x".repeat(400);
        m.process(&doc(&format!("{long}\nshort one")));
        // New doc: shares only the LONG paragraph -> >50% of chars dup.
        assert!(m.process(&doc(&format!("{long}\nbrand new tail"))));
        // New doc sharing only the SHORT paragraph -> far below 50%.
        let long2 = "y".repeat(400);
        assert!(!m.process(&doc(&format!("{long2}\nshort one"))));
    }

    #[test]
    fn within_doc_repetition_is_not_self_duplicate() {
        let mut m = dolma_method(0.2, UnitBudget::new(10_000));
        assert!(!m.process(&doc("same line\nsame line\nsame line")));
    }

    #[test]
    fn empty_document_is_not_duplicate() {
        let mut m = dolma_method(0.2, UnitBudget::new(100));
        assert!(!m.process(&doc("")));
        assert!(!m.process(&doc("\n\n")));
    }

    #[test]
    fn normalization_bridges_parser_variants() {
        let mut m = dolma_method(0.2, UnitBudget::new(10_000));
        m.process(&doc("The E\u{FB03}cient   Method"));
        assert!(m.process(&doc("the efficient method")));
    }
}
