//! CCNet deduplication (§3.3), document-level extension (§5.1.2).
//!
//! CCNet lowercases, strips special unicode, splits on newlines, and
//! SHA-1-hashes each unit; duplicates are exact hash matches. Extended to
//! the document level per the paper: a document is a duplicate when the
//! fraction of its paragraphs already seen exceeds the threshold.
//!
//! The membership structure is a single Bloom filter (the paper
//! normalizes Bloom-filter modules across techniques, §5.1.2); an exact
//! `HashSet` variant is provided for ablation.

use super::{Decider, Method, Prepared, Preparer, UnitBudget};
use crate::bloom::BloomFilter;
use crate::corpus::Doc;
use crate::hash::sha1::Sha1;
use crate::text::{normalize, paragraphs};
use std::collections::HashSet;
use std::sync::Arc;

/// Parallel stage: SHA-1 (low-8) keys of normalized paragraphs.
pub struct CcnetPreparer;

impl Preparer for CcnetPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        docs.iter()
            .map(|d| {
                let keys: Vec<u64> = paragraphs(&d.text)
                    .into_iter()
                    .map(|p| {
                        let digest = Sha1::digest(normalize(p).as_bytes());
                        u64::from_le_bytes(digest[..8].try_into().unwrap())
                    })
                    .collect();
                Prepared::Keys(keys)
            })
            .collect()
    }
}

/// Paragraph-fraction decider over a Bloom filter or exact set.
pub struct CcnetDecider {
    filter: Membership,
    threshold: f64,
    docs: u64,
}

enum Membership {
    Bloom(BloomFilter),
    Exact(HashSet<u64>),
}

impl Membership {
    fn contains(&self, k: u64) -> bool {
        match self {
            Membership::Bloom(f) => f.contains(k),
            Membership::Exact(s) => s.contains(&k),
        }
    }

    fn insert(&mut self, k: u64) {
        match self {
            Membership::Bloom(f) => {
                f.insert(k);
            }
            Membership::Exact(s) => {
                s.insert(k);
            }
        }
    }

    fn disk_bytes(&self) -> u64 {
        match self {
            Membership::Bloom(f) => f.size_bytes(),
            // Exact set serialized as raw 8-byte hashes.
            Membership::Exact(s) => (s.len() * 8) as u64,
        }
    }
}

impl Decider for CcnetDecider {
    fn decide(&mut self, prep: &Prepared) -> bool {
        let Prepared::Keys(keys) = prep else {
            panic!("CcnetDecider fed wrong payload");
        };
        self.docs += 1;
        if keys.is_empty() {
            return false;
        }
        let dup = keys.iter().filter(|&&k| self.filter.contains(k)).count();
        for &k in keys {
            self.filter.insert(k);
        }
        (dup as f64 / keys.len() as f64) >= self.threshold
    }

    fn disk_bytes(&self) -> u64 {
        self.filter.disk_bytes()
    }

    fn len(&self) -> u64 {
        self.docs
    }
}

/// Build CCNet with the normalized Bloom-filter membership structure.
pub fn ccnet_method(threshold: f64, budget: UnitBudget) -> Method {
    Method {
        name: "ccnet".to_string(),
        preparer: Arc::new(CcnetPreparer),
        decider: Box::new(CcnetDecider {
            filter: Membership::Bloom(BloomFilter::with_capacity(
                budget.expected_units,
                budget.fp_rate,
            )),
            threshold,
            docs: 0,
        }),
    }
}

/// Exact-set ablation variant (original CCNet semantics).
pub fn ccnet_exact_method(threshold: f64) -> Method {
    Method {
        name: "ccnet-exact".to_string(),
        preparer: Arc::new(CcnetPreparer),
        decider: Box::new(CcnetDecider {
            filter: Membership::Exact(HashSet::new()),
            threshold,
            docs: 0,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> Doc {
        Doc { id: 0, text: text.to_string() }
    }

    #[test]
    fn exact_duplicate_detected_both_variants() {
        for mut m in [ccnet_method(0.2, UnitBudget::new(10_000)), ccnet_exact_method(0.2)] {
            let d = doc("paragraph alpha content\nparagraph beta content");
            assert!(!m.process(&d), "{}", m.name);
            assert!(m.process(&d), "{}", m.name);
        }
    }

    #[test]
    fn paragraph_fraction_thresholding() {
        let mut m = ccnet_method(0.5, UnitBudget::new(10_000));
        m.process(&doc("p one\np two\np three\np four"));
        // 1/4 shared < 0.5.
        assert!(!m.process(&doc("p one\nnew a\nnew b\nnew c")));
        // 3/4 shared >= 0.5.
        assert!(m.process(&doc("p one\np two\np three\nnew d")));
    }

    #[test]
    fn exact_matching_is_not_robust_to_noise() {
        // The paper's point: CCNet only catches byte-identical units.
        let mut m = ccnet_exact_method(0.2);
        m.process(&doc("the measurement was performed at cryogenic temperature"));
        assert!(!m.process(&doc("the rneasurement was perforrned at cryogenic ternperature")));
    }

    #[test]
    fn bloom_and_exact_agree_on_clean_data() {
        let mut a = ccnet_method(0.2, UnitBudget::new(10_000));
        let mut b = ccnet_exact_method(0.2);
        let g = crate::corpus::CorpusGenerator::new(crate::corpus::GeneratorConfig::short());
        for i in 0..60 {
            let d = g.generate(55, i % 30); // every doc repeats once
            assert_eq!(a.process(&d), b.process(&d), "doc {i}");
        }
    }
}
