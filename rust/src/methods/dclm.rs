//! DataComp-LM document-level deduplication (§3.3).
//!
//! Same Bloom-filter n-gram vote as Dolma-Ngram but tokenized with the
//! UniSeg-style Unicode segmenter — the difference the paper credits for
//! DCLM's better fidelity (§5.2.2). (The paper's BFF also removes
//! duplicated paragraphs in-place; for the document-level comparison of
//! §5.1.2 only the document verdict matters.)

use super::dolma_ngram::NgramBloomDecider;
use super::{Method, Prepared, Preparer, UnitBudget};
use crate::bloom::BloomFilter;
use crate::corpus::Doc;
use crate::hash::fast_str_hash;
use crate::text::{ngram::word_ngrams, normalize, tokenize::uniseg_words};
use std::sync::Arc;

/// Parallel stage: uniseg n-gram keys.
pub struct UnisegNgramPreparer {
    pub n: usize,
}

impl Preparer for UnisegNgramPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        docs.iter()
            .map(|d| {
                let norm = normalize(&d.text);
                let tokens = uniseg_words(&norm);
                let mut keys = Vec::with_capacity(tokens.len());
                word_ngrams(&tokens, self.n, |g| keys.push(fast_str_hash(g.as_bytes())));
                Prepared::Keys(keys)
            })
            .collect()
    }
}

/// Build DCLM.
pub fn dclm_method(n: usize, threshold: f64, budget: UnitBudget) -> Method {
    Method {
        name: "dclm".to_string(),
        preparer: Arc::new(UnisegNgramPreparer { n }),
        decider: Box::new(NgramBloomDecider {
            filter: BloomFilter::with_capacity(budget.expected_units, budget.fp_rate),
            threshold,
            docs: 0,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> Doc {
        Doc { id: 0, text: text.to_string() }
    }

    #[test]
    fn exact_duplicate_detected() {
        let mut m = dclm_method(5, 0.2, UnitBudget::new(100_000));
        let d = doc("measurement of the cross section in proton collisions at high energy");
        assert!(!m.process(&d));
        assert!(m.process(&d));
    }

    #[test]
    fn uniseg_tokenization_is_punctuation_robust() {
        // Same content, different spacing around punctuation: DCLM (uniseg)
        // should still match; Dolma-Ngram (whitespace) should not.
        let a = "results show p<0.05 for the primary endpoint, confirming the effect size";
        let b = "results show p < 0.05 for the primary endpoint , confirming the effect size";
        let mut dclm = dclm_method(5, 0.6, UnitBudget::new(100_000));
        dclm.process(&doc(a));
        assert!(dclm.process(&doc(b)), "uniseg should bridge spacing variants");

        let mut dn = super::super::dolma_ngram::dolma_ngram_method(5, 0.6, UnitBudget::new(100_000));
        dn.process(&doc(a));
        assert!(!dn.process(&doc(b)), "whitespace n-grams should not");
    }

    #[test]
    fn distinct_documents_pass() {
        let mut m = dclm_method(5, 0.2, UnitBudget::new(100_000));
        assert!(!m.process(&doc("entirely original first document about enzymes and catalysis")));
        assert!(!m.process(&doc("second manuscript concerning tectonic plate motion and seismics")));
    }
}
