//! LSHBloom (§4) — the paper's method.
//!
//! Prepare: normalize → shingle → MinHash signature → band sum-hashes
//! (parallel; or batched through the XLA artifact — see
//! `crate::runtime::minhash_xla::XlaBandPreparer`).
//! Decide: probe/insert `b` Bloom filters (sequential, contiguous
//! bit-array access — the §4.5 throughput story).

use super::{Decider, Method, Prepared, Preparer};
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::hash::band::band_hashes_for_doc;
use crate::index::lshbloom::{LshBloomConfig, LshBloomIndex};
use crate::index::BandIndex;
use crate::minhash::{optimal_param, LshParams, MinHasher, PermFamily};
use crate::text::normalize;
use std::sync::Arc;

/// Parallel stage: band sum-hashes via the native backend.
pub struct BandPreparer {
    pub hasher: MinHasher,
    pub lsh: LshParams,
}

impl BandPreparer {
    /// Native (Mix64) preparer with the config's band geometry — the one
    /// construction every engine / server / bench site must share so
    /// band hashes stay bit-identical across them.
    pub fn from_config(cfg: &PipelineConfig) -> Self {
        let lsh = optimal_param(cfg.threshold, cfg.num_perms);
        Self { hasher: MinHasher::new(PermFamily::Mix64, lsh.rows_used(), cfg.ngram), lsh }
    }
}

impl Preparer for BandPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        let mut out = Vec::with_capacity(docs.len());
        let mut bands = Vec::with_capacity(self.lsh.num_bands);
        for d in docs {
            let sig = self.hasher.signature(&normalize(&d.text));
            band_hashes_for_doc(&sig, self.lsh.num_bands, self.lsh.rows_per_band, &mut bands);
            out.push(Prepared::Bands(bands.clone()));
        }
        out
    }
}

/// Sequential stage: the per-band Bloom index.
pub struct LshBloomDecider {
    index: LshBloomIndex,
}

impl LshBloomDecider {
    /// Expose the index (persistence, diagnostics).
    pub fn index(&self) -> &LshBloomIndex {
        &self.index
    }

    /// Take the index out (for saving at end of run).
    pub fn into_index(self) -> LshBloomIndex {
        self.index
    }
}

impl Decider for LshBloomDecider {
    fn decide(&mut self, prep: &Prepared) -> bool {
        let Prepared::Bands(bands) = prep else {
            panic!("LshBloomDecider fed non-bands payload");
        };
        self.index.insert_if_new(bands)
    }

    fn disk_bytes(&self) -> u64 {
        self.index.disk_bytes()
    }

    fn len(&self) -> u64 {
        self.index.len()
    }
}

/// Build LSHBloom with the native backend.
pub fn lshbloom_method(cfg: &PipelineConfig, family: PermFamily) -> Method {
    let lsh = optimal_param(cfg.threshold, cfg.num_perms);
    let hasher = MinHasher::new(family, lsh.rows_used(), cfg.ngram);
    Method {
        name: "lshbloom".to_string(),
        preparer: Arc::new(BandPreparer { hasher, lsh }),
        decider: Box::new(decider_from_config(cfg, lsh)),
    }
}

/// Build just the decider (shared by the XLA-preparer variant).
pub fn decider_from_config(cfg: &PipelineConfig, lsh: LshParams) -> LshBloomDecider {
    let index_cfg = LshBloomConfig {
        lsh,
        p_effective: cfg.p_effective,
        expected_docs: cfg.expected_docs,
        blocked: cfg.blocked_bloom && !cfg.use_shm,
    };
    let index = if cfg.use_shm {
        let dir = crate::bloom::shm::default_shm_dir().join(format!(
            "lshbloom-{}-{}",
            std::process::id(),
            lsh.num_bands
        ));
        LshBloomIndex::new_shm(index_cfg, &dir).unwrap_or_else(|e| {
            crate::log_warn!("shm index unavailable ({e}); falling back to heap");
            LshBloomIndex::new(index_cfg)
        })
    } else {
        LshBloomIndex::new(index_cfg)
    };
    LshBloomDecider { index }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};

    fn cfg() -> PipelineConfig {
        PipelineConfig {
            num_perms: 128,
            threshold: 0.5,
            expected_docs: 10_000,
            ..Default::default()
        }
    }

    #[test]
    fn detects_exact_and_rejects_distinct() {
        let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
        let d1 = Doc { id: 0, text: "the quick brown fox jumps over the lazy dog".into() };
        let d2 = d1.clone();
        let d3 = Doc { id: 2, text: "completely unrelated content with other words".into() };
        assert!(!m.process(&d1));
        assert!(m.process(&d2));
        assert!(!m.process(&d3));
    }

    #[test]
    fn tracks_minhashlsh_verdicts_closely() {
        // The paper's core fidelity claim: LSHBloom ≈ MinHashLSH. Same
        // family + same corpus -> nearly identical verdict vectors.
        let corpus = LabeledCorpus::build(DatasetSpec::testing(13, 150, 0.5));
        let mut lshb = lshbloom_method(&cfg(), PermFamily::Mix64);
        let mut mlsh = super::super::minhashlsh::minhashlsh_method(&cfg(), PermFamily::Mix64);
        let va = lshb.process_all(&corpus.docs);
        let vb = mlsh.process_all(&corpus.docs);
        let agree = va.iter().zip(&vb).filter(|(a, b)| a == b).count();
        let agreement = agree as f64 / va.len() as f64;
        assert!(agreement > 0.97, "agreement {agreement}");
    }

    #[test]
    fn disk_is_fixed_by_capacity_not_docs() {
        let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
        let before = m.decider.disk_bytes();
        let g = crate::corpus::CorpusGenerator::new(crate::corpus::GeneratorConfig::short());
        for i in 0..100 {
            m.process(&g.generate(21, i));
        }
        assert_eq!(m.decider.disk_bytes(), before, "bloom index size is static");
    }

    #[test]
    fn shm_variant_constructs() {
        let mut c = cfg();
        c.use_shm = true;
        let mut m = lshbloom_method(&c, PermFamily::Mix64);
        let d = Doc { id: 0, text: "shm backed bloom filter test".into() };
        assert!(!m.process(&d));
        assert!(m.process(&d));
    }
}
