//! Corpus unit-count estimation (§5.1.2).
//!
//! Bloom-based unit methods need an expected n-gram/paragraph count to
//! size their filter. Counting exactly requires a full pass, so the paper
//! samples N=1000 documents, takes the mean unit count, and multiplies by
//! the corpus cardinality. Reproduced here over any doc iterator.

use crate::corpus::Doc;
use crate::text::{ngram::word_ngrams, normalize, paragraphs, tokenize};

/// What to count per document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    /// Newline paragraphs (Dolma, CCNet).
    Paragraphs,
    /// Whitespace-token n-grams of size n (Dolma-Ngram).
    WhitespaceNgrams(usize),
    /// Uniseg-token n-grams of size n (DCLM).
    UnisegNgrams(usize),
}

/// Count units in one document.
pub fn count_units(doc: &Doc, unit: Unit) -> u64 {
    match unit {
        Unit::Paragraphs => paragraphs(&doc.text).len() as u64,
        Unit::WhitespaceNgrams(n) => {
            let norm = normalize(&doc.text);
            let tokens: Vec<&str> = tokenize::whitespace_tokens(&norm).collect();
            let mut c = 0u64;
            word_ngrams(&tokens, n, |_| c += 1);
            c
        }
        Unit::UnisegNgrams(n) => {
            let norm = normalize(&doc.text);
            let tokens = tokenize::uniseg_words(&norm);
            let mut c = 0u64;
            word_ngrams(&tokens, n, |_| c += 1);
            c
        }
    }
}

/// §5.1.2 estimator: mean unit count over a sample of up to
/// `sample_size` docs (paper: 1000), scaled to `total_docs`.
pub fn estimate_total_units<'a, I>(sample: I, sample_size: usize, total_docs: u64, unit: Unit) -> u64
where
    I: IntoIterator<Item = &'a Doc>,
{
    let mut n = 0u64;
    let mut total = 0u64;
    for doc in sample.into_iter().take(sample_size) {
        total += count_units(doc, unit);
        n += 1;
    }
    if n == 0 {
        return total_docs; // degenerate fallback: 1 unit/doc
    }
    let mean = total as f64 / n as f64;
    (mean * total_docs as f64).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, GeneratorConfig};

    fn sample_docs(n: usize) -> Vec<Doc> {
        let g = CorpusGenerator::new(GeneratorConfig::short());
        (0..n as u64).map(|i| g.generate(77, i)).collect()
    }

    #[test]
    fn estimator_close_to_exact_on_uniform_corpus() {
        let docs = sample_docs(400);
        for unit in [Unit::Paragraphs, Unit::WhitespaceNgrams(5), Unit::UnisegNgrams(5)] {
            let exact: u64 = docs.iter().map(|d| count_units(d, unit)).sum();
            let est = estimate_total_units(docs.iter().take(100), 100, 400, unit);
            let rel = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(rel < 0.15, "{unit:?}: est {est} vs exact {exact} (rel {rel:.3})");
        }
    }

    #[test]
    fn ngram_counts_shrink_with_n() {
        let docs = sample_docs(10);
        let c1: u64 = docs.iter().map(|d| count_units(d, Unit::WhitespaceNgrams(1))).sum();
        let c5: u64 = docs.iter().map(|d| count_units(d, Unit::WhitespaceNgrams(5))).sum();
        assert!(c1 > c5);
    }

    #[test]
    fn uniseg_yields_more_tokens_than_whitespace() {
        // Punctuation splitting produces more unigrams.
        let docs = sample_docs(10);
        let w: u64 = docs.iter().map(|d| count_units(d, Unit::WhitespaceNgrams(1))).sum();
        let u: u64 = docs.iter().map(|d| count_units(d, Unit::UnisegNgrams(1))).sum();
        assert!(u >= w);
    }

    #[test]
    fn empty_sample_fallback() {
        assert_eq!(estimate_total_units([].iter(), 1000, 500, Unit::Paragraphs), 500);
    }
}
