//! Dolma-Ngram (§3.3): whitespace-tokenized n-grams against a single
//! Bloom filter; a document is a duplicate when the fraction of its
//! n-grams already present exceeds the overlap threshold `T`.

use super::{Decider, Method, Prepared, Preparer, UnitBudget};
use crate::bloom::BloomFilter;
use crate::corpus::Doc;
use crate::hash::fast_str_hash;
use crate::text::{ngram::word_ngrams, normalize, tokenize::whitespace_tokens};
use std::sync::Arc;

/// Parallel stage: whitespace n-gram keys.
pub struct WhitespaceNgramPreparer {
    pub n: usize,
}

impl Preparer for WhitespaceNgramPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        docs.iter()
            .map(|d| {
                let norm = normalize(&d.text);
                let tokens: Vec<&str> = whitespace_tokens(&norm).collect();
                let mut keys = Vec::with_capacity(tokens.len());
                word_ngrams(&tokens, self.n, |g| keys.push(fast_str_hash(g.as_bytes())));
                Prepared::Keys(keys)
            })
            .collect()
    }
}

/// Sequential stage: fraction-duplicated vote against one Bloom filter.
/// Shared by Dolma-Ngram and DCLM (they differ only in tokenization).
pub struct NgramBloomDecider {
    pub(crate) filter: BloomFilter,
    pub(crate) threshold: f64,
    pub(crate) docs: u64,
}

impl Decider for NgramBloomDecider {
    fn decide(&mut self, prep: &Prepared) -> bool {
        let Prepared::Keys(keys) = prep else {
            panic!("NgramBloomDecider fed wrong payload");
        };
        self.docs += 1;
        if keys.is_empty() {
            return false;
        }
        // Query all n-grams first, then insert (no self-matching).
        let dup = keys.iter().filter(|&&k| self.filter.contains(k)).count();
        for &k in keys {
            self.filter.insert(k);
        }
        (dup as f64 / keys.len() as f64) >= self.threshold
    }

    fn disk_bytes(&self) -> u64 {
        self.filter.size_bytes()
    }

    fn len(&self) -> u64 {
        self.docs
    }
}

/// Build Dolma-Ngram.
pub fn dolma_ngram_method(n: usize, threshold: f64, budget: UnitBudget) -> Method {
    Method {
        name: "dolma-ngram".to_string(),
        preparer: Arc::new(WhitespaceNgramPreparer { n }),
        decider: Box::new(NgramBloomDecider {
            filter: BloomFilter::with_capacity(budget.expected_units, budget.fp_rate),
            threshold,
            docs: 0,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> Doc {
        Doc { id: 0, text: text.to_string() }
    }

    #[test]
    fn exact_duplicate_detected() {
        let mut m = dolma_ngram_method(5, 0.2, UnitBudget::new(100_000));
        let d = doc("one two three four five six seven eight nine ten eleven twelve");
        assert!(!m.process(&d));
        assert!(m.process(&d));
    }

    #[test]
    fn distinct_documents_pass() {
        let mut m = dolma_ngram_method(5, 0.2, UnitBudget::new(100_000));
        assert!(!m.process(&doc("alpha beta gamma delta epsilon zeta eta theta")));
        assert!(!m.process(&doc("iota kappa lambda mu nu xi omicron pi rho")));
    }

    #[test]
    fn threshold_controls_sensitivity() {
        let shared = "the method achieves strong results on every benchmark suite tested";
        let tail = "but the analysis requires care regarding confounders and baselines";
        let mut strict = dolma_ngram_method(5, 0.9, UnitBudget::new(100_000));
        strict.process(&doc(shared));
        assert!(!strict.process(&doc(&format!("{shared} {tail}"))), "strict T");
        let mut loose = dolma_ngram_method(5, 0.2, UnitBudget::new(100_000));
        loose.process(&doc(shared));
        assert!(loose.process(&doc(&format!("{shared} {tail}"))), "loose T");
    }

    #[test]
    fn short_doc_single_shingle() {
        let mut m = dolma_ngram_method(13, 0.2, UnitBudget::new(1000));
        assert!(!m.process(&doc("tiny doc")));
        assert!(m.process(&doc("tiny doc")));
    }
}
