//! The six deduplication techniques the paper benchmarks (§3.3, §5.1.2).
//!
//! Every method is expressed as a two-stage object, mirroring the paper's
//! pipeline phases (Fig. 1):
//!
//! * a [`Preparer`] (stateless, `Sync`) — the *parallelizable* per-document
//!   work: normalization, shingling, MinHashing / paragraph hashing.
//! * a [`Decider`] (stateful, sequential) — the *index* work: query the
//!   method's structure for a duplicate verdict and insert the document.
//!
//! The orchestrator fans [`Preparer::prepare_batch`] out across worker
//! threads and runs [`Decider::decide`] on the single insert thread
//! (§4.4.2: index insertion must be sequential to keep the streaming
//! duplicate semantics exact).
//!
//! | Method        | Prepared payload                  | Decider state              |
//! |---------------|-----------------------------------|----------------------------|
//! | MinHashLSH    | full MinHash signature            | hashmap band index         |
//! | LSHBloom      | band sum-hashes                   | per-band Bloom filters     |
//! | Dolma         | paragraph keys + char weights     | single Bloom filter        |
//! | Dolma-Ngram   | whitespace n-gram keys            | single Bloom filter        |
//! | CCNet         | normalized-paragraph SHA-1 keys   | single Bloom filter        |
//! | DCLM          | uniseg n-gram keys                | single Bloom filter        |

pub mod ccnet;
pub mod dclm;
pub mod dolma;
pub mod dolma_ngram;
pub mod estimate;
pub mod factory;
pub mod lshbloom;
pub mod minhashlsh;

pub use factory::{MethodKind, MethodSpec};

use crate::corpus::Doc;
use std::sync::Arc;

/// Per-document intermediate produced by the parallel stage.
#[derive(Clone, Debug)]
pub enum Prepared {
    /// Full MinHash signature (MinHashLSH).
    Signature(Vec<u64>),
    /// Band sum-hashes (LSHBloom).
    Bands(Vec<u64>),
    /// Unit keys with weights: (key, weight) — e.g. paragraph hash with
    /// its character count (Dolma weights overlap by text length).
    WeightedKeys(Vec<(u64, u32)>),
    /// Unweighted unit keys (n-grams, paragraphs counted equally).
    Keys(Vec<u64>),
}

impl Prepared {
    /// Number of units in the payload (diagnostics).
    pub fn len(&self) -> usize {
        match self {
            Prepared::Signature(v) | Prepared::Bands(v) | Prepared::Keys(v) => v.len(),
            Prepared::WeightedKeys(v) => v.len(),
        }
    }

    /// True when the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Stateless, thread-shareable per-document preparation.
pub trait Preparer: Send + Sync {
    /// Prepare a batch of documents (batched so the XLA backend can run
    /// one artifact execution per batch).
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared>;
}

/// Sequential duplicate decision + state update.
pub trait Decider: Send {
    /// Atomically query-and-insert; `true` = duplicate (§2.1's F(d_i)).
    fn decide(&mut self, prep: &Prepared) -> bool;

    /// Current index footprint in bytes (Fig. 6b / 7b metric).
    fn disk_bytes(&self) -> u64;

    /// Documents processed.
    fn len(&self) -> u64;

    /// True when no documents have been processed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A complete deduplication method: name + the two stages.
pub struct Method {
    pub name: String,
    pub preparer: Arc<dyn Preparer>,
    pub decider: Box<dyn Decider>,
}

impl Method {
    /// Convenience for tests / single-threaded evaluation: process one
    /// document through both stages.
    pub fn process(&mut self, doc: &Doc) -> bool {
        let prepared = self.preparer.prepare_batch(std::slice::from_ref(doc));
        self.decider.decide(&prepared[0])
    }

    /// Process a full labeled corpus sequentially, returning per-doc
    /// verdicts (the simple evaluation path; the pipeline module provides
    /// the parallel one).
    pub fn process_all(&mut self, docs: &[crate::corpus::LabeledDoc]) -> Vec<bool> {
        docs.iter().map(|ld| self.process(&ld.doc)).collect()
    }
}

/// Count-estimation inputs shared by Bloom-based unit methods (§5.1.2):
/// expected number of unit insertions, used to size the filter.
#[derive(Clone, Copy, Debug)]
pub struct UnitBudget {
    /// Expected total units (n-grams / paragraphs) across the corpus.
    pub expected_units: u64,
    /// Per-filter false-positive rate (paper: 1e-5 for unit methods).
    pub fp_rate: f64,
}

impl UnitBudget {
    /// Default unit-method FP rate from §5.1.5.
    pub const DEFAULT_FP: f64 = 1e-5;

    /// Construct with the default rate.
    pub fn new(expected_units: u64) -> Self {
        Self { expected_units: expected_units.max(1), fp_rate: Self::DEFAULT_FP }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepared_len() {
        assert_eq!(Prepared::Keys(vec![1, 2, 3]).len(), 3);
        assert_eq!(Prepared::WeightedKeys(vec![(1, 10)]).len(), 1);
        assert!(Prepared::Signature(vec![]).is_empty());
    }
}
