//! Streaming corpus generation for scale experiments (Fig. 1, 7, 8).
//!
//! [`LabeledCorpus`](super::dataset::LabeledCorpus) materializes the whole
//! dataset (fine at 50 k fidelity scale); the scaling study needs millions
//! of documents, so this iterator generates documents lazily in O(1)
//! memory: originals come from the deterministic generator, duplicates
//! are parser-noise/truncation mutations of a bounded reservoir of recent
//! originals (matching real streams, where near-duplicates cluster in
//! time). Originals always precede their duplicates.

use super::generator::{CorpusGenerator, GeneratorConfig};
use super::noise::{parser_noise, truncate, Parser, TruncationNoise};
use super::{Doc, LabeledDoc};
use crate::rng::Xoshiro256pp;

/// Specification of a lazy labeled stream.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    pub total_docs: u64,
    pub dup_rate: f64,
    pub seed: u64,
    pub generator: GeneratorConfig,
    pub truncation: TruncationNoise,
    /// Duplicates are drawn from the last `reservoir` originals.
    pub reservoir: usize,
}

impl StreamSpec {
    /// peS2o-sim defaults: full-length docs, ~30% duplication.
    pub fn pes2o_sim(seed: u64, total_docs: u64) -> Self {
        Self {
            total_docs,
            dup_rate: 0.3,
            seed,
            generator: GeneratorConfig::default(),
            truncation: TruncationNoise::default(),
            reservoir: 1024,
        }
    }

    /// Instantiate the iterator.
    pub fn stream(&self) -> CorpusStream {
        CorpusStream {
            gen: CorpusGenerator::new(self.generator.clone()),
            rng: Xoshiro256pp::seeded(self.seed),
            spec: self.clone(),
            emitted: 0,
            originals_made: 0,
            reservoir: Vec::with_capacity(self.reservoir),
        }
    }
}

/// The lazy document stream.
pub struct CorpusStream {
    gen: CorpusGenerator,
    rng: Xoshiro256pp,
    spec: StreamSpec,
    emitted: u64,
    originals_made: u64,
    /// (stream id, text) of recent originals.
    reservoir: Vec<(u64, String)>,
}

impl Iterator for CorpusStream {
    type Item = LabeledDoc;

    fn next(&mut self) -> Option<LabeledDoc> {
        if self.emitted >= self.spec.total_docs {
            return None;
        }
        let id = self.emitted;
        self.emitted += 1;

        let make_dup = !self.reservoir.is_empty() && self.rng.chance(self.spec.dup_rate);
        let item = if make_dup {
            let pick = self.rng.below(self.reservoir.len() as u64) as usize;
            let (orig_id, orig_text) = &self.reservoir[pick];
            let text = if self.rng.chance(0.5) {
                let parser = Parser::ALL[self.rng.below(3) as usize];
                parser_noise(orig_text, parser, &mut self.rng)
            } else {
                truncate(orig_text, self.spec.truncation, &mut self.rng)
            };
            LabeledDoc { doc: Doc { id, text }, duplicate_of: Some(*orig_id) }
        } else {
            let doc = self.gen.generate(self.spec.seed, self.originals_made);
            self.originals_made += 1;
            let text = doc.text;
            if self.reservoir.len() < self.spec.reservoir {
                self.reservoir.push((id, text.clone()));
            } else {
                let slot = self.rng.below(self.spec.reservoir as u64) as usize;
                self.reservoir[slot] = (id, text.clone());
            }
            LabeledDoc { doc: Doc { id, text }, duplicate_of: None }
        };
        Some(item)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = (self.spec.total_docs - self.emitted) as usize;
        (left, Some(left))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_respects_count_and_rate() {
        let spec = StreamSpec { dup_rate: 0.4, ..StreamSpec::pes2o_sim(1, 2000) };
        let docs: Vec<LabeledDoc> = spec.stream().collect();
        assert_eq!(docs.len(), 2000);
        let dups = docs.iter().filter(|d| d.is_duplicate()).count();
        let rate = dups as f64 / 2000.0;
        assert!((rate - 0.4).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn duplicates_reference_earlier_ids() {
        let spec = StreamSpec::pes2o_sim(2, 500);
        for d in spec.stream() {
            if let Some(orig) = d.duplicate_of {
                assert!(orig < d.doc.id);
            }
        }
    }

    #[test]
    fn stream_is_deterministic() {
        let spec = StreamSpec::pes2o_sim(3, 100);
        let a: Vec<String> = spec.stream().map(|d| d.doc.text).collect();
        let b: Vec<String> = spec.stream().map(|d| d.doc.text).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn ids_are_stream_positions() {
        let spec = StreamSpec::pes2o_sim(4, 50);
        for (i, d) in spec.stream().enumerate() {
            assert_eq!(d.doc.id, i as u64);
        }
    }
}
