//! Synthetic document generator.
//!
//! Documents are scientific-prose-shaped: a title line, several
//! paragraphs of Zipf-sampled sentences, occasional inline numerics.
//! Each document mixes the global vocabulary with a small *topic bank*
//! (a random vocabulary slice) so that distinct documents share function
//! words but differ strongly in content words — like real corpora, where
//! non-duplicate pairs have low but non-zero Jaccard similarity.

use super::vocab::build_vocab;
use super::Doc;
use crate::rng::{geometric, Xoshiro256pp, Zipf};
use std::sync::Arc;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    /// Vocabulary size.
    pub vocab_size: usize,
    /// Zipf exponent for word sampling.
    pub zipf_s: f64,
    /// Mean words per sentence.
    pub mean_sentence_words: usize,
    /// Mean sentences per paragraph.
    pub mean_paragraph_sentences: usize,
    /// Minimum / maximum paragraphs per document.
    pub paragraphs: (usize, usize),
    /// Words drawn from the per-document topic bank with this probability.
    pub topic_mix: f64,
    /// Topic bank size (distinct content words per document).
    pub topic_bank: usize,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            vocab_size: 20_000,
            zipf_s: 1.05,
            mean_sentence_words: 18,
            mean_paragraph_sentences: 5,
            paragraphs: (3, 10),
            topic_mix: 0.35,
            topic_bank: 120,
        }
    }
}

/// Tiny config for fast tests / CI (shorter docs).
impl GeneratorConfig {
    /// Short-document variant (abstract-length, ~80 words).
    pub fn short() -> Self {
        Self {
            mean_sentence_words: 12,
            mean_paragraph_sentences: 3,
            paragraphs: (2, 4),
            ..Self::default()
        }
    }
}

/// Deterministic corpus generator (seeded).
pub struct CorpusGenerator {
    vocab: Arc<Vec<String>>,
    zipf: Zipf,
    config: GeneratorConfig,
}

impl CorpusGenerator {
    /// Build with a config; vocabulary construction is O(vocab_size).
    pub fn new(config: GeneratorConfig) -> Self {
        let vocab = Arc::new(build_vocab(config.vocab_size));
        let zipf = Zipf::new(config.vocab_size, config.zipf_s);
        Self { vocab, zipf, config }
    }

    /// Generate document `id` deterministically from `seed` and `id`.
    pub fn generate(&self, seed: u64, id: u64) -> Doc {
        let mut rng = Xoshiro256pp::seeded(seed ^ id.wrapping_mul(crate::rng::GOLDEN_GAMMA));
        // Per-document topic bank: a contiguous-ish random slice of vocab.
        let bank: Vec<usize> = (0..self.config.topic_bank)
            .map(|_| rng.below(self.vocab.len() as u64) as usize)
            .collect();

        let mut text = String::with_capacity(2048);
        // Title.
        let title_words = 4 + rng.below(8) as usize;
        for i in 0..title_words {
            if i > 0 {
                text.push(' ');
            }
            text.push_str(self.word(&mut rng, &bank));
        }
        text.push('\n');

        let num_paras = rng.range_inclusive(
            self.config.paragraphs.0 as u64,
            self.config.paragraphs.1 as u64,
        ) as usize;
        for _ in 0..num_paras {
            let sentences =
                1 + geometric(&mut rng, 1.0 / self.config.mean_paragraph_sentences as f64);
            for _ in 0..sentences {
                let words = 3 + geometric(&mut rng, 1.0 / self.config.mean_sentence_words as f64);
                for w in 0..words {
                    if w > 0 {
                        text.push(' ');
                    }
                    // Occasional inline numeric tokens.
                    if rng.chance(0.03) {
                        text.push_str(&format!("{:.2}", rng.next_f64() * 100.0));
                    } else {
                        text.push_str(self.word(&mut rng, &bank));
                    }
                }
                text.push_str(". ");
            }
            text.push('\n');
        }
        Doc { id, text }
    }

    fn word(&self, rng: &mut Xoshiro256pp, bank: &[usize]) -> &str {
        if rng.chance(self.config.topic_mix) {
            let idx = bank[rng.below(bank.len() as u64) as usize];
            &self.vocab[idx]
        } else {
            &self.vocab[self.zipf.sample(rng)]
        }
    }

    /// The vocabulary (shared with noise injection).
    pub fn vocab(&self) -> &Arc<Vec<String>> {
        &self.vocab
    }

    /// Generator configuration.
    pub fn config(&self) -> &GeneratorConfig {
        &self.config
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minhash::signature::{exact_jaccard, MinHasher, PermFamily};

    #[test]
    fn generation_is_deterministic() {
        let g = CorpusGenerator::new(GeneratorConfig::short());
        let a = g.generate(42, 7);
        let b = g.generate(42, 7);
        assert_eq!(a, b);
        let c = g.generate(42, 8);
        assert_ne!(a.text, c.text);
    }

    #[test]
    fn documents_have_structure() {
        let g = CorpusGenerator::new(GeneratorConfig::default());
        let d = g.generate(1, 0);
        let paras = crate::text::paragraphs(&d.text);
        assert!(paras.len() >= 3, "expected multiple paragraphs");
        assert!(d.text.split_whitespace().count() > 50, "doc too short");
    }

    #[test]
    fn distinct_docs_have_low_jaccard() {
        let g = CorpusGenerator::new(GeneratorConfig::default());
        let mh = MinHasher::new(PermFamily::Mix64, 64, 1);
        let mut max_j: f64 = 0.0;
        let base = g.generate(5, 0);
        let hb = mh.shingle_hashes(&crate::text::normalize(&base.text));
        for id in 1..20 {
            let other = g.generate(5, id);
            let ho = mh.shingle_hashes(&crate::text::normalize(&other.text));
            max_j = max_j.max(exact_jaccard(&hb, &ho));
        }
        // Non-duplicates share function words but must sit far below any
        // sane dedup threshold.
        assert!(max_j < 0.35, "non-duplicate Jaccard too high: {max_j}");
        assert!(max_j > 0.0, "docs should share some function words");
    }

    #[test]
    fn length_scales_with_config() {
        let short = CorpusGenerator::new(GeneratorConfig::short());
        let long = CorpusGenerator::new(GeneratorConfig::default());
        let avg = |g: &CorpusGenerator| -> f64 {
            (0..10)
                .map(|i| g.generate(9, i).text.split_whitespace().count())
                .sum::<usize>() as f64
                / 10.0
        };
        assert!(avg(&long) > avg(&short) * 1.5);
    }
}
