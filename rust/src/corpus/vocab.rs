//! Synthetic scientific vocabulary.
//!
//! A deterministic lexicon of plausible scientific-prose tokens: a core
//! of real function words (so documents have natural high-frequency
//! structure), a bank of domain stems composed with suffixes, plus
//! numerals and symbols that PDF parsers commonly mangle.

/// High-frequency function words (ranks 0..~50 under Zipf sampling).
pub const FUNCTION_WORDS: &[&str] = &[
    "the", "of", "and", "a", "to", "in", "is", "we", "that", "for", "with",
    "as", "are", "this", "by", "on", "be", "an", "which", "from", "our",
    "can", "at", "these", "it", "results", "model", "data", "using", "each",
    "between", "where", "when", "than", "into", "both", "under", "over",
    "not", "or", "has", "have", "was", "were", "its", "their", "however",
    "thus", "therefore", "furthermore",
];

/// Domain stems for content words.
pub const STEMS: &[&str] = &[
    "spectr", "quant", "neur", "molec", "catal", "enzym", "polym", "therm",
    "electr", "magnet", "optic", "photon", "proton", "isotop", "genom",
    "protein", "lipid", "membran", "cellul", "vascul", "cardi", "cortic",
    "synapt", "algorithm", "comput", "stochast", "bayes", "gradient",
    "tensor", "matrix", "eigen", "fourier", "laplac", "hamilton", "lagrang",
    "entrop", "diffus", "convect", "turbul", "laminar", "viscos", "elastic",
    "plastic", "crystall", "amorph", "lattice", "dopant", "semiconduct",
    "superconduct", "ferromagnet", "dielectr", "piezo", "katalys", "oxid",
    "reduct", "hydrolys", "synthes", "polymeris", "ligand", "receptor",
    "antibod", "antigen", "pathogen", "viral", "bacteri", "fungal",
    "ecolog", "climat", "atmospher", "ocean", "seismic", "tecton",
    "stratigraph", "sediment", "mineral", "petrolog", "econometr", "equilibr",
];

/// Suffixes composing stems into word families.
pub const SUFFIXES: &[&str] = &[
    "al", "ic", "ity", "ation", "ism", "ous", "ive", "ly", "s", "es", "ed",
    "ing", "ant", "ent", "ible", "ance", "ence", "or", "er", "um", "a",
];

/// Build the full deterministic vocabulary of `size` words.
///
/// Layout: function words first (so Zipf rank 0.. hits them), then
/// stem+suffix compositions, then numbered technical identifiers.
pub fn build_vocab(size: usize) -> Vec<String> {
    let mut v: Vec<String> = Vec::with_capacity(size);
    for w in FUNCTION_WORDS {
        if v.len() >= size {
            return v;
        }
        v.push((*w).to_string());
    }
    'outer: for suf in SUFFIXES {
        for stem in STEMS {
            if v.len() >= size {
                break 'outer;
            }
            v.push(format!("{stem}{suf}"));
        }
    }
    // Tail: numbered identifiers (rare words — the Zipf tail).
    let mut i = 0usize;
    while v.len() < size {
        v.push(format!("var{i:x}"));
        i += 1;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_deterministic_and_sized() {
        let a = build_vocab(5000);
        let b = build_vocab(5000);
        assert_eq!(a.len(), 5000);
        assert_eq!(a, b);
    }

    #[test]
    fn vocab_has_no_duplicates() {
        let v = build_vocab(3000);
        let mut set = std::collections::HashSet::new();
        for w in &v {
            assert!(set.insert(w.clone()), "duplicate word {w}");
        }
    }

    #[test]
    fn function_words_lead() {
        let v = build_vocab(1000);
        assert_eq!(v[0], "the");
        assert!(v[..50].iter().any(|w| w == "model"));
    }

    #[test]
    fn small_vocab_truncates_cleanly() {
        assert_eq!(build_vocab(3).len(), 3);
        assert_eq!(build_vocab(0).len(), 0);
    }
}
