//! Labeled dataset construction (§5.1.4).
//!
//! Builds a document stream with a target duplication rate where every
//! duplicate is either a *parser-noise* or a *truncation* variant of an
//! earlier original — balanced 50/50 as in the paper — and ground-truth
//! labels record which original each duplicate came from.
//!
//! Stream-order guarantee: an original always precedes its duplicates,
//! matching the streaming SAMQ task definition (§2.1) where `F(d_i)`
//! is evaluated against `D_seen`.

use super::generator::{CorpusGenerator, GeneratorConfig};
use super::noise::{parser_noise, truncate, Parser, TruncationNoise};
use super::{Doc, LabeledDoc};
use crate::rng::Xoshiro256pp;

/// Specification for a labeled corpus.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Total documents in the stream.
    pub total_docs: usize,
    /// Fraction of the stream that is duplicates (0.0–0.9).
    pub dup_rate: f64,
    /// Master seed.
    pub seed: u64,
    /// Document shape.
    pub generator: GeneratorConfig,
    /// Truncation parameters.
    pub truncation: TruncationNoise,
}

impl DatasetSpec {
    /// The paper's tuning-set shape: balanced (50% duplicates), 24k docs.
    ///
    /// Truncation keeps as little as 55% of the document so near-duplicate
    /// pairs straddle the T=0.5 decision boundary (the paper's benchmark
    /// likewise contains borderline duplicates; a too-easy corpus saturates
    /// every method at F1=1).
    pub fn tuning(seed: u64, total_docs: usize) -> Self {
        Self {
            total_docs,
            dup_rate: 0.5,
            seed,
            generator: GeneratorConfig::short(),
            truncation: TruncationNoise { min_keep: 0.55, max_keep: 0.95 },
        }
    }

    /// The paper's testing-set shape at a given duplication level.
    pub fn testing(seed: u64, total_docs: usize, dup_rate: f64) -> Self {
        Self { dup_rate, ..Self::tuning(seed, total_docs) }
    }
}

/// A fully materialized labeled corpus.
pub struct LabeledCorpus {
    pub docs: Vec<LabeledDoc>,
    pub spec: DatasetSpec,
}

impl LabeledCorpus {
    /// Build the corpus per spec (deterministic).
    pub fn build(spec: DatasetSpec) -> Self {
        assert!((0.0..1.0).contains(&spec.dup_rate), "dup_rate in [0,1)");
        let n = spec.total_docs;
        let num_dups = (n as f64 * spec.dup_rate).round() as usize;
        let num_orig = n - num_dups;
        assert!(num_orig > 0, "need at least one original");

        let gen = CorpusGenerator::new(spec.generator.clone());
        let mut rng = Xoshiro256pp::seeded(spec.seed);

        // Originals: ids 0..num_orig (generated lazily below by stream id).
        // Stream layout: start with originals in order; then interleave
        // duplicates at random positions *after* their original. Simplest
        // construction preserving the precedence invariant: fill the
        // stream with originals, then insert each duplicate at a uniform
        // position after its source, shifting the tail.
        let mut stream: Vec<LabeledDoc> = Vec::with_capacity(n);
        for i in 0..num_orig {
            stream.push(LabeledDoc {
                doc: gen.generate(spec.seed, i as u64),
                duplicate_of: None,
            });
        }

        for d in 0..num_dups {
            // Pick a source among current stream entries that are originals.
            let src_pos = rng.below(stream.len() as u64) as usize;
            let src_pos = match stream[src_pos].duplicate_of {
                None => src_pos,
                // If we hit a duplicate, follow to its original's position.
                Some(orig_id) => stream
                    .iter()
                    .position(|ld| ld.doc.id == orig_id && ld.duplicate_of.is_none())
                    .unwrap_or(src_pos),
            };
            let src_text = stream[src_pos].doc.text.clone();
            let src_id = stream[src_pos].doc.id;
            // Balanced duplicate types (§5.1.4): even = parser, odd = trunc.
            let text = if d % 2 == 0 {
                let parser = *rng_choose(&mut rng, &Parser::ALL);
                parser_noise(&src_text, parser, &mut rng)
            } else {
                truncate(&src_text, spec.truncation, &mut rng)
            };
            // Insert after the source position.
            let insert_at = src_pos + 1 + rng.below((stream.len() - src_pos) as u64) as usize;
            stream.insert(
                insert_at,
                LabeledDoc {
                    doc: Doc { id: (num_orig + d) as u64, text },
                    duplicate_of: Some(src_id),
                },
            );
        }

        // Re-number stream ids to ingestion order (labels keep original
        // doc ids via duplicate_of -> remap).
        let mut remap = std::collections::HashMap::new();
        for (pos, ld) in stream.iter().enumerate() {
            remap.insert(ld.doc.id, pos as u64);
        }
        for (pos, ld) in stream.iter_mut().enumerate() {
            ld.doc.id = pos as u64;
            if let Some(orig) = ld.duplicate_of {
                ld.duplicate_of = Some(remap[&orig]);
            }
        }

        Self { docs: stream, spec }
    }

    /// Number of ground-truth duplicates.
    pub fn num_duplicates(&self) -> usize {
        self.docs.iter().filter(|d| d.is_duplicate()).count()
    }

    /// Write as JSONL: `{"id": .., "text": .., "duplicate_of": ..|null}`.
    pub fn save_jsonl(&self, path: &std::path::Path) -> crate::error::Result<()> {
        use crate::error::Error;
        use crate::json::{obj, Value};
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
        }
        let f = std::fs::File::create(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let mut w = std::io::BufWriter::new(f);
        for ld in &self.docs {
            let dup = match ld.duplicate_of {
                Some(id) => Value::u64(id),
                None => Value::Null,
            };
            let line = obj(vec![
                ("id", Value::u64(ld.doc.id)),
                ("text", Value::str(ld.doc.text.clone())),
                ("duplicate_of", dup),
            ]);
            writeln!(w, "{}", line.to_json()).map_err(|e| Error::io(path.display().to_string(), e))?;
        }
        Ok(())
    }

    /// Read back a JSONL corpus produced by [`LabeledCorpus::save_jsonl`].
    pub fn load_jsonl(path: &std::path::Path) -> crate::error::Result<Vec<LabeledDoc>> {
        use crate::error::Error;
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = crate::json::parse(line)
                .map_err(|e| Error::parse(format!("corpus line {}", i + 1), e.to_string()))?;
            let id = v
                .get("id")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| Error::parse("corpus", format!("line {}: missing id", i + 1)))?;
            let doc_text = v
                .get("text")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::parse("corpus", format!("line {}: missing text", i + 1)))?
                .to_string();
            let duplicate_of = v.get("duplicate_of").and_then(|x| x.as_u64());
            out.push(LabeledDoc { doc: Doc { id, text: doc_text }, duplicate_of });
        }
        Ok(out)
    }
}

fn rng_choose<'a, T>(rng: &mut Xoshiro256pp, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len() as u64) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicate_rate_is_respected() {
        let c = LabeledCorpus::build(DatasetSpec::testing(1, 500, 0.3));
        assert_eq!(c.docs.len(), 500);
        let rate = c.num_duplicates() as f64 / 500.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn originals_precede_their_duplicates() {
        let c = LabeledCorpus::build(DatasetSpec::testing(2, 400, 0.5));
        let pos: std::collections::HashMap<u64, usize> =
            c.docs.iter().enumerate().map(|(i, d)| (d.doc.id, i)).collect();
        for d in &c.docs {
            if let Some(orig) = d.duplicate_of {
                assert!(pos[&orig] < pos[&d.doc.id], "dup {:?} precedes original", d.doc.id);
                // The referenced original must itself be an original.
                let orig_doc = &c.docs[pos[&orig]];
                assert!(orig_doc.duplicate_of.is_none());
            }
        }
    }

    #[test]
    fn duplicates_are_near_duplicates_of_their_original() {
        use crate::minhash::signature::{exact_jaccard, MinHasher, PermFamily};
        let c = LabeledCorpus::build(DatasetSpec::testing(3, 200, 0.5));
        let mh = MinHasher::new(PermFamily::Mix64, 32, 1);
        let by_id: std::collections::HashMap<u64, &str> =
            c.docs.iter().map(|d| (d.doc.id, d.doc.text.as_str())).collect();
        let mut min_j: f64 = 1.0;
        for d in c.docs.iter().filter(|d| d.is_duplicate()).take(50) {
            let orig = by_id[&d.duplicate_of.unwrap()];
            let j = exact_jaccard(
                &mh.shingle_hashes(&crate::text::normalize(orig)),
                &mh.shingle_hashes(&crate::text::normalize(&d.doc.text)),
            );
            min_j = min_j.min(j);
        }
        assert!(min_j > 0.45, "weakest duplicate pair jaccard {min_j}");
    }

    #[test]
    fn build_is_deterministic() {
        let a = LabeledCorpus::build(DatasetSpec::testing(7, 100, 0.4));
        let b = LabeledCorpus::build(DatasetSpec::testing(7, 100, 0.4));
        for (x, y) in a.docs.iter().zip(&b.docs) {
            assert_eq!(x.doc, y.doc);
            assert_eq!(x.duplicate_of, y.duplicate_of);
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let c = LabeledCorpus::build(DatasetSpec::testing(9, 50, 0.5));
        let dir = std::env::temp_dir().join(format!("lshbloom-ds-{}", std::process::id()));
        let path = dir.join("c.jsonl");
        c.save_jsonl(&path).unwrap();
        let loaded = LabeledCorpus::load_jsonl(&path).unwrap();
        assert_eq!(loaded.len(), c.docs.len());
        for (a, b) in c.docs.iter().zip(&loaded) {
            assert_eq!(a.doc, b.doc);
            assert_eq!(a.duplicate_of, b.duplicate_of);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_dup_rate_all_originals() {
        let c = LabeledCorpus::build(DatasetSpec::testing(11, 50, 0.0));
        assert_eq!(c.num_duplicates(), 0);
    }
}
