//! Synthetic corpora with ground-truth duplicate labels.
//!
//! The paper evaluates on (a) labeled synthetic datasets built from
//! AdaParse PDF/HTML parse pairs (fidelity, §5.1.4) and (b) peS2o
//! (scale, §5.4). Neither is available offline, so this module generates
//! the closest synthetic equivalents (see DESIGN.md §3 Substitutions):
//!
//! * [`generator`] — scientific-prose documents over a Zipf vocabulary
//!   with configurable length distributions (abstract-ish to full-text).
//! * [`noise`] — the two duplication mechanisms of §5.1.4: *parser-noise*
//!   duplicates (OCR-style character aberrations at per-parser rates
//!   emulating PyMuPDF / Nougat / Tesseract) and *truncation* duplicates.
//! * [`dataset`] — labeled tuning/testing dataset builder: balanced
//!   duplicate types, target duplication rate, shuffled stream order with
//!   originals preceding their duplicates.

pub mod dataset;
pub mod generator;
pub mod noise;
pub mod stream;
pub mod vocab;

pub use dataset::{DatasetSpec, LabeledCorpus};
pub use generator::{CorpusGenerator, GeneratorConfig};
pub use noise::{Parser, TruncationNoise};
pub use stream::StreamSpec;

/// A document in the stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Doc {
    /// Stream id (position in ingestion order).
    pub id: u64,
    /// Raw text content.
    pub text: String,
}

/// A labeled document: `duplicate_of` is the id of the original it
/// duplicates (ground truth), if any.
#[derive(Clone, Debug)]
pub struct LabeledDoc {
    pub doc: Doc,
    pub duplicate_of: Option<u64>,
}

impl LabeledDoc {
    /// Ground-truth positive ("is a duplicate") label.
    pub fn is_duplicate(&self) -> bool {
        self.duplicate_of.is_some()
    }
}
