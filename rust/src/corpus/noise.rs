//! Duplicate-generation noise models (§5.1.4 substitution).
//!
//! The paper's fidelity benchmark contains two duplicate flavors, both
//! reproduced here:
//!
//! * **Parser-noise duplicates** — the same underlying document parsed by
//!   a different tool (PyMuPDF / Nougat / Tesseract). Emulated by
//!   character-level OCR aberrations (substitutions, ligature splits,
//!   hyphenation, whitespace/linebreak mangling, dropped punctuation) at
//!   per-parser rates.
//! * **Truncation duplicates** — parsing errors that abruptly skip or cut
//!   text; emulated by truncating a random fraction of the document tail
//!   (and optionally a short head skip).

use crate::rng::Xoshiro256pp;

/// A simulated PDF/HTML parser with a characteristic error profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parser {
    /// Text-layer extraction: very light noise, linebreak changes.
    PyMuPdf,
    /// Neural OCR: moderate substitutions, occasional dropped spans.
    Nougat,
    /// Classic OCR: heaviest character confusion + hyphenation.
    Tesseract,
}

impl Parser {
    /// All parsers, with the paper's "roughly the same frequency" usage.
    pub const ALL: [Parser; 3] = [Parser::PyMuPdf, Parser::Nougat, Parser::Tesseract];

    /// Per-character substitution probability.
    fn char_sub_rate(self) -> f64 {
        match self {
            Parser::PyMuPdf => 0.0005,
            Parser::Nougat => 0.004,
            Parser::Tesseract => 0.012,
        }
    }

    /// Probability a space becomes a linebreak or is doubled.
    fn whitespace_rate(self) -> f64 {
        match self {
            Parser::PyMuPdf => 0.02,
            Parser::Nougat => 0.01,
            Parser::Tesseract => 0.03,
        }
    }

    /// Probability of hyphenating (splitting) a long word.
    fn hyphenation_rate(self) -> f64 {
        match self {
            Parser::PyMuPdf => 0.0,
            Parser::Nougat => 0.002,
            Parser::Tesseract => 0.01,
        }
    }
}

/// Apply parser noise to a document, returning the "re-parsed" text.
pub fn parser_noise(text: &str, parser: Parser, rng: &mut Xoshiro256pp) -> String {
    let mut out = String::with_capacity(text.len() + 64);
    let sub_rate = parser.char_sub_rate();
    let ws_rate = parser.whitespace_rate();
    let hyph_rate = parser.hyphenation_rate();
    let mut word_len = 0usize;
    for ch in text.chars() {
        if ch == ' ' {
            word_len = 0;
            if rng.chance(ws_rate) {
                // Linebreak reflow or doubled space.
                if rng.chance(0.5) {
                    out.push('\n');
                } else {
                    out.push_str("  ");
                }
            } else {
                out.push(' ');
            }
            continue;
        }
        word_len += 1;
        if ch.is_alphabetic() && rng.chance(sub_rate) {
            out.push(confuse(ch, rng));
            continue;
        }
        if ch.is_ascii_punctuation() && rng.chance(sub_rate * 2.0) {
            continue; // dropped punctuation
        }
        if word_len > 6 && rng.chance(hyph_rate) {
            out.push_str("-\n");
            word_len = 0;
        }
        out.push(ch);
    }
    out
}

fn confuse(ch: char, rng: &mut Xoshiro256pp) -> char {
    const TABLE: &[(char, char)] = &[
        ('l', '1'),
        ('i', 'l'),
        ('o', '0'),
        ('e', 'c'),
        ('a', 'o'),
        ('s', '5'),
        ('b', '6'),
        ('g', 'q'),
        ('n', 'h'),
        ('u', 'v'),
    ];
    for &(from, to) in TABLE {
        if ch == from {
            return to;
        }
        if ch == to {
            return from;
        }
    }
    // Unknown character: perturb within lowercase letters.
    if ch.is_ascii_lowercase() {
        (b'a' + rng.below(26) as u8) as char
    } else {
        ch
    }
}

/// Truncation noise parameters.
#[derive(Clone, Copy, Debug)]
pub struct TruncationNoise {
    /// Keep at least this fraction of the document.
    pub min_keep: f64,
    /// Keep at most this fraction.
    pub max_keep: f64,
}

impl Default for TruncationNoise {
    fn default() -> Self {
        // §5.1.4 duplicates must remain duplicates under T=0.5; keep the
        // bulk of the document.
        Self { min_keep: 0.7, max_keep: 0.95 }
    }
}

/// Truncate the tail of a document at a word boundary.
pub fn truncate(text: &str, noise: TruncationNoise, rng: &mut Xoshiro256pp) -> String {
    let words: Vec<&str> = text.split_inclusive(char::is_whitespace).collect();
    if words.len() < 4 {
        return text.to_string();
    }
    let keep_frac = noise.min_keep + rng.next_f64() * (noise.max_keep - noise.min_keep);
    let keep = ((words.len() as f64 * keep_frac).round() as usize).clamp(1, words.len());
    words[..keep].concat()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minhash::signature::{exact_jaccard, MinHasher, PermFamily};
    use crate::text::normalize;

    fn sample_doc() -> String {
        let g = crate::corpus::generator::CorpusGenerator::new(Default::default());
        g.generate(3, 1).text
    }

    fn jaccard(a: &str, b: &str) -> f64 {
        let mh = MinHasher::new(PermFamily::Mix64, 32, 1);
        exact_jaccard(
            &mh.shingle_hashes(&normalize(a)),
            &mh.shingle_hashes(&normalize(b)),
        )
    }

    #[test]
    fn parser_noise_preserves_high_similarity() {
        let doc = sample_doc();
        let mut rng = Xoshiro256pp::seeded(1);
        for parser in Parser::ALL {
            let noisy = parser_noise(&doc, parser, &mut rng);
            let j = jaccard(&doc, &noisy);
            assert!(j > 0.55, "{parser:?}: jaccard {j} too low to be a near-duplicate");
            assert!(j < 1.0 || parser == Parser::PyMuPdf, "{parser:?} should perturb");
        }
    }

    #[test]
    fn tesseract_noisier_than_pymupdf() {
        let doc = sample_doc();
        let mut rng = Xoshiro256pp::seeded(2);
        let light = jaccard(&doc, &parser_noise(&doc, Parser::PyMuPdf, &mut rng));
        let heavy = jaccard(&doc, &parser_noise(&doc, Parser::Tesseract, &mut rng));
        assert!(light > heavy, "light {light} vs heavy {heavy}");
    }

    #[test]
    fn truncation_keeps_prefix() {
        let doc = sample_doc();
        let mut rng = Xoshiro256pp::seeded(3);
        let t = truncate(&doc, TruncationNoise::default(), &mut rng);
        assert!(t.len() < doc.len());
        assert!(doc.starts_with(&t[..t.len().min(40)]));
        let j = jaccard(&doc, &t);
        assert!(j > 0.6, "truncation jaccard {j}");
    }

    #[test]
    fn truncation_short_doc_is_identity() {
        let mut rng = Xoshiro256pp::seeded(4);
        assert_eq!(truncate("a b c", TruncationNoise::default(), &mut rng), "a b c");
    }

    #[test]
    fn noise_is_deterministic_per_seed() {
        let doc = sample_doc();
        let a = parser_noise(&doc, Parser::Nougat, &mut Xoshiro256pp::seeded(9));
        let b = parser_noise(&doc, Parser::Nougat, &mut Xoshiro256pp::seeded(9));
        assert_eq!(a, b);
    }
}
