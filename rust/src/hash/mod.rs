//! Hashing substrates.
//!
//! * [`sha1`] — from-scratch RFC 3174 SHA-1 (token hashing, CCNet
//!   paragraph hashes); verified against the RustCrypto crate in dev tests.
//! * [`mix64`] — splitmix64-finalizer permutation family shared with the
//!   Pallas kernels (see DESIGN.md "Deviation: permutation family").
//! * [`universal`] — the datasketch-compatible `(a·h+b) mod 2^61-1`
//!   family, implemented with 128-bit arithmetic (§4.4.1 codesign).
//! * [`band`] — band sum-hash routines: wrapping-u64 fast path, u128
//!   `mod N` general path, and a faithful Python-bigint simulation used as
//!   the §4.4.1 baseline.
//! * Token/string hashing helpers used across methods.

pub mod band;
pub mod mix64;
pub mod pybigint;
pub mod sha1;
pub mod universal;

/// Hash a token (byte string) to u64: low 8 bytes of SHA-1, little-endian.
///
/// This is the document-side hash the MinHash layer consumes; both the
/// native backend and the batch marshaller for the XLA artifacts use it.
#[inline]
pub fn token_hash_u64(token: &[u8]) -> u64 {
    let digest = sha1::Sha1::digest(token);
    u64::from_le_bytes(digest[..8].try_into().unwrap())
}

/// Hash a token to u32 (datasketch-compatible width: first 4 bytes LE).
#[inline]
pub fn token_hash_u32(token: &[u8]) -> u32 {
    let digest = sha1::Sha1::digest(token);
    u32::from_le_bytes(digest[..4].try_into().unwrap())
}

/// Fast 64-bit string hash (FNV-1a core + mix64 finalizer) for Bloom keys
/// of exact-match methods (Dolma paragraphs, DCLM n-grams) where
/// cryptographic strength is unnecessary but good diffusion matters.
#[inline]
pub fn fast_str_hash(data: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    crate::rng::mix64(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_hashes_are_stable() {
        // Pinned values: changing them breaks golden-vector compatibility.
        assert_eq!(token_hash_u64(b"the"), token_hash_u64(b"the"));
        assert_ne!(token_hash_u64(b"the"), token_hash_u64(b"The"));
        assert_ne!(token_hash_u32(b"a"), token_hash_u32(b"b"));
    }

    #[test]
    fn token_hash_u64_matches_sha1_low8() {
        let d = sha1::Sha1::digest(b"hello world");
        assert_eq!(
            token_hash_u64(b"hello world"),
            u64::from_le_bytes(d[..8].try_into().unwrap())
        );
    }

    #[test]
    fn fast_str_hash_differs_on_small_changes() {
        let a = fast_str_hash(b"paragraph one");
        let b = fast_str_hash(b"paragraph one ");
        let c = fast_str_hash(b"paragraph two");
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }
}
