//! SHA-1 (RFC 3174), implemented from scratch.
//!
//! The paper uses SHA-1 as its universal hash for tokens (§4.1, following
//! datasketch) and CCNet uses SHA-1 paragraph digests; this is the only
//! cryptographic primitive the system needs. Correctness is pinned against
//! the RFC test vectors here and against the RustCrypto `sha1` crate in
//! `rust/tests/sha1_crosscheck.rs`.
//!
//! Performance note: the compression function is written straight-line per
//! round group so LLVM can keep the five state words in registers; the
//! message schedule is computed on the fly in a 16-word ring, which is the
//! classic low-footprint formulation.

/// Streaming SHA-1 hasher.
#[derive(Clone)]
pub struct Sha1 {
    state: [u32; 5],
    /// Total message length in bytes.
    len: u64,
    buf: [u8; 64],
    buf_len: usize,
}

impl Default for Sha1 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha1 {
    /// Fresh hasher with the RFC initial state.
    pub const fn new() -> Self {
        Self {
            state: [0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476, 0xC3D2E1F0],
            len: 0,
            buf: [0; 64],
            buf_len: 0,
        }
    }

    /// One-shot digest.
    pub fn digest(data: &[u8]) -> [u8; 20] {
        let mut h = Self::new();
        h.update(data);
        h.finalize()
    }

    /// Absorb bytes.
    pub fn update(&mut self, mut data: &[u8]) {
        self.len = self.len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let need = 64 - self.buf_len;
            let take = need.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Finish and produce the 20-byte digest.
    pub fn finalize(mut self) -> [u8; 20] {
        let bit_len = self.len.wrapping_mul(8);
        // Padding: 0x80, zeros, 64-bit big-endian length — written
        // directly into the block buffer (§Perf: the previous
        // byte-at-a-time `update(&[0])` loop dominated small-token
        // hashing).
        let n = self.buf_len;
        self.buf[n] = 0x80;
        if n < 56 {
            self.buf[n + 1..56].fill(0);
        } else {
            // Length field does not fit: pad out this block, compress,
            // and use a fresh zero block for the length.
            self.buf[n + 1..64].fill(0);
            let block = self.buf;
            self.compress(&block);
            self.buf.fill(0);
        }
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);

        let mut out = [0u8; 20];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    #[inline]
    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 16];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }

        let [mut a, mut b, mut c, mut d, mut e] = self.state;

        macro_rules! schedule {
            ($t:expr) => {{
                let idx = $t & 15;
                let v = (w[(idx + 13) & 15] ^ w[(idx + 8) & 15] ^ w[(idx + 2) & 15] ^ w[idx])
                    .rotate_left(1);
                w[idx] = v;
                v
            }};
        }
        macro_rules! round {
            ($f:expr, $k:expr, $wt:expr) => {{
                let tmp = a
                    .rotate_left(5)
                    .wrapping_add($f)
                    .wrapping_add(e)
                    .wrapping_add($k)
                    .wrapping_add($wt);
                e = d;
                d = c;
                c = b.rotate_left(30);
                b = a;
                a = tmp;
            }};
        }

        for t in 0..80 {
            let wt = if t < 16 { w[t] } else { schedule!(t) };
            match t {
                0..=19 => round!((b & c) | ((!b) & d), 0x5A827999, wt),
                20..=39 => round!(b ^ c ^ d, 0x6ED9EBA1, wt),
                40..=59 => round!((b & c) | (b & d) | (c & d), 0x8F1BBCDC, wt),
                _ => round!(b ^ c ^ d, 0xCA62C1D6, wt),
            }
        }

        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
    }
}

/// Hex-encode a digest (for CCNet-style dedup keys and debugging).
pub fn hex(digest: &[u8; 20]) -> String {
    let mut s = String::with_capacity(40);
    for b in digest {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hexdigest(data: &[u8]) -> String {
        hex(&Sha1::digest(data))
    }

    #[test]
    fn rfc3174_test_vectors() {
        assert_eq!(hexdigest(b"abc"), "a9993e364706816aba3e25717850c26c9cd0d89d");
        assert_eq!(
            hexdigest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "84983e441c3bd26ebaae4aa1f95129e5e54670f1"
        );
        assert_eq!(hexdigest(b""), "da39a3ee5e6b4b0d3255bfef95601890afd80709");
    }

    #[test]
    fn million_a() {
        let mut h = Sha1::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(hex(&h.finalize()), "34aa973cd4c4daa4f61eeb2bdbad27316534016f");
    }

    #[test]
    fn streaming_equals_oneshot_at_all_boundaries() {
        let data: Vec<u8> = (0..=255u8).cycle().take(300).collect();
        let oneshot = Sha1::digest(&data);
        for split in [0, 1, 55, 56, 63, 64, 65, 128, 299, 300] {
            let mut h = Sha1::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), oneshot, "split at {split}");
        }
    }

    #[test]
    fn length_boundary_paddings() {
        // Exercise messages straddling the 56-byte padding boundary.
        for n in 50..70 {
            let data = vec![0xABu8; n];
            let d = Sha1::digest(&data);
            // Compare against a second computation through the streaming path.
            let mut h = Sha1::new();
            for b in &data {
                h.update(std::slice::from_ref(b));
            }
            assert_eq!(h.finalize(), d, "n={n}");
        }
    }
}
