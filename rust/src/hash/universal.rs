//! The datasketch-compatible universal hash family (native-only).
//!
//! `perm_{a,b}(h) = ((a·h + b) mod p) & (2^32 - 1)` with the Mersenne
//! prime `p = 2^61 - 1`, matching the datasketch `MinHash` default the
//! paper's baseline uses. The 61-bit modular product needs 128-bit
//! intermediates — exactly the fixed-precision codesign point of §4.4.1 —
//! so this family exists only on the rust side; the XLA path uses the
//! [`mix64`](super::mix64) family (see DESIGN.md).

use crate::rng::Xoshiro256pp;

/// The Mersenne prime 2^61 - 1 used by datasketch.
pub const MERSENNE_PRIME: u64 = (1 << 61) - 1;
/// Output mask (datasketch truncates to 32-bit hash values).
pub const MAX_HASH: u64 = (1 << 32) - 1;

/// One (a, b) permutation pair; `a` in [1, p), `b` in [0, p).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PermPair {
    pub a: u64,
    pub b: u64,
}

/// Fast `x mod (2^61-1)` for x < 2^122 via the Mersenne folding trick.
#[inline(always)]
fn mod_mersenne(x: u128) -> u64 {
    // Fold twice: each fold reduces the bit-length by ~61.
    let folded = (x & MERSENNE_PRIME as u128) + (x >> 61);
    let folded = (folded & MERSENNE_PRIME as u128) + (folded >> 61);
    let mut r = folded as u64;
    if r >= MERSENNE_PRIME {
        r -= MERSENNE_PRIME;
    }
    r
}

impl PermPair {
    /// Apply the permutation to a token hash (datasketch semantics:
    /// 32-bit truncated output).
    #[inline(always)]
    pub fn apply(&self, h: u64) -> u64 {
        let prod = (self.a as u128) * (h as u128) + (self.b as u128);
        mod_mersenne(prod) & MAX_HASH
    }

    /// Apply without the 32-bit truncation (full 61-bit output); used by
    /// the u64-width fidelity variant.
    #[inline(always)]
    pub fn apply_wide(&self, h: u64) -> u64 {
        let prod = (self.a as u128) * (h as u128) + (self.b as u128);
        mod_mersenne(prod)
    }
}

/// Derive `n` (a, b) pairs from a seed.
pub fn derive_pairs(seed: u64, n: usize) -> Vec<PermPair> {
    let mut rng = Xoshiro256pp::seeded(seed);
    (0..n)
        .map(|_| PermPair {
            a: rng.range_inclusive(1, MERSENNE_PRIME - 1),
            b: rng.below(MERSENNE_PRIME),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Slow reference: full u128 modulo.
    fn apply_ref(a: u64, b: u64, h: u64) -> u64 {
        (((a as u128 * h as u128 + b as u128) % MERSENNE_PRIME as u128) as u64) & MAX_HASH
    }

    #[test]
    fn mod_mersenne_matches_slow_modulo() {
        let cases: Vec<u128> = vec![
            0,
            1,
            MERSENNE_PRIME as u128 - 1,
            MERSENNE_PRIME as u128,
            MERSENNE_PRIME as u128 + 1,
            u64::MAX as u128,
            (MERSENNE_PRIME as u128) * (MERSENNE_PRIME as u128) - 1,
            u128::MAX >> 6, // 2^122 - 1, the max a*h+b can reach
        ];
        for x in cases {
            assert_eq!(mod_mersenne(x) as u128, x % MERSENNE_PRIME as u128, "x={x}");
        }
    }

    #[test]
    fn apply_matches_reference_randomized() {
        let pairs = derive_pairs(99, 64);
        let mut rng = Xoshiro256pp::seeded(123);
        for p in &pairs {
            for _ in 0..100 {
                let h = rng.next_u64();
                assert_eq!(p.apply(h), apply_ref(p.a, p.b, h));
            }
        }
    }

    #[test]
    fn outputs_respect_mask() {
        let pairs = derive_pairs(7, 16);
        let mut rng = Xoshiro256pp::seeded(8);
        for p in &pairs {
            for _ in 0..64 {
                assert!(p.apply(rng.next_u64()) <= MAX_HASH);
                assert!(p.apply_wide(rng.next_u64()) < MERSENNE_PRIME);
            }
        }
    }

    #[test]
    fn pairs_are_in_valid_ranges() {
        for p in derive_pairs(42, 1000) {
            assert!(p.a >= 1 && p.a < MERSENNE_PRIME);
            assert!(p.b < MERSENNE_PRIME);
        }
    }
}
