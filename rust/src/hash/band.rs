//! Band sum-hashing (paper §4.1 / §4.4.1).
//!
//! A band of `r` MinHash values is reduced to one integer:
//! `h(x̄) = (Σ_i h_i) mod N`. Three implementations:
//!
//! * [`band_hash_wrapping`] — `N = 2^64`: the sum wraps for free in one
//!   register. This is the pipeline hot path and matches the Pallas
//!   bandhash kernel exactly.
//! * [`band_hash_mod_n`] — arbitrary `N`, 128-bit accumulator. This is the
//!   paper-faithful §4.4.1 routine: summing 64-bit values needs at most
//!   64 + log2(r) bits (≤ 72 for r ≤ 256), so a u128 accumulator (compiled
//!   to `add`/`adc` on x86-64) is exact; a single modulo finishes.
//! * [`super::pybigint`] — a simulation of CPython's base-2^30 bigint
//!   addition, the slow baseline the paper's 94% speedup is measured
//!   against (`cargo bench --bench micro_bandhash`).

/// Wrapping-u64 band hash: `(Σ h_i) mod 2^64`.
#[inline]
pub fn band_hash_wrapping(band: &[u64]) -> u64 {
    let mut acc = 0u64;
    for &h in band {
        acc = acc.wrapping_add(h);
    }
    acc
}

/// General `(Σ h_i) mod n` with an exact 128-bit accumulator.
///
/// Panics if `n == 0`. For `r ≤ 2^64` the u128 accumulator cannot
/// overflow (max sum < 2^64 · r ≤ 2^128).
#[inline]
pub fn band_hash_mod_n(band: &[u64], n: u64) -> u64 {
    assert!(n > 0, "modulus must be positive");
    debug_assert!(band.len() < (1usize << 60), "band too long for exact u128 sum");
    let mut acc: u128 = 0;
    for &h in band {
        acc += h as u128;
    }
    (acc % n as u128) as u64
}

/// Band hash over a signature matrix row layout: given the signature
/// slice for one document (`P` values) and band geometry, produce all `b`
/// band hashes (wrapping variant).
#[inline]
pub fn band_hashes_for_doc(sig: &[u64], num_bands: usize, rows_per_band: usize, out: &mut Vec<u64>) {
    debug_assert!(num_bands * rows_per_band <= sig.len());
    out.clear();
    for band in 0..num_bands {
        let start = band * rows_per_band;
        out.push(band_hash_wrapping(&sig[start..start + rows_per_band]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn wrapping_equals_mod_2_64() {
        let mut rng = Xoshiro256pp::seeded(77);
        for len in [1usize, 2, 13, 128, 256] {
            let band: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let wrap = band_hash_wrapping(&band);
            // mod 2^64 via u128 reference
            let total: u128 = band.iter().map(|&x| x as u128).sum();
            assert_eq!(wrap, (total & 0xFFFF_FFFF_FFFF_FFFF) as u64, "len={len}");
        }
    }

    #[test]
    fn mod_n_matches_naive_bigsum() {
        let mut rng = Xoshiro256pp::seeded(3);
        let band: Vec<u64> = (0..100).map(|_| rng.next_u64()).collect();
        let total: u128 = band.iter().map(|&x| x as u128).sum();
        for n in [2u64, 3, 1 << 32, (1 << 61) - 1, u64::MAX] {
            assert_eq!(band_hash_mod_n(&band, n) as u128, total % n as u128);
        }
    }

    #[test]
    fn empty_band_hashes_to_zero() {
        assert_eq!(band_hash_wrapping(&[]), 0);
        assert_eq!(band_hash_mod_n(&[], 12345), 0);
    }

    #[test]
    fn order_invariance() {
        // Addition commutes: band hash must not depend on row order
        // (it is a hash of the multiset of values in the band).
        let band = [5u64, u64::MAX, 17, 0, 9999];
        let mut rev = band;
        rev.reverse();
        assert_eq!(band_hash_wrapping(&band), band_hash_wrapping(&rev));
    }

    #[test]
    fn doc_band_layout() {
        let sig: Vec<u64> = (0..10).collect();
        let mut out = Vec::new();
        band_hashes_for_doc(&sig, 3, 3, &mut out); // uses rows 0..9
        assert_eq!(out, vec![0 + 1 + 2, 3 + 4 + 5, 6 + 7 + 8]);
    }
}
