//! The mix64 MinHash permutation family (XLA-facing).
//!
//! `perm_i(h) = mix64(h ^ seed_i)` — a bijective u64 mixer applied to the
//! XOR of the token hash and a per-permutation seed. This is the family
//! the Pallas kernel implements (`python/compile/kernels/minhash.py`);
//! the native rust backend here must stay bit-for-bit identical, which
//! the golden-vector test (`rust/tests/xla_backend.rs`) enforces.

pub use crate::rng::mix64;
use crate::rng::SplitMix64;

/// Master seed for the permutation-seed stream.
///
/// Mirrors `python/compile/aot.py::PERM_MASTER_SEED`; both sides derive
/// `seeds[i]` as the i-th output of splitmix64 seeded with this constant.
pub const PERM_MASTER_SEED: u64 = 0x53_48_42_6C_6F_6F_6D; // b"SHBloom"

/// Apply permutation `seed` to token hash `h`.
#[inline(always)]
pub fn perm(h: u64, seed: u64) -> u64 {
    mix64(h ^ seed)
}

/// Derive `n` permutation seeds from a master seed.
pub fn derive_seeds(master: u64, n: usize) -> Vec<u64> {
    let mut sm = SplitMix64::new(master);
    (0..n).map(|_| sm.next_u64()).collect()
}

/// The default seed set used by the pipeline (and baked into golden.json).
pub fn default_seeds(n: usize) -> Vec<u64> {
    derive_seeds(PERM_MASTER_SEED, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perm_is_bijective_ish() {
        // mix64 is a bijection; distinct inputs never collide.
        let seed = 0xDEAD_BEEF;
        let mut outs: Vec<u64> = (0..10_000u64).map(|h| perm(h, seed)).collect();
        outs.sort_unstable();
        outs.dedup();
        assert_eq!(outs.len(), 10_000);
    }

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let a = derive_seeds(PERM_MASTER_SEED, 256);
        let b = derive_seeds(PERM_MASTER_SEED, 256);
        assert_eq!(a, b);
        let mut u = a.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 256, "seed collision");
    }

    #[test]
    fn perm_distributes_minima_uniformly() {
        // Min-wise property smoke test: over random sets, each element
        // should be the argmin under a random permutation ~uniformly.
        let seeds = derive_seeds(1234, 512);
        let set: Vec<u64> = (0..8u64).map(|i| crate::rng::mix64(i + 100)).collect();
        let mut wins = [0u32; 8];
        for &s in &seeds {
            let (argmin, _) = set
                .iter()
                .enumerate()
                .map(|(i, &h)| (i, perm(h, s)))
                .min_by_key(|&(_, v)| v)
                .unwrap();
            wins[argmin] += 1;
        }
        // Each of 8 elements expects 64 wins out of 512; allow wide slack.
        for (i, w) in wins.iter().enumerate() {
            assert!((20..=130).contains(w), "element {i} won {w}/512 times");
        }
    }
}
