//! A faithful simulation of CPython's arbitrary-precision integers.
//!
//! Paper §4.4.1 attributes >90% of LSHBloom's original insert/query time
//! to Python's software bigint representation ("stores extended integers
//! as base-10 strings" — in CPython the internal representation is
//! base-2^30 digit arrays; the performance pathology is the same: heap
//! allocation per intermediate plus digit-by-digit carry loops). This
//! module reproduces that arithmetic so the §4.4.1 comparison
//! (pybigint vs fixed-precision u128) can be benchmarked on identical
//! hardware in `cargo bench --bench micro_bandhash`.
//!
//! Only the operations the band-hash needs are implemented: add u64,
//! modulo u64.

/// CPython-style digit size (30 bits per digit on 64-bit builds).
const SHIFT: u32 = 30;
const MASK: u32 = (1 << SHIFT) - 1;

/// Non-negative arbitrary-precision integer, base-2^30 digits, little-endian.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PyBigInt {
    digits: Vec<u32>,
}

impl PyBigInt {
    /// Zero.
    pub fn zero() -> Self {
        Self { digits: Vec::new() }
    }

    /// From a u64 (splits into up to three 30-bit digits, like CPython's
    /// `PyLong_FromUnsignedLongLong`).
    pub fn from_u64(mut v: u64) -> Self {
        let mut digits = Vec::new();
        while v > 0 {
            digits.push((v as u32) & MASK);
            v >>= SHIFT;
        }
        Self { digits }
    }

    /// `self + rhs`, allocating a fresh result — as CPython's `x_add`
    /// does for every `+=` on an int (ints are immutable).
    pub fn add_u64(&self, rhs: u64) -> Self {
        self.add(&Self::from_u64(rhs))
    }

    /// Digit-by-digit schoolbook addition with carry (CPython `x_add`).
    pub fn add(&self, rhs: &Self) -> Self {
        let (longer, shorter) = if self.digits.len() >= rhs.digits.len() {
            (&self.digits, &rhs.digits)
        } else {
            (&rhs.digits, &self.digits)
        };
        let mut out = Vec::with_capacity(longer.len() + 1);
        let mut carry: u32 = 0;
        for i in 0..longer.len() {
            let mut s = longer[i].wrapping_add(carry);
            if i < shorter.len() {
                s = s.wrapping_add(shorter[i]);
            }
            out.push(s & MASK);
            carry = s >> SHIFT;
        }
        if carry > 0 {
            out.push(carry);
        }
        Self { digits: out }
    }

    /// `self mod n` for u64 modulus (CPython `divrem1`-style long division
    /// digit loop, most-significant first).
    pub fn mod_u64(&self, n: u64) -> u64 {
        assert!(n > 0);
        let mut rem: u128 = 0;
        for &d in self.digits.iter().rev() {
            rem = ((rem << SHIFT) | d as u128) % n as u128;
        }
        rem as u64
    }

    /// Value as u128 (panics if it does not fit; test helper).
    pub fn to_u128(&self) -> u128 {
        let mut v: u128 = 0;
        for &d in self.digits.iter().rev() {
            v = (v << SHIFT) | d as u128;
        }
        v
    }
}

/// The §4.4.1 *baseline* band hash: bigint accumulation then modulo.
/// Each `+=` allocates, exactly like the original Python implementation.
pub fn band_hash_pybigint(band: &[u64], n: u64) -> u64 {
    let mut acc = PyBigInt::zero();
    for &h in band {
        acc = acc.add_u64(h);
    }
    acc.mod_u64(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn from_u64_roundtrip() {
        for v in [0u64, 1, MASK as u64, (MASK as u64) + 1, u64::MAX] {
            assert_eq!(PyBigInt::from_u64(v).to_u128(), v as u128);
        }
    }

    #[test]
    fn add_matches_u128() {
        let mut rng = Xoshiro256pp::seeded(21);
        let mut acc = PyBigInt::zero();
        let mut reference: u128 = 0;
        for _ in 0..300 {
            let v = rng.next_u64();
            acc = acc.add_u64(v);
            reference += v as u128;
            assert_eq!(acc.to_u128(), reference);
        }
    }

    #[test]
    fn mod_matches_u128() {
        let mut rng = Xoshiro256pp::seeded(22);
        let band: Vec<u64> = (0..256).map(|_| rng.next_u64()).collect();
        let total: u128 = band.iter().map(|&x| x as u128).sum();
        for n in [3u64, 1 << 32, (1 << 61) - 1, u64::MAX] {
            assert_eq!(band_hash_pybigint(&band, n) as u128, total % n as u128);
        }
    }

    #[test]
    fn agrees_with_fixed_precision_routines() {
        let mut rng = Xoshiro256pp::seeded(23);
        for len in [1usize, 8, 13, 256] {
            let band: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let n = (1u64 << 61) - 1;
            assert_eq!(
                band_hash_pybigint(&band, n),
                super::super::band::band_hash_mod_n(&band, n),
                "len={len}"
            );
        }
    }
}
