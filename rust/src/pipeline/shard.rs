//! Sharded deduplication with progressive aggregation (paper §6 future
//! work: "splitting the dataset into subsets for processing and
//! progressively aggregating each reduced subset").
//!
//! Phase 1: the stream is split into `S` shards; each shard is deduped
//! *independently* (in parallel across shards) with its own LSHBloom
//! index, discarding within-shard duplicates.
//! Phase 2: shard survivors are re-deduped sequentially against a single
//! aggregate index, catching cross-shard duplicates.
//!
//! The final survivor set equals the sequential result whenever the
//! duplicate relation is transitively closed through originals (a
//! duplicate's duplicate also matches the original) — the property the
//! `matches_sequential_on_labeled_corpus` test exercises; order of
//! survivors follows (shard, position).

use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::methods::lshbloom::{decider_from_config, BandPreparer};
use crate::methods::{Decider, Preparer};
use crate::minhash::{optimal_param, MinHasher, PermFamily};
use std::sync::Arc;

/// Result of a sharded run.
#[derive(Debug)]
pub struct ShardedStats {
    /// Survivor documents (non-duplicates), aggregation order.
    pub survivors: Vec<Doc>,
    /// Duplicates dropped in phase 1 (within-shard).
    pub phase1_dropped: u64,
    /// Duplicates dropped in phase 2 (cross-shard).
    pub phase2_dropped: u64,
    /// Total documents seen.
    pub docs: u64,
}

/// Dedup `docs` across `num_shards` shards with progressive aggregation.
pub fn dedup_sharded(cfg: &PipelineConfig, docs: Vec<Doc>, num_shards: usize) -> ShardedStats {
    assert!(num_shards > 0);
    let lsh = optimal_param(cfg.threshold, cfg.num_perms);
    let preparer = Arc::new(BandPreparer {
        hasher: MinHasher::new(PermFamily::Mix64, lsh.rows_used(), cfg.ngram),
        lsh,
    });
    let total = docs.len() as u64;

    // Phase 1: round-robin shard assignment preserving in-shard order,
    // then parallel per-shard dedup.
    let mut shards: Vec<Vec<Doc>> = (0..num_shards).map(|_| Vec::new()).collect();
    for (i, doc) in docs.into_iter().enumerate() {
        shards[i % num_shards].push(doc);
    }

    let shard_results: Vec<(Vec<Doc>, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                let preparer = Arc::clone(&preparer);
                let shard_cfg = cfg.clone();
                scope.spawn(move || {
                    let mut decider = decider_from_config(&shard_cfg, lsh);
                    let mut survivors = Vec::with_capacity(shard.len());
                    let mut dropped = 0u64;
                    for doc in shard {
                        let prep = preparer.prepare_batch(std::slice::from_ref(&doc));
                        if decider.decide(&prep[0]) {
                            dropped += 1;
                        } else {
                            survivors.push(doc);
                        }
                    }
                    (survivors, dropped)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });

    let phase1_dropped: u64 = shard_results.iter().map(|(_, d)| *d).sum();

    // Phase 2: aggregate survivors sequentially against a fresh index.
    let mut agg = decider_from_config(cfg, lsh);
    let mut survivors = Vec::new();
    let mut phase2_dropped = 0u64;
    for (shard_survivors, _) in shard_results {
        for doc in shard_survivors {
            let prep = preparer.prepare_batch(std::slice::from_ref(&doc));
            if agg.decide(&prep[0]) {
                phase2_dropped += 1;
            } else {
                survivors.push(doc);
            }
        }
    }

    ShardedStats { survivors, phase1_dropped, phase2_dropped, docs: total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};
    use crate::methods::lshbloom::lshbloom_method;

    fn cfg() -> PipelineConfig {
        PipelineConfig { num_perms: 64, expected_docs: 10_000, ..Default::default() }
    }

    #[test]
    fn matches_sequential_on_labeled_corpus() {
        let c = LabeledCorpus::build(DatasetSpec::testing(23, 240, 0.5));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();

        let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
        let seq_verdicts = seq.process_all(&c.docs);
        let seq_survivors = seq_verdicts.iter().filter(|&&v| !v).count();

        for shards in [1usize, 2, 4, 7] {
            let stats = dedup_sharded(&cfg(), docs.clone(), shards);
            assert_eq!(stats.docs, 240);
            // Borderline near-duplicates (truncations straddling T) may
            // resolve differently depending on which variant is seen
            // first, so sharded order can drift by a few documents; exact
            // duplicates are covered by the property test in
            // props_coordinator.rs, which requires strict equality.
            let drift = stats.survivors.len().abs_diff(seq_survivors);
            assert!(drift <= 3, "shards={shards}: survivor drift {drift}");
            assert_eq!(
                stats.phase1_dropped + stats.phase2_dropped + stats.survivors.len() as u64,
                240
            );
        }
    }

    #[test]
    fn single_shard_equals_plain_run() {
        let c = LabeledCorpus::build(DatasetSpec::testing(29, 100, 0.4));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();
        let stats = dedup_sharded(&cfg(), docs, 1);
        assert_eq!(stats.phase2_dropped, 0, "one shard has no cross-shard dups");
    }

    #[test]
    fn no_duplicates_all_survive() {
        let c = LabeledCorpus::build(DatasetSpec::testing(31, 80, 0.0));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();
        let stats = dedup_sharded(&cfg(), docs, 4);
        assert_eq!(stats.survivors.len(), 80);
        assert_eq!(stats.phase1_dropped + stats.phase2_dropped, 0);
    }
}
