//! Sharded deduplication with progressive bit-OR aggregation (paper §6
//! future work: "splitting the dataset into subsets for processing and
//! progressively aggregating each reduced subset"), running on the
//! lock-free [`crate::engine`].
//!
//! Phase 1: the stream is split round-robin into `S` shards; each shard
//! is deduped *independently* (in parallel across shards) by its own
//! [`ConcurrentEngine`] — batched MinHash + lock-free atomic-Bloom
//! probes via [`ConcurrentEngine::submit_with_bands`], which also hands
//! back every document's band hashes so they are computed exactly once.
//! Phase 2: shards are aggregated in shard order against a running
//! **bit-OR union** of the per-shard filters: each shard's survivors are
//! rechecked with a pure `query` of the stored phase-1 band hashes
//! (zero re-MinHashing), then the shard's whole filter is folded into
//! the aggregate with [`ConcurrentLshBloomIndex::union_from`] — one
//! `fetch_or` per word, no index rebuild, no re-insertion.
//!
//! ## The bit-OR aggregation invariant
//!
//! Bloom filters are monotone bit-sets, so the union of two filters with
//! identical geometry answers `true` for exactly the keys either filter
//! answers `true` for. Because phase 1 inserts *every* document's bands
//! (duplicates included — the same rule as the sequential single-pass
//! insert), the running union after folding shards `0..s` contains
//! precisely the bits a single sequential index would contain after
//! ingesting those shards' documents. A shard-`s` survivor is therefore
//! dropped in phase 2 iff it collides with *any* earlier-shard document,
//! originals and duplicates alike — the same membership rule as the
//! unsharded run.
//!
//! ## Equality and ordering caveats
//!
//! The final survivor *count* equals the sequential result on corpora
//! whose duplicate relation is transitively closed through originals
//! (exact duplicates always are — the `props_coordinator` property test
//! requires strict equality there), and for exact duplicates the
//! surviving *content set* matches too. Which *copy* survives can
//! differ, though: aggregation runs in shard order, not stream order,
//! so a duplicate pair split across shards may keep the copy and drop
//! the stream-first original — position-based labels score that swap as
//! one false positive plus one false negative even when the content set
//! is exactly right (the `dedup` CLI prints a caveat with
//! `--report-fidelity`). Borderline near-duplicates that straddle the
//! threshold may additionally resolve to different survivor counts.
//! Survivor order follows (shard, in-shard position). Within one shard the engine's
//! intra-batch reconcile keeps verdicts deterministic and equal to the
//! sequential decider (see `engine::batch`); the engine's concurrency is
//! confined to `submit` internals, so the linearizability caveat of
//! unsynchronized `insert_if_new_shared` callers does not apply here —
//! shard workers never share a live index, and phase 2 reads each shard
//! filter only after joining its thread (a happens-before edge, so no
//! in-flight bits can be missed by the union).

use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::engine::{ConcurrentEngine, ConcurrentLshBloomIndex};
use crate::error::Result;
use crate::index::lshbloom::LshBloomConfig;
use crate::minhash::optimal_param;
use std::path::Path;
use std::time::{Duration, Instant};

/// Result of a sharded run.
#[derive(Debug)]
pub struct ShardedStats {
    /// Survivor documents (non-duplicates), aggregation order.
    pub survivors: Vec<Doc>,
    /// Per-document duplicate verdicts in original stream order
    /// (`true` = dropped in either phase).
    pub verdicts: Vec<bool>,
    /// Duplicates dropped in phase 1 (within-shard).
    pub phase1_dropped: u64,
    /// Duplicates dropped in phase 2 (cross-shard).
    pub phase2_dropped: u64,
    /// Total documents seen.
    pub docs: u64,
    /// Footprint of the aggregate index (static: sized by capacity).
    pub disk_bytes: u64,
    /// Wall time of the parallel per-shard dedup phase.
    pub phase1_wall: Duration,
    /// Wall time of the recheck + bit-OR aggregation phase.
    pub phase2_wall: Duration,
}

impl ShardedStats {
    /// Documents per second end-to-end (both phases).
    pub fn throughput(&self) -> f64 {
        let wall = (self.phase1_wall + self.phase2_wall).as_secs_f64();
        self.docs as f64 / wall.max(1e-9)
    }
}

/// Per-shard phase-1 output: kept documents with their stream position
/// and band hashes, dropped documents' stream positions, and the shard's
/// filled filter for the phase-2 union — in memory, or `None` when the
/// shard checkpointed it to disk (the cross-process path).
type ShardOutcome = (Vec<(usize, Doc, Vec<u64>)>, Vec<usize>, Option<ConcurrentLshBloomIndex>);

/// Running phase-2 state shared by the in-process sharded path (below)
/// and the distributed supervisor (`super::supervisor`): the cross-shard
/// bit-OR union plus verdict/survivor accounting.
///
/// Both paths MUST fold through this one type, shard by shard in shard
/// order — the distributed mode's byte-identical-verdicts guarantee
/// rests on the recheck rule living in exactly one place.
pub(crate) struct ShardAggregator {
    agg: ConcurrentLshBloomIndex,
    /// Per-document duplicate verdicts, original stream order.
    pub(crate) verdicts: Vec<bool>,
    /// Kept documents, (shard, in-shard position) order.
    pub(crate) survivors: Vec<Doc>,
    /// Documents dropped within their shard (phase 1).
    pub(crate) phase1_dropped: u64,
    /// Shard survivors dropped against the cross-shard union (phase 2).
    pub(crate) phase2_dropped: u64,
}

impl ShardAggregator {
    /// Empty union sized from the same config fields every shard engine
    /// used, so geometry mismatches are impossible by construction.
    pub(crate) fn new(cfg: &PipelineConfig, total: usize) -> Self {
        let agg = ConcurrentLshBloomIndex::new(LshBloomConfig::new(
            optimal_param(cfg.threshold, cfg.num_perms),
            cfg.p_effective,
            cfg.expected_docs,
        ));
        Self {
            agg,
            verdicts: vec![false; total],
            survivors: Vec::new(),
            phase1_dropped: 0,
            phase2_dropped: 0,
        }
    }

    /// Record a phase-1 verdict: dropped within its shard.
    pub(crate) fn mark_dropped(&mut self, pos: usize) {
        self.verdicts[pos] = true;
        self.phase1_dropped += 1;
    }

    /// Recheck one shard survivor (stream position + phase-1 band
    /// hashes) against the running union: dropped iff it collides with
    /// any earlier-folded shard. Takes the document by value so the
    /// in-process path moves rather than clones its survivors.
    pub(crate) fn recheck(&mut self, pos: usize, doc: Doc, bands: &[u64]) {
        if self.agg.query(bands) {
            self.phase2_dropped += 1;
            self.verdicts[pos] = true;
        } else {
            self.survivors.push(doc);
        }
    }

    /// Fold a finished shard's filter into the union from memory…
    pub(crate) fn union_from_index(&mut self, index: &ConcurrentLshBloomIndex) {
        self.agg.union_from(index);
    }

    /// …or straight from its persisted checkpoint.
    pub(crate) fn union_from_checkpoint(&mut self, dir: &Path) -> Result<()> {
        crate::persist::union_from_checkpoint(&self.agg, dir)?;
        Ok(())
    }

    /// The live union (the distributed supervisor persists it as the
    /// serve-ready aggregate checkpoint).
    pub(crate) fn index(&self) -> &ConcurrentLshBloomIndex {
        &self.agg
    }

    /// Finish: package the accounting into [`ShardedStats`].
    pub(crate) fn into_stats(
        self,
        docs: u64,
        phase1_wall: Duration,
        phase2_wall: Duration,
    ) -> ShardedStats {
        let disk_bytes = self.agg.disk_bytes();
        ShardedStats {
            survivors: self.survivors,
            verdicts: self.verdicts,
            phase1_dropped: self.phase1_dropped,
            phase2_dropped: self.phase2_dropped,
            docs,
            disk_bytes,
            phase1_wall,
            phase2_wall,
        }
    }
}

/// Dedup `docs` across `num_shards` shards with progressive aggregation
/// (in-memory filter union).
pub fn dedup_sharded(cfg: &PipelineConfig, docs: Vec<Doc>, num_shards: usize) -> ShardedStats {
    dedup_sharded_with_state(cfg, docs, num_shards, None)
        .expect("in-memory sharded dedup cannot fail")
}

/// [`dedup_sharded`] with an optional on-disk aggregation seam: with
/// `state_dir`, every shard *checkpoints* its filled filter to
/// `state_dir/shard-{s:03}/` (full [`crate::persist`] manifest +
/// per-band bit files) and phase 2 folds each shard in with
/// [`crate::persist::union_from_checkpoint`] — straight from the files,
/// exactly as a sibling *process* would consume them. This is the
/// cross-process half of the §6 seam: the shard checkpoints double as
/// the wire format for multi-process (and later multi-node) aggregation,
/// and the survivor sets are identical to the in-memory union (the files
/// hold the same bits the live filters do).
///
/// # Examples
///
/// ```
/// use lshbloom::config::PipelineConfig;
/// use lshbloom::corpus::Doc;
/// use lshbloom::pipeline::dedup_sharded_with_state;
///
/// let cfg = PipelineConfig {
///     num_perms: 64,
///     expected_docs: 10_000,
///     workers: 2,
///     ..Default::default()
/// };
/// let docs = vec![
///     Doc { id: 0, text: "alpha beta gamma delta epsilon".into() },
///     Doc { id: 1, text: "totally different words over here".into() },
///     Doc { id: 2, text: "alpha beta gamma delta epsilon".into() }, // exact copy of 0
/// ];
/// // Two shards, in-memory aggregation (pass a state dir for the
/// // on-disk union a sibling process could consume).
/// let stats = dedup_sharded_with_state(&cfg, docs, 2, None)?;
/// assert_eq!(stats.verdicts, [false, false, true]);
/// assert_eq!(stats.survivors.len(), 2);
/// # Ok::<(), lshbloom::error::Error>(())
/// ```
pub fn dedup_sharded_with_state(
    cfg: &PipelineConfig,
    docs: Vec<Doc>,
    num_shards: usize,
    state_dir: Option<&Path>,
) -> Result<ShardedStats> {
    assert!(num_shards > 0);
    let total = docs.len();
    // Split the worker budget across shard engines; each shard engine
    // runs its own scoped pool inside `submit`.
    let mut shard_cfg = cfg.clone();
    shard_cfg.workers = (cfg.effective_workers() / num_shards).max(1);
    let super_batch = cfg.batch_size.max(1) * shard_cfg.workers;

    // Round-robin shard assignment preserving in-shard stream order,
    // remembering each document's stream position for the verdict vector.
    let mut shard_docs: Vec<Vec<Doc>> = (0..num_shards).map(|_| Vec::new()).collect();
    let mut shard_pos: Vec<Vec<usize>> = (0..num_shards).map(|_| Vec::new()).collect();
    for (i, doc) in docs.into_iter().enumerate() {
        shard_docs[i % num_shards].push(doc);
        shard_pos[i % num_shards].push(i);
    }

    // Phase 1: engine-backed per-shard dedup, in parallel across shards.
    // With a state dir, each shard also checkpoints its filled filter
    // before returning (inside the shard thread, so checkpoint IO
    // overlaps across shards).
    let t1 = Instant::now();
    let shard_results: Vec<ShardOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = shard_docs
            .into_iter()
            .zip(shard_pos)
            .enumerate()
            .map(|(s, (docs, pos))| {
                let shard_cfg = shard_cfg.clone();
                let shard_state = state_dir.map(|d| d.join(format!("shard-{s:03}")));
                scope.spawn(move || -> Result<ShardOutcome> {
                    let engine = ConcurrentEngine::from_config(&shard_cfg);
                    let mut flags = Vec::with_capacity(docs.len());
                    let mut bands = Vec::with_capacity(docs.len());
                    for chunk in docs.chunks(super_batch) {
                        let (decisions, chunk_bands) = engine.submit_with_bands(chunk);
                        flags.extend(decisions.into_iter().map(|d| d.duplicate));
                        bands.extend(chunk_bands);
                    }
                    let mut survivors = Vec::new();
                    let mut dropped = Vec::new();
                    let fates = docs.into_iter().zip(pos).zip(flags.into_iter().zip(bands));
                    for ((doc, p), (dup, doc_bands)) in fates {
                        if dup {
                            dropped.push(p);
                        } else {
                            survivors.push((p, doc, doc_bands));
                        }
                    }
                    let index = match &shard_state {
                        Some(dir) => {
                            engine.checkpoint(dir)?;
                            None // phase 2 reads the files, as a sibling process would
                        }
                        None => Some(engine.into_concurrent_index()),
                    };
                    Ok((survivors, dropped, index))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let phase1_wall = t1.elapsed();

    // Phase 2: recheck survivors against the running cross-shard union,
    // reusing the phase-1 band hashes, then fold each shard's filter in
    // — from memory, or straight from its persisted checkpoint. Shard
    // 0's survivors all pass (the union starts empty). The recheck/fold
    // rule lives in [`ShardAggregator`], shared with the distributed
    // supervisor (and `union_from_checkpoint` re-verifies geometry
    // against each manifest anyway).
    let t2 = Instant::now();
    let mut agg = ShardAggregator::new(cfg, total);
    for (s, (shard_survivors, dropped, shard_index)) in shard_results.into_iter().enumerate() {
        for p in dropped {
            agg.mark_dropped(p);
        }
        for (p, doc, bands) in shard_survivors {
            agg.recheck(p, doc, &bands);
        }
        match shard_index {
            Some(index) => agg.union_from_index(&index),
            None => {
                let dir = state_dir
                    .expect("index omitted only in state-dir mode")
                    .join(format!("shard-{s:03}"));
                agg.union_from_checkpoint(&dir)?;
            }
        }
    }
    let phase2_wall = t2.elapsed();

    Ok(agg.into_stats(total as u64, phase1_wall, phase2_wall))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};
    use crate::methods::lshbloom::lshbloom_method;
    use crate::minhash::PermFamily;

    fn cfg() -> PipelineConfig {
        PipelineConfig { num_perms: 64, expected_docs: 10_000, ..Default::default() }
    }

    #[test]
    fn matches_sequential_on_labeled_corpus() {
        let c = LabeledCorpus::build(DatasetSpec::testing(23, 240, 0.5));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();

        let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
        let seq_verdicts = seq.process_all(&c.docs);
        let seq_survivors = seq_verdicts.iter().filter(|&&v| !v).count();

        for shards in [1usize, 2, 4, 7] {
            let stats = dedup_sharded(&cfg(), docs.clone(), shards);
            assert_eq!(stats.docs, 240);
            assert_eq!(stats.verdicts.len(), 240);
            // Borderline near-duplicates (truncations straddling T) may
            // resolve differently depending on which variant is seen
            // first, so sharded order can drift by a few documents; exact
            // duplicates are covered by the property test in
            // props_coordinator.rs, which requires strict equality.
            let drift = stats.survivors.len().abs_diff(seq_survivors);
            assert!(drift <= 3, "shards={shards}: survivor drift {drift}");
            assert_eq!(
                stats.phase1_dropped + stats.phase2_dropped + stats.survivors.len() as u64,
                240
            );
            // The stream-order verdict vector agrees with the counters.
            assert_eq!(
                stats.verdicts.iter().filter(|&&v| !v).count(),
                stats.survivors.len()
            );
        }
    }

    #[test]
    fn single_shard_equals_plain_run() {
        let c = LabeledCorpus::build(DatasetSpec::testing(29, 100, 0.4));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();

        let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
        let seq_verdicts = seq.process_all(&c.docs);

        let stats = dedup_sharded(&cfg(), docs, 1);
        assert_eq!(stats.phase2_dropped, 0, "one shard has no cross-shard dups");
        // One shard is the whole stream through one engine: verdicts are
        // exactly the sequential decider's (the engine equivalence
        // contract), position for position.
        assert_eq!(stats.verdicts, seq_verdicts);
    }

    #[test]
    fn no_duplicates_all_survive() {
        let c = LabeledCorpus::build(DatasetSpec::testing(31, 80, 0.0));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();
        let stats = dedup_sharded(&cfg(), docs, 4);
        assert_eq!(stats.survivors.len(), 80);
        assert_eq!(stats.phase1_dropped + stats.phase2_dropped, 0);
        assert!(stats.verdicts.iter().all(|&v| !v));
        assert!(stats.disk_bytes > 0);
    }

    #[test]
    fn state_dir_union_matches_in_memory_union() {
        // The on-disk aggregation path must reproduce the in-memory
        // bit-OR exactly: same verdict vector, same survivor contents.
        let dir = std::env::temp_dir().join(format!("lshbloom-shard-state-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let c = LabeledCorpus::build(DatasetSpec::testing(41, 200, 0.5));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();
        for shards in [2usize, 5] {
            let mem = dedup_sharded(&cfg(), docs.clone(), shards);
            let disk =
                dedup_sharded_with_state(&cfg(), docs.clone(), shards, Some(dir.as_path()))
                    .unwrap();
            assert_eq!(disk.verdicts, mem.verdicts, "shards={shards}");
            assert_eq!(disk.survivors.len(), mem.survivors.len());
            assert_eq!(disk.phase1_dropped, mem.phase1_dropped);
            assert_eq!(disk.phase2_dropped, mem.phase2_dropped);
            // The shard checkpoints are complete, manifest-described
            // state a sibling process could consume.
            for s in 0..shards {
                let sdir = dir.join(format!("shard-{s:03}"));
                assert!(
                    crate::persist::CheckpointManifest::exists(&sdir),
                    "shard {s} left no manifest"
                );
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn more_shards_than_docs() {
        let c = LabeledCorpus::build(DatasetSpec::testing(37, 5, 0.0));
        let docs: Vec<Doc> = c.docs.iter().map(|ld| ld.doc.clone()).collect();
        let stats = dedup_sharded(&cfg(), docs, 16);
        assert_eq!(stats.survivors.len(), 5);
        assert_eq!(stats.docs, 5);
    }

    #[test]
    fn empty_stream() {
        let stats = dedup_sharded(&cfg(), Vec::new(), 4);
        assert_eq!(stats.docs, 0);
        assert!(stats.survivors.is_empty());
        assert!(stats.verdicts.is_empty());
    }
}
