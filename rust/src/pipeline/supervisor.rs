//! Multi-process sharded deduplication: a supervising orchestrator that
//! launches one OS **worker process per shard** and aggregates their
//! published checkpoints (`dedup --shards N --distributed`).
//!
//! This crosses the seam PR 3 prepared: the checkpoint directory format
//! ([`crate::persist`]) is the *only* channel between the supervisor and
//! its workers — no shared memory, no pipes beyond stdout/stderr logs,
//! no sockets. A worker ingests its shard slice through a private
//! [`ConcurrentEngine`], streams per-document outcomes to disk, publishes
//! its filled filters as an engine checkpoint, and finally writes a
//! [`WorkerManifest`] (tmp + rename) as its completion marker. The
//! supervisor spawns the workers by **self-exec** (`<current binary>
//! worker --shard s …`), watches their exit statuses, restarts a crashed
//! worker once with `--resume`, and then runs phase-2 aggregation
//! entirely from the published directories via
//! [`crate::persist::union_from_checkpoint`].
//!
//! ```text
//!  supervisor (dedup --shards N --distributed --checkpoint-dir STATE)
//!    ├─ spawn: self-exec `worker --shard 0 … --dir STATE/worker-000`
//!    ├─ spawn: self-exec `worker --shard 1 … --dir STATE/worker-001`
//!    │    …                       (monitor exits; restart-once on crash)
//!    └─ phase 2: for each shard in order
//!         recheck outcomes.jsonl survivors against the running union,
//!         then bit-OR the shard checkpoint in (union_from_checkpoint);
//!       finally publish the aggregate checkpoint at STATE/ for
//!       `serve --state-dir STATE`.
//! ```
//!
//! ## Equivalence with the in-process sharded run
//!
//! Workers split the stream round-robin (`pos % N == shard`) exactly
//! like [`super::shard::dedup_sharded`], engine verdicts are
//! deterministic and batch-size independent (see `engine::batch`), and
//! phase 2 applies the same shard-order recheck + bit-OR rule — so a
//! distributed run's verdict vector is identical to the in-process
//! `--shards N` run (enforced by `rust/tests/distributed_shard.rs`).
//!
//! ## Crash recovery
//!
//! Workers checkpoint **cold snapshots** every `checkpoint_every`
//! documents, with the outcomes file fsync'd *before* each snapshot, so
//! a restored engine holds exactly the bits of an uninterrupted run at
//! that boundary (never the mmap superset — that would poison verdict
//! determinism for re-processed documents). On restart with `--resume`
//! the worker truncates its outcomes file to the checkpointed prefix and
//! continues; the survivor set is byte-identical to a crash-free run.
//!
//! This is the bridge from "one process, many threads" to "many
//! processes, then many hosts": swapping [`std::process::Command`] for a
//! remote execution endpoint is all the ROADMAP router item still needs.

use super::shard::{ShardAggregator, ShardedStats};
use crate::config::PipelineConfig;
use crate::corpus::{Doc, LabeledDoc};
use crate::engine::ConcurrentEngine;
use crate::error::{Error, Result};
use crate::json::{self, obj, Value};
use crate::persist::{
    worker_dir_name, write_checkpoint, CheckpointManifest, ChecksumStream, WorkerManifest,
    WORKER_CHECKPOINT_DIR, WORKER_OUTCOMES_FILE,
};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Instant;

/// File name of a worker's captured stdout/stderr inside its directory.
pub const WORKER_LOG_FILE: &str = "worker.log";

/// Exit code a worker uses for an injected (test-only) crash.
pub const WORKER_CRASH_EXIT: i32 = 42;

/// Fault-injection env var: shard index that should crash (test hook).
///
/// Together with [`CRASH_AFTER_ENV`], lets the integration tests kill a
/// real worker process mid-ingest deterministically: the matching worker
/// exits with [`WORKER_CRASH_EXIT`] once it has processed at least that
/// many documents. The supervisor strips both variables from restarted
/// workers, so the crash fires exactly once.
pub const CRASH_SHARD_ENV: &str = "LSHBLOOM_WORKER_CRASH_SHARD";

/// Fault-injection env var: crash once processed docs reach this count.
pub const CRASH_AFTER_ENV: &str = "LSHBLOOM_WORKER_CRASH_AFTER_DOCS";

/// Supervisor tuning knobs.
#[derive(Clone, Debug)]
pub struct SupervisorOptions {
    /// Worker binary to self-exec (`None` = `std::env::current_exe()`;
    /// tests pass `env!("CARGO_BIN_EXE_lshbloom")` because their own
    /// `current_exe` is the test harness, not the CLI).
    pub worker_bin: Option<PathBuf>,
    /// How many times a crashed/torn worker is restarted (with
    /// `--resume`) before the run fails. Default 1.
    pub restarts: u32,
    /// Extra env vars for *first-attempt* worker spawns (the
    /// fault-injection hook; restarts never receive these).
    pub worker_env: Vec<(String, String)>,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        Self { worker_bin: None, restarts: 1, worker_env: Vec::new() }
    }
}

/// Result of a distributed run.
#[derive(Debug)]
pub struct DistributedRun {
    /// Aggregated phase-1/phase-2 statistics, identical in shape to the
    /// in-process sharded run.
    pub stats: ShardedStats,
    /// Worker restarts the supervisor performed (0 on a clean run).
    pub restarts: u32,
    /// Threads each worker process ran with.
    pub worker_threads: usize,
}

/// One per-document record in a worker's `outcomes.jsonl`.
struct Outcome {
    /// Original stream position (`line_index * num_shards + shard`).
    pos: usize,
    /// Phase-1 verdict (`true` = dropped within the shard).
    dup: bool,
    /// Band hashes (survivors only — what phase 2 rechecks).
    bands: Vec<u64>,
}

fn parse_outcome(line: &str, path: &Path, lineno: usize) -> Result<Outcome> {
    let context = || format!("{} line {}", path.display(), lineno + 1);
    let v = json::parse(line).map_err(|e| Error::parse(context(), e.to_string()))?;
    let pos = v
        .get("pos")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| Error::parse(context(), "missing 'pos'"))?;
    let dup = v
        .get("dup")
        .and_then(|x| x.as_bool())
        .ok_or_else(|| Error::parse(context(), "missing 'dup'"))?;
    let bands = if dup {
        Vec::new()
    } else {
        v.get("bands")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::parse(context(), "survivor line missing 'bands'"))?
            .iter()
            .map(|b| b.as_u64().ok_or_else(|| Error::parse(context(), "band not a u64")))
            .collect::<Result<Vec<u64>>>()?
    };
    Ok(Outcome { pos, dup, bands })
}

fn outcome_line(pos: usize, dup: bool, bands: &[u64]) -> String {
    let mut fields = vec![("pos", Value::u64(pos as u64)), ("dup", Value::Bool(dup))];
    if !dup {
        fields.push(("bands", Value::Arr(bands.iter().map(|&h| Value::u64(h)).collect())));
    }
    obj(fields).to_json()
}

/// Keep only the first `keep` outcome lines (the prefix the engine
/// checkpoint covers), rewriting the file atomically. Returns the
/// (dropped, survivors) counts among the kept lines so the resumed
/// worker's counters continue exactly.
fn truncate_outcomes(path: &Path, keep: u64) -> Result<(u64, u64)> {
    if keep == 0 {
        crate::persist::remove_file_if_exists(path)?;
        return Ok((0, 0));
    }
    use std::io::BufRead;
    let file = std::fs::File::open(path).map_err(|e| Error::io(path.display().to_string(), e))?;
    let reader = std::io::BufReader::new(file);
    let tmp = path.with_extension("jsonl.tmp");
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(&tmp).map_err(|e| Error::io(tmp.display().to_string(), e))?,
    );
    let mut dropped = 0u64;
    let mut survivors = 0u64;
    let mut n = 0u64;
    for (lineno, line) in reader.lines().enumerate() {
        if n == keep {
            break;
        }
        let line = line.map_err(|e| Error::io(path.display().to_string(), e))?;
        let outcome = parse_outcome(&line, path, lineno)?;
        if outcome.dup {
            dropped += 1;
        } else {
            survivors += 1;
        }
        w.write_all(line.as_bytes()).map_err(|e| Error::io(tmp.display().to_string(), e))?;
        w.write_all(b"\n").map_err(|e| Error::io(tmp.display().to_string(), e))?;
        n += 1;
    }
    if n < keep {
        return Err(Error::Format(format!(
            "outcomes file {} holds {n} complete lines but the engine checkpoint \
             covers {keep} documents; the worker directory is corrupt",
            path.display()
        )));
    }
    let f = w
        .into_inner()
        .map_err(|e| Error::io(tmp.display().to_string(), e.into_error()))?;
    f.sync_all().map_err(|e| Error::io(tmp.display().to_string(), e))?;
    std::fs::rename(&tmp, path).map_err(|e| Error::io(path.display().to_string(), e))?;
    Ok((dropped, survivors))
}

/// File binding a worker directory to one (input, shard layout): the
/// guard that keeps `--resume` from silently adopting checkpointed state
/// from a *different* corpus or shard count, which would produce a
/// corrupt survivor set with no error. Private to the worker — the
/// supervisor never reads it.
const WORKER_BINDING_FILE: &str = "binding.json";

/// Stream this worker's round-robin slice out of the corpus without
/// materializing the rest (positions count non-empty JSONL lines,
/// matching `LabeledCorpus::load_jsonl`). Returns the slice, the total
/// stream length, and a fingerprint over the slice contents + layout
/// that [`run_worker`] uses to bind its resume state to this input.
fn load_shard_slice(
    input: &Path,
    shard: usize,
    num_shards: usize,
) -> Result<(Vec<(usize, Doc)>, usize, u64)> {
    use std::io::BufRead;
    let file =
        std::fs::File::open(input).map_err(|e| Error::io(input.display().to_string(), e))?;
    let reader = std::io::BufReader::new(file);
    let mut docs: Vec<(usize, Doc)> = Vec::new();
    let mut pos = 0usize;
    let mut cs = ChecksumStream::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| Error::io(input.display().to_string(), e))?;
        if line.trim().is_empty() {
            continue;
        }
        if pos % num_shards == shard {
            let bad = |what: &str| {
                Error::parse("corpus", format!("line {}: missing {what}", lineno + 1))
            };
            let v = json::parse(&line)
                .map_err(|e| Error::parse(format!("corpus line {}", lineno + 1), e.to_string()))?;
            let id = v.get("id").and_then(|x| x.as_u64()).ok_or_else(|| bad("id"))?;
            let text = v
                .get("text")
                .and_then(|x| x.as_str())
                .ok_or_else(|| bad("text"))?
                .to_string();
            let mut words = Vec::with_capacity(3 + text.len() / 8 + 1);
            words.extend([pos as u64, id, text.len() as u64]);
            for chunk in text.as_bytes().chunks(8) {
                let mut w = [0u8; 8];
                w[..chunk.len()].copy_from_slice(chunk);
                words.push(u64::from_le_bytes(w));
            }
            cs.update(&words);
            docs.push((pos, Doc { id, text }));
        }
        pos += 1;
    }
    cs.update(&[pos as u64, shard as u64, num_shards as u64]);
    Ok((docs, pos, cs.finish()))
}

/// Record which (input fingerprint, shard layout) this worker directory
/// belongs to. Written at every fresh start, after any stale engine
/// checkpoint has been removed.
fn write_binding(dir: &Path, shard: usize, num_shards: usize, fingerprint: u64) -> Result<()> {
    let path = dir.join(WORKER_BINDING_FILE);
    let doc = obj(vec![
        ("shard", Value::u64(shard as u64)),
        ("num_shards", Value::u64(num_shards as u64)),
        ("fingerprint", Value::u64(fingerprint)),
    ]);
    std::fs::write(&path, doc.to_json()).map_err(|e| Error::io(path.display().to_string(), e))
}

/// Whether the directory's binding matches this run. Any missing or
/// unreadable binding reads as a mismatch (resume then degrades to a
/// fresh start — safe, just slower).
fn binding_matches(dir: &Path, shard: usize, num_shards: usize, fingerprint: u64) -> bool {
    let path = dir.join(WORKER_BINDING_FILE);
    let Ok(text) = std::fs::read_to_string(&path) else { return false };
    let Ok(v) = json::parse(&text) else { return false };
    let field = |k: &str| v.get(k).and_then(|x| x.as_u64());
    field("shard") == Some(shard as u64)
        && field("num_shards") == Some(num_shards as u64)
        && field("fingerprint") == Some(fingerprint)
}

/// Remove a stale engine-checkpoint manifest so a fresh-starting worker
/// that crashes before its first checkpoint cannot leave an adoptable
/// manifest describing the *previous* run's bits. Mirrors
/// `ConcurrentLshBloomIndex::new_shm`'s discipline: failure to remove an
/// existing manifest is a hard error.
fn remove_stale_checkpoint(ckpt: &Path) -> Result<()> {
    for name in [
        crate::persist::MANIFEST_FILE.to_string(),
        format!("{}.tmp", crate::persist::MANIFEST_FILE),
    ] {
        crate::persist::remove_file_if_exists(&ckpt.join(name))?;
    }
    Ok(())
}

/// Whether the (test-only) fault-injection env vars ask this worker to
/// crash now. See [`CRASH_SHARD_ENV`].
fn crash_requested(shard: usize, processed: u64) -> bool {
    let Ok(s) = std::env::var(CRASH_SHARD_ENV) else { return false };
    let Ok(n) = std::env::var(CRASH_AFTER_ENV) else { return false };
    s.parse::<usize>().map(|v| v == shard).unwrap_or(false)
        && n.parse::<u64>().map(|v| processed >= v).unwrap_or(false)
}

/// Run one shard worker to completion: ingest the round-robin slice
/// `pos % num_shards == shard` of `input` through a private
/// [`ConcurrentEngine`], stream per-document outcomes to
/// `dir/outcomes.jsonl`, checkpoint the engine into `dir/checkpoint/`
/// (periodically per `cfg.checkpoint_every`, and always at end of
/// stream), and publish a [`WorkerManifest`] as the completion marker.
///
/// With `resume` and an existing engine checkpoint, the worker restores
/// the snapshot, truncates the outcomes file to the checkpointed prefix,
/// and continues from there; without a checkpoint, `resume` degrades to
/// a fresh start. This is the function behind the `worker` CLI
/// subcommand — the supervisor never calls it in-process.
pub fn run_worker(
    cfg: &PipelineConfig,
    input: &Path,
    shard: usize,
    num_shards: usize,
    dir: &Path,
    resume: bool,
) -> Result<WorkerManifest> {
    if num_shards == 0 || shard >= num_shards {
        return Err(Error::Config(format!(
            "worker shard {shard} out of range for {num_shards} shards"
        )));
    }
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    // A (re)starting worker is by definition incomplete: a stale marker
    // from a previous run must go before any state changes.
    WorkerManifest::remove_stale(dir)?;
    let ckpt = dir.join(WORKER_CHECKPOINT_DIR);
    let outcomes_path = dir.join(WORKER_OUTCOMES_FILE);

    // Stream only this shard's slice into memory (a worker holding the
    // whole corpus would multiply the fleet's footprint by N), and
    // fingerprint it — folding in every parameter that shapes band
    // hashes or filter geometry: resume state is only adoptable for the
    // exact (input, shard layout, parameters) that produced it. A
    // parameter change thus degrades to a fresh start instead of a
    // deterministic restore failure that would burn the restart budget.
    let (shard_docs, _total, slice_fp) = load_shard_slice(input, shard, num_shards)?;
    let fingerprint = {
        let mut cs = ChecksumStream::new();
        cs.update(&[
            slice_fp,
            cfg.threshold.to_bits(),
            cfg.num_perms as u64,
            cfg.ngram as u64,
            cfg.p_effective.to_bits(),
            cfg.expected_docs,
        ]);
        cs.finish()
    };

    let adoptable = resume
        && CheckpointManifest::exists(&ckpt)
        && binding_matches(dir, shard, num_shards, fingerprint);
    if resume && CheckpointManifest::exists(&ckpt) && !adoptable {
        eprintln!(
            "worker {shard}: checkpoint in {} belongs to a different input or shard \
             layout; starting this slice fresh",
            ckpt.display()
        );
    }
    let (engine, mut dropped, mut survivors, skipped) = if adoptable {
        // Cold-snapshot restore (mmap=false): the engine holds exactly
        // the bits of an uninterrupted run at the checkpoint boundary,
        // so re-processing the tail yields identical verdicts. An mmap
        // restore could hold a post-checkpoint superset, which would
        // flag re-processed documents as duplicates of themselves.
        let engine = ConcurrentEngine::restore(cfg, &ckpt, false)?;
        let skipped = engine.stats().0;
        let (dropped, survivors) = truncate_outcomes(&outcomes_path, skipped)?;
        (engine, dropped, survivors, skipped as usize)
    } else {
        // Fresh start: the stale engine manifest goes FIRST (a crash
        // after the binding rewrite but before the first checkpoint must
        // not leave an adoptable manifest over the old bits), then the
        // outcomes, then the new binding.
        remove_stale_checkpoint(&ckpt)?;
        truncate_outcomes(&outcomes_path, 0)?;
        write_binding(dir, shard, num_shards, fingerprint)?;
        (ConcurrentEngine::from_config(cfg), 0, 0, 0)
    };
    if skipped > shard_docs.len() {
        return Err(Error::Format(format!(
            "checkpoint in {} covers {skipped} documents but shard {shard} of {} only \
             holds {}; the worker directory is corrupt",
            ckpt.display(),
            num_shards,
            shard_docs.len()
        )));
    }

    let mut out = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&outcomes_path)
        .map_err(|e| Error::io(outcomes_path.display().to_string(), e))?;
    let super_batch = cfg.batch_size.max(1) * engine.workers();
    let mut processed = skipped;
    let mut since_checkpoint = 0u64;
    for chunk in shard_docs[skipped..].chunks(super_batch) {
        let batch: Vec<Doc> = chunk.iter().map(|(_, doc)| doc.clone()).collect();
        let (decisions, bands) = engine.submit_with_bands(&batch);
        let mut buf = String::new();
        for ((item, decision), doc_bands) in chunk.iter().zip(&decisions).zip(&bands) {
            if decision.duplicate {
                dropped += 1;
            } else {
                survivors += 1;
            }
            buf.push_str(&outcome_line(item.0, decision.duplicate, doc_bands));
            buf.push('\n');
        }
        out.write_all(buf.as_bytes())
            .map_err(|e| Error::io(outcomes_path.display().to_string(), e))?;
        processed += chunk.len();
        since_checkpoint += chunk.len() as u64;
        if cfg.checkpoint_every > 0 && since_checkpoint >= cfg.checkpoint_every {
            // Outcomes become durable BEFORE the engine checkpoint that
            // covers them, so the file always holds at least as many
            // complete lines as the checkpoint's document counter — the
            // invariant the resume-side truncation relies on. Syncing
            // only here (not per super-batch) keeps fsync off the hot
            // ingest loop.
            out.sync_data().map_err(|e| Error::io(outcomes_path.display().to_string(), e))?;
            engine.checkpoint(&ckpt)?;
            since_checkpoint = 0;
        }
        if crash_requested(shard, processed as u64) {
            eprintln!(
                "worker {shard}: injected crash after {processed} documents (test hook)"
            );
            std::process::exit(WORKER_CRASH_EXIT);
        }
    }
    // The final checkpoint IS the published shard filter phase 2 unions;
    // same ordering: outcomes durable first.
    out.sync_data().map_err(|e| Error::io(outcomes_path.display().to_string(), e))?;
    engine.checkpoint(&ckpt)?;
    let manifest = WorkerManifest {
        version: crate::persist::worker::WORKER_MANIFEST_VERSION,
        shard,
        num_shards,
        docs: processed as u64,
        dropped,
        survivors,
    };
    manifest.save(dir)?;
    Ok(manifest)
}

/// Everything constant across worker spawns of one distributed run.
struct WorkerSpawner<'a> {
    bin: PathBuf,
    cfg: &'a PipelineConfig,
    input: &'a Path,
    state_dir: &'a Path,
    num_shards: usize,
    worker_threads: usize,
}

impl WorkerSpawner<'_> {
    /// Spawn the worker process for `shard`, its stdout/stderr appended
    /// to `worker.log` in its directory. Every spawn passes `--resume`
    /// (a worker with no checkpoint just starts fresh), which makes
    /// re-running a failed distributed command incremental: workers pick
    /// up from their snapshots instead of redoing their slices.
    /// `restart` spawns are additionally stripped of the fault-injection
    /// env vars so an injected crash fires at most once.
    fn spawn(&self, shard: usize, restart: bool, env: &[(String, String)]) -> Result<Child> {
        let wdir = self.state_dir.join(worker_dir_name(shard));
        std::fs::create_dir_all(&wdir).map_err(|e| Error::io(wdir.display().to_string(), e))?;
        let log_path = wdir.join(WORKER_LOG_FILE);
        let log = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&log_path)
            .map_err(|e| Error::io(log_path.display().to_string(), e))?;
        let log_err = log.try_clone().map_err(|e| Error::io(log_path.display().to_string(), e))?;
        let mut cmd = Command::new(&self.bin);
        cmd.arg("worker")
            .arg("--input")
            .arg(self.input)
            .arg("--shard")
            .arg(shard.to_string())
            .arg("--shards")
            .arg(self.num_shards.to_string())
            .arg("--dir")
            .arg(&wdir)
            .arg("--threshold")
            .arg(self.cfg.threshold.to_string())
            .arg("--perms")
            .arg(self.cfg.num_perms.to_string())
            .arg("--ngram")
            .arg(self.cfg.ngram.to_string())
            .arg("--p-effective")
            .arg(self.cfg.p_effective.to_string())
            .arg("--expected-docs")
            .arg(self.cfg.expected_docs.to_string())
            .arg("--workers")
            .arg(self.worker_threads.to_string())
            .arg("--batch-size")
            .arg(self.cfg.batch_size.to_string())
            .arg("--checkpoint-every")
            .arg(self.cfg.checkpoint_every.to_string())
            .arg("--rotate-watermark")
            .arg(self.cfg.rotate_watermark.to_string())
            .arg("--resume")
            .stdin(Stdio::null())
            .stdout(Stdio::from(log))
            .stderr(Stdio::from(log_err));
        // The run-level trace crosses the process boundary by env: every
        // spawn — first attempt and restart alike — carries the
        // supervisor's context so each worker's run parents under the
        // distributed-run root span.
        if let Some(ctx) = crate::obs::trace::current_context() {
            cmd.env(crate::obs::trace::TRACE_PARENT_ENV, ctx.encode());
        }
        if restart {
            cmd.env_remove(CRASH_SHARD_ENV).env_remove(CRASH_AFTER_ENV);
        } else {
            for (k, v) in env {
                cmd.env(k, v);
            }
        }
        cmd.spawn().map_err(|e| Error::io(self.bin.display().to_string(), e))
    }
}

fn describe_exit(status: &std::process::ExitStatus) -> String {
    match status.code() {
        Some(code) => format!("exit code {code}"),
        None => "killed by a signal".to_string(),
    }
}

/// Run the full distributed pipeline: spawn one worker process per
/// shard, supervise them (restart-once-with-`--resume` on crash or torn
/// output), aggregate phase 2 from the published checkpoint directories,
/// and leave the aggregate index as a checkpoint at `state_dir` so
/// `serve --state-dir` can warm-start from the whole deduplicated
/// corpus.
///
/// `docs` must be the same corpus `input` holds — passed as the
/// already-loaded [`LabeledDoc`] vector so the CLI hands over its one
/// in-memory copy instead of cloning a second, corpus-sized `Vec<Doc>`
/// (labels are ignored here; only positions and texts are read).
/// Verdicts, survivor order, and counters are identical to
/// [`super::shard::dedup_sharded_with_state`] over the same corpus and
/// shard count.
pub fn run_distributed(
    cfg: &PipelineConfig,
    input: &Path,
    docs: &[LabeledDoc],
    state_dir: &Path,
    opts: &SupervisorOptions,
) -> Result<DistributedRun> {
    let num_shards = cfg.shards.max(1);
    let total = docs.len();
    // The whole distributed run is one trace: adopt an inherited
    // context when a traced parent exported one, else mint a forced
    // root — run-level traces are few and always worth keeping. Worker
    // spawns below re-export this context, so per-shard ingest and the
    // phase-2 aggregate all share one tree.
    let _trace_root = match crate::obs::trace::root_from_env(
        "dedup.distributed",
        crate::obs::TraceParams::default(),
    ) {
        Some(guard) => guard,
        None => {
            let guard = crate::obs::trace::start_root(
                "dedup.distributed",
                crate::obs::TraceParams::default(),
            );
            crate::obs::trace::force_record();
            guard
        }
    };
    // Same thread-budget split as the in-process sharded run, one
    // process instead of one scoped pool per shard.
    let worker_threads = (cfg.effective_workers() / num_shards).max(1);
    let bin = match &opts.worker_bin {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| Error::io("current_exe".to_string(), e))?,
    };
    std::fs::create_dir_all(state_dir)
        .map_err(|e| Error::io(state_dir.display().to_string(), e))?;
    // A stale aggregate from a previous run must not stay adoptable
    // while THIS run is in flight (or after it fails): `serve
    // --state-dir` would warm-start from the wrong corpus. Same
    // discipline as the per-worker stale-marker removal; the aggregate
    // manifest republishes only when phase 2 completes.
    remove_stale_checkpoint(state_dir)?;
    let spawner = WorkerSpawner {
        bin,
        cfg,
        input,
        state_dir,
        num_shards,
        worker_threads,
    };
    // Documents round-robin'd onto shard `s`.
    let shard_len = |s: usize| (s..total).step_by(num_shards).count() as u64;

    // Phase 1: all workers in parallel, supervised to completion.
    // Polling with try_wait (instead of blocking wait in shard order)
    // restarts a crashed worker immediately, while its siblings are
    // still running — blocking on shard 0 would delay shard 7's restart
    // by the whole phase.
    struct WorkerSlot {
        shard: usize,
        child: Child,
        attempts: u32,
        done: bool,
    }
    let t1 = Instant::now();
    let mut slots: Vec<WorkerSlot> = Vec::with_capacity(num_shards);
    for shard in 0..num_shards {
        let child = spawner.spawn(shard, false, &opts.worker_env)?;
        slots.push(WorkerSlot { shard, child, attempts: 1, done: false });
    }
    crate::obs::global().gauge("supervisor.workers").set(num_shards as f64);
    let mut restarts = 0u32;
    let supervise = |slots: &mut Vec<WorkerSlot>, restarts: &mut u32| -> Result<()> {
        let mut pending = slots.iter().filter(|s| !s.done).count();
        while pending > 0 {
            let mut progressed = false;
            for slot in slots.iter_mut() {
                if slot.done {
                    continue;
                }
                let shard = slot.shard;
                let Some(status) = slot
                    .child
                    .try_wait()
                    .map_err(|e| Error::io(format!("worker {shard}"), e))?
                else {
                    continue;
                };
                progressed = true;
                let wdir = state_dir.join(worker_dir_name(shard));
                let outcome = if !status.success() {
                    Err(Error::Format(format!(
                        "worker {shard} failed: {}",
                        describe_exit(&status)
                    )))
                } else {
                    WorkerManifest::load(&wdir)
                        .and_then(|m| m.verify(shard, num_shards, shard_len(shard)))
                };
                match outcome {
                    Ok(()) => {
                        slot.done = true;
                        pending -= 1;
                    }
                    Err(e) if slot.attempts <= opts.restarts => {
                        crate::log_warn!(
                            "worker {shard}: {e}; restarting with --resume (attempt {})",
                            slot.attempts + 1
                        );
                        crate::obs::global().counter("supervisor.worker_restarts.total").inc();
                        *restarts += 1;
                        slot.attempts += 1;
                        slot.child = spawner.spawn(shard, true, &opts.worker_env)?;
                    }
                    Err(e) => {
                        crate::obs::global().counter("supervisor.worker_failures.total").inc();
                        return Err(Error::Format(format!(
                            "worker {shard} failed after {} attempt(s): {e}; see {}",
                            slot.attempts,
                            wdir.join(WORKER_LOG_FILE).display()
                        )));
                    }
                }
            }
            if pending > 0 && !progressed {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
        Ok(())
    };
    if let Err(e) = supervise(&mut slots, &mut restarts) {
        // Kill (and reap) every still-running sibling before surfacing
        // the error: orphans racing a retried run on the same worker
        // directories could tear the very files the retry resumes from.
        for slot in &mut slots {
            if !slot.done {
                let _ = slot.child.kill();
                let _ = slot.child.wait();
            }
        }
        return Err(e);
    }
    let phase1_wall = t1.elapsed();

    // Phase 2: shard-order recheck against the running bit-OR union —
    // the SAME fold as the in-process path (`ShardAggregator`, defined
    // in `super::shard`, is the single home of the recheck rule) —
    // except every shard's verdicts, band hashes, and filter bits come
    // from the files its worker process published, streamed line by
    // line (an outcomes file is large at scale; it never needs to be
    // resident at once).
    let t2 = Instant::now();
    let aggregate_span = crate::obs::span("supervisor.aggregate");
    let mut agg = ShardAggregator::new(cfg, total);
    for shard in 0..num_shards {
        use std::io::BufRead;
        let wdir = state_dir.join(worker_dir_name(shard));
        let manifest = WorkerManifest::load(&wdir)?;
        let outcomes_path = wdir.join(WORKER_OUTCOMES_FILE);
        let file = std::fs::File::open(&outcomes_path)
            .map_err(|e| Error::io(outcomes_path.display().to_string(), e))?;
        let (mut lines, mut dropped) = (0u64, 0u64);
        for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
            let line = line.map_err(|e| Error::io(outcomes_path.display().to_string(), e))?;
            let outcome = parse_outcome(&line, &outcomes_path, lineno)?;
            let expect_pos = lineno * num_shards + shard;
            if outcome.pos != expect_pos || outcome.pos >= total {
                return Err(Error::Format(format!(
                    "{} line {}: stream position {} does not match the round-robin \
                     layout (expected {expect_pos}, corpus holds {total})",
                    outcomes_path.display(),
                    lineno + 1,
                    outcome.pos
                )));
            }
            if outcome.dup {
                agg.mark_dropped(outcome.pos);
                dropped += 1;
            } else {
                agg.recheck(outcome.pos, docs[outcome.pos].doc.clone(), &outcome.bands);
            }
            lines += 1;
        }
        if lines != manifest.docs || dropped != manifest.dropped {
            return Err(Error::Format(format!(
                "{}: {lines} outcome lines ({dropped} dropped) but the worker \
                 manifest records {} ({} dropped); the worker directory is torn",
                outcomes_path.display(),
                manifest.docs,
                manifest.dropped
            )));
        }
        agg.union_from_checkpoint(&wdir.join(WORKER_CHECKPOINT_DIR))?;
    }
    // Publish the aggregate at the state root: `serve --state-dir` then
    // warm-starts with the union of every shard filter and the full-run
    // counters.
    write_checkpoint(
        agg.index(),
        total as u64,
        agg.phase1_dropped + agg.phase2_dropped,
        state_dir,
    )?;
    drop(aggregate_span);
    let phase2_wall = t2.elapsed();

    Ok(DistributedRun {
        stats: agg.into_stats(total as u64, phase1_wall, phase2_wall),
        restarts,
        worker_threads,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};

    fn cfg() -> PipelineConfig {
        PipelineConfig {
            num_perms: 64,
            expected_docs: 10_000,
            workers: 2,
            ..Default::default()
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lshbloom-sup-{name}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_corpus(dir: &Path, seed: u64, n: usize, rate: f64) -> (PathBuf, Vec<Doc>) {
        let corpus = LabeledCorpus::build(DatasetSpec::testing(seed, n, rate));
        let path = dir.join("corpus.jsonl");
        corpus.save_jsonl(&path).unwrap();
        let docs = corpus.docs.iter().map(|ld| ld.doc.clone()).collect();
        (path, docs)
    }

    #[test]
    fn run_worker_matches_in_process_shard_slice() {
        // The worker's published outcomes must agree line-for-line with
        // an in-process engine fed the same round-robin slice.
        let dir = tmp_dir("worker-eq");
        let (input, docs) = write_corpus(&dir, 71, 120, 0.5);
        let config = cfg();
        let (shard, num_shards) = (1usize, 3usize);
        let wdir = dir.join(worker_dir_name(shard));
        let manifest = run_worker(&config, &input, shard, num_shards, &wdir, false).unwrap();

        let slice: Vec<(usize, Doc)> = docs
            .iter()
            .enumerate()
            .filter(|(pos, _)| pos % num_shards == shard)
            .map(|(pos, d)| (pos, d.clone()))
            .collect();
        assert_eq!(manifest.docs, slice.len() as u64);
        assert_eq!(manifest.dropped + manifest.survivors, manifest.docs);

        let engine = ConcurrentEngine::from_config(&config);
        let batch: Vec<Doc> = slice.iter().map(|(_, d)| d.clone()).collect();
        let (decisions, bands) = engine.submit_with_bands(&batch);

        let text = std::fs::read_to_string(wdir.join(WORKER_OUTCOMES_FILE)).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), slice.len());
        for (i, line) in lines.iter().enumerate() {
            let outcome = parse_outcome(line, Path::new("outcomes"), i).unwrap();
            assert_eq!(outcome.pos, slice[i].0);
            assert_eq!(outcome.dup, decisions[i].duplicate, "line {i}");
            if !outcome.dup {
                assert_eq!(outcome.bands, bands[i], "line {i}");
            }
        }
        // The published checkpoint is a complete, loadable engine state.
        assert!(CheckpointManifest::exists(&wdir.join(WORKER_CHECKPOINT_DIR)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn worker_rejects_bad_shard_index() {
        let dir = tmp_dir("worker-badshard");
        let (input, _) = write_corpus(&dir, 5, 10, 0.0);
        let err = run_worker(&cfg(), &input, 3, 3, &dir.join("w"), false).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outcome_line_roundtrip() {
        let line = outcome_line(42, false, &[u64::MAX, 0, 123_456_789_012_345_678]);
        let outcome = parse_outcome(&line, Path::new("x"), 0).unwrap();
        assert_eq!(outcome.pos, 42);
        assert!(!outcome.dup);
        assert_eq!(outcome.bands, vec![u64::MAX, 0, 123_456_789_012_345_678]);

        let line = outcome_line(7, true, &[]);
        let outcome = parse_outcome(&line, Path::new("x"), 0).unwrap();
        assert!(outcome.dup);
        assert!(outcome.bands.is_empty());
    }

    #[test]
    fn truncate_outcomes_keeps_exact_prefix() {
        let dir = tmp_dir("truncate");
        let path = dir.join(WORKER_OUTCOMES_FILE);
        let mut text = String::new();
        for i in 0..10usize {
            text.push_str(&outcome_line(i, i % 3 == 0, &[i as u64]));
            text.push('\n');
        }
        text.push_str("{\"pos\":10,\"dup\""); // torn tail from a crash
        std::fs::write(&path, &text).unwrap();
        let (dropped, survivors) = truncate_outcomes(&path, 6).unwrap();
        assert_eq!(dropped, 2); // positions 0 and 3
        assert_eq!(survivors, 4);
        let kept = std::fs::read_to_string(&path).unwrap();
        assert_eq!(kept.lines().count(), 6);
        // Asking for more than the file holds is corruption, not silence.
        std::fs::write(&path, &text).unwrap();
        assert!(truncate_outcomes(&path, 11).is_err());
        // keep == 0 clears the file entirely.
        truncate_outcomes(&path, 0).unwrap();
        assert!(!path.exists());
        truncate_outcomes(&path, 0).unwrap(); // idempotent on a missing file
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_over_a_different_input_starts_fresh_instead_of_adopting() {
        // The binding guard: pointing --resume at state produced from a
        // DIFFERENT corpus must not adopt its checkpoint (that would
        // silently corrupt verdicts) — it starts the slice fresh and
        // produces exactly what a clean run on the new corpus produces.
        let dir = tmp_dir("worker-rebind");
        let (input_a, _) = write_corpus(&dir, 1, 60, 0.5);
        let wdir = dir.join(worker_dir_name(0));
        run_worker(&cfg(), &input_a, 0, 2, &wdir, false).unwrap();

        let corpus_b = LabeledCorpus::build(DatasetSpec::testing(2, 60, 0.5));
        let input_b = dir.join("corpus-b.jsonl");
        corpus_b.save_jsonl(&input_b).unwrap();
        let resumed = run_worker(&cfg(), &input_b, 0, 2, &wdir, true).unwrap();
        let fresh_dir = dir.join("fresh");
        let fresh = run_worker(&cfg(), &input_b, 0, 2, &fresh_dir, false).unwrap();
        assert_eq!(resumed, fresh, "stale-state resume must equal a clean run");
        assert_eq!(
            std::fs::read_to_string(wdir.join(WORKER_OUTCOMES_FILE)).unwrap(),
            std::fs::read_to_string(fresh_dir.join(WORKER_OUTCOMES_FILE)).unwrap(),
            "outcomes must be rebuilt for the new corpus, not truncated from the old"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn worker_resume_without_checkpoint_is_fresh_start() {
        let dir = tmp_dir("worker-fresh-resume");
        let (input, _) = write_corpus(&dir, 11, 60, 0.4);
        let wdir = dir.join(worker_dir_name(0));
        let fresh = run_worker(&cfg(), &input, 0, 2, &wdir, false).unwrap();
        // Re-running with --resume over the *completed* state restores
        // the final checkpoint, truncates nothing, and republishes the
        // same manifest.
        let resumed = run_worker(&cfg(), &input, 0, 2, &wdir, true).unwrap();
        assert_eq!(resumed, fresh);
        // And a resume pointed at an empty directory just starts over.
        let wdir2 = dir.join(worker_dir_name(1));
        let manifest = run_worker(&cfg(), &input, 1, 2, &wdir2, true).unwrap();
        assert_eq!(manifest.docs, 30);
        std::fs::remove_dir_all(&dir).ok();
    }
}
