//! The leader event loop: reader → workers → sequencer → decider.

use super::timing::PhaseTimes;
use crate::corpus::Doc;
use crate::methods::{Method, Prepared};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Pipeline tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct PipelineOptions {
    /// Worker thread count (0 = available parallelism).
    pub workers: usize,
    /// Documents per batch.
    pub batch_size: usize,
    /// Bounded channel depth (batches in flight per stage).
    pub channel_depth: usize,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        Self { workers: 0, batch_size: 64, channel_depth: 64 }
    }
}

impl PipelineOptions {
    /// From the pipeline config.
    pub fn from_config(cfg: &crate::config::PipelineConfig) -> Self {
        Self {
            workers: cfg.workers,
            batch_size: cfg.batch_size,
            channel_depth: cfg.channel_depth,
        }
    }

    fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }
}

/// Result of a pipeline run.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Per-document duplicate verdicts, in stream order.
    pub verdicts: Vec<bool>,
    /// Documents processed.
    pub docs: u64,
    /// Duplicates found.
    pub duplicates: u64,
    /// Phase timing (Fig. 1).
    pub times: PhaseTimes,
    /// Workers actually used.
    pub workers: usize,
    /// Index footprint after the run.
    pub disk_bytes: u64,
}

impl RunStats {
    /// Documents per second end-to-end.
    pub fn throughput(&self) -> f64 {
        self.docs as f64 / self.times.wall.as_secs_f64().max(1e-9)
    }
}

/// Run the full pipeline over a document stream.
///
/// Verdicts are produced in exact stream order regardless of worker
/// scheduling (the sequencer reorders batches), so results are
/// deterministic for a deterministic `Method`.
pub fn run_stream<I>(method: &mut Method, docs: I, opts: PipelineOptions) -> RunStats
where
    I: IntoIterator<Item = Doc>,
    I::IntoIter: Send,
{
    let workers = opts.effective_workers();
    let batch_size = opts.batch_size.max(1);
    let t_wall = Instant::now();

    // Stage channels. Work items are (batch_idx, Vec<Doc>).
    let (work_tx, work_rx) = sync_channel::<(u64, Vec<Doc>)>(opts.channel_depth);
    let work_rx = Arc::new(Mutex::new(work_rx));
    let (done_tx, done_rx) = sync_channel::<(u64, Vec<Prepared>)>(opts.channel_depth);

    let prepare_ns = Arc::new(AtomicU64::new(0));
    let preparer = Arc::clone(&method.preparer);
    let doc_iter = docs.into_iter();

    std::thread::scope(|scope| {
        // Workers.
        for _ in 0..workers {
            let work_rx = Arc::clone(&work_rx);
            let done_tx = done_tx.clone();
            let preparer = Arc::clone(&preparer);
            let prepare_ns = Arc::clone(&prepare_ns);
            scope.spawn(move || {
                loop {
                    let item = {
                        let guard = work_rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok((idx, batch)) = item else { break };
                    let t0 = Instant::now();
                    let prepared = preparer.prepare_batch(&batch);
                    prepare_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    if done_tx.send((idx, prepared)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(done_tx); // workers hold the remaining clones

        // Reader: batch the stream into the work channel.
        let reader = scope.spawn(move || {
            let mut idx = 0u64;
            let mut batch = Vec::with_capacity(batch_size);
            let mut total = 0u64;
            for doc in doc_iter {
                batch.push(doc);
                total += 1;
                if batch.len() == batch_size {
                    if work_tx.send((idx, std::mem::take(&mut batch))).is_err() {
                        return total;
                    }
                    idx += 1;
                    batch.reserve(batch_size);
                }
            }
            if !batch.is_empty() {
                let _ = work_tx.send((idx, batch));
            }
            total
        });

        // Sequencer + decider (this thread).
        let decider = &mut method.decider;
        let mut verdicts = Vec::new();
        let mut duplicates = 0u64;
        let mut decide_time = Duration::ZERO;
        let mut next_idx = 0u64;
        let mut pending: BTreeMap<u64, Vec<Prepared>> = BTreeMap::new();
        for (idx, prepared) in done_rx.iter() {
            pending.insert(idx, prepared);
            while let Some(prepared) = pending.remove(&next_idx) {
                let t0 = Instant::now();
                for prep in &prepared {
                    let dup = decider.decide(prep);
                    duplicates += dup as u64;
                    verdicts.push(dup);
                }
                decide_time += t0.elapsed();
                next_idx += 1;
            }
        }
        assert!(pending.is_empty(), "sequencer drained with gaps");
        let docs = reader.join().expect("reader panicked");
        assert_eq!(verdicts.len() as u64, docs, "verdict count mismatch");

        RunStats {
            docs,
            duplicates,
            disk_bytes: decider.disk_bytes(),
            verdicts,
            times: PhaseTimes {
                prepare_cpu: Duration::from_nanos(prepare_ns.load(Ordering::Relaxed)),
                decide: decide_time,
                wall: t_wall.elapsed(),
            },
            workers,
        }
    })
}

/// When and where [`run_stream_engine_checkpointed`] persists engine
/// state (see [`crate::persist`] for the on-disk format and the
/// crash-consistency contract).
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// Checkpoint directory (`manifest.json` + one filter file per band).
    pub dir: std::path::PathBuf,
    /// Checkpoint after every `every_docs` processed documents
    /// (checked at super-batch boundaries, so the manifest counters
    /// always describe an exact stream prefix). `0` = only the final
    /// end-of-stream checkpoint.
    pub every_docs: u64,
}

/// Run the pipeline over a stream with the lock-free concurrent engine
/// (`--engine concurrent`).
///
/// The engine parallelizes internally — each `submit` fans MinHash and
/// index work across its scoped worker pool — so this loop just feeds it
/// super-batches (`opts.batch_size × engine.workers()` documents,
/// keeping every worker busy per call) and concatenates verdicts. Only
/// `opts.batch_size` is consulted: the worker count is fixed at engine
/// construction (`PipelineConfig::workers`), and there are no inter-stage
/// channels, so `opts.workers` and `opts.channel_depth` have no effect
/// here (unlike [`run_stream`]). Verdicts stay in stream
/// order and deterministic (the engine's intra-batch reconcile runs in
/// submission order); in-batch duplicate detection is by exact band-hash
/// collision rather than filter probes, so verdicts can differ from
/// [`run_stream`] only on ~`p_effective`-probability in-flight filter
/// false positives — see `engine::batch` for the full contract.
///
/// `times.decide` reports total `submit` time (prepare and index work
/// are fused inside the engine, so no separate prepare figure exists).
pub fn run_stream_engine<I>(
    engine: &crate::engine::ConcurrentEngine,
    docs: I,
    opts: PipelineOptions,
) -> RunStats
where
    I: IntoIterator<Item = Doc>,
{
    run_stream_engine_checkpointed(engine, docs, opts, None)
        .expect("checkpoint-free engine run cannot fail")
}

/// [`run_stream_engine`] with optional periodic durability: with a
/// [`CheckpointPolicy`], the engine's full state (filter bits + manifest
/// with counters) is persisted at super-batch boundaries every
/// `every_docs` documents and once more at end of stream, so a killed
/// run resumes from the last checkpoint instead of hour zero
/// (`dedup --checkpoint-dir D --resume`). Checkpoints land between
/// `submit` calls, so each manifest describes an exact stream prefix —
/// the property the resume path's skip-count relies on.
pub fn run_stream_engine_checkpointed<I>(
    engine: &crate::engine::ConcurrentEngine,
    docs: I,
    opts: PipelineOptions,
    policy: Option<&CheckpointPolicy>,
) -> crate::error::Result<RunStats>
where
    I: IntoIterator<Item = Doc>,
{
    let t_wall = Instant::now();
    let super_batch = opts.batch_size.max(1) * engine.workers().max(1);
    let mut verdicts = Vec::new();
    let mut duplicates = 0u64;
    let mut total = 0u64;
    let mut submit_time = Duration::ZERO;
    let mut since_checkpoint = 0u64;
    let mut batch: Vec<Doc> = Vec::with_capacity(super_batch);
    let flush = |batch: &mut Vec<Doc>, verdicts: &mut Vec<bool>, duplicates: &mut u64| {
        if batch.is_empty() {
            return Duration::ZERO;
        }
        let t0 = Instant::now();
        let decisions = engine.submit(std::mem::take(batch));
        let spent = t0.elapsed();
        for d in decisions {
            *duplicates += d.duplicate as u64;
            verdicts.push(d.duplicate);
        }
        spent
    };
    for doc in docs {
        total += 1;
        since_checkpoint += 1;
        batch.push(doc);
        if batch.len() == super_batch {
            submit_time += flush(&mut batch, &mut verdicts, &mut duplicates);
            batch.reserve(super_batch);
            if let Some(p) = policy {
                if p.every_docs > 0 && since_checkpoint >= p.every_docs {
                    engine.checkpoint(&p.dir)?;
                    since_checkpoint = 0;
                }
            }
        }
    }
    submit_time += flush(&mut batch, &mut verdicts, &mut duplicates);
    assert_eq!(verdicts.len() as u64, total, "verdict count mismatch");
    if let Some(p) = policy {
        // Final checkpoint: a *completed* run leaves durable state too,
        // so a follow-up incremental ingest can warm-start from it.
        engine.checkpoint(&p.dir)?;
    }

    Ok(RunStats {
        docs: total,
        duplicates,
        disk_bytes: engine.disk_bytes(),
        verdicts,
        times: PhaseTimes {
            prepare_cpu: Duration::ZERO,
            decide: submit_time,
            wall: t_wall.elapsed(),
        },
        workers: engine.workers(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::corpus::{DatasetSpec, LabeledCorpus};
    use crate::methods::lshbloom::lshbloom_method;
    use crate::minhash::PermFamily;

    fn cfg() -> PipelineConfig {
        PipelineConfig { num_perms: 64, expected_docs: 10_000, ..Default::default() }
    }

    fn corpus(n: usize) -> LabeledCorpus {
        LabeledCorpus::build(DatasetSpec::testing(17, n, 0.5))
    }

    #[test]
    fn parallel_run_matches_sequential() {
        let c = corpus(300);
        // Sequential reference.
        let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
        let expected = seq.process_all(&c.docs);
        // Parallel with several worker counts and batch sizes.
        for (w, b) in [(1usize, 7usize), (2, 16), (4, 64), (8, 3)] {
            let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
            let stats = run_stream(
                &mut m,
                c.docs.iter().map(|ld| ld.doc.clone()),
                PipelineOptions { workers: w, batch_size: b, channel_depth: 4 },
            );
            assert_eq!(stats.verdicts, expected, "w={w} b={b}");
            assert_eq!(stats.docs, 300);
            assert_eq!(
                stats.duplicates,
                expected.iter().filter(|&&v| v).count() as u64
            );
        }
    }

    #[test]
    fn empty_stream() {
        let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
        let stats = run_stream(&mut m, std::iter::empty(), PipelineOptions::default());
        assert_eq!(stats.docs, 0);
        assert!(stats.verdicts.is_empty());
    }

    #[test]
    fn single_doc_stream() {
        let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
        let doc = Doc { id: 0, text: "just one document".into() };
        let stats = run_stream(&mut m, vec![doc], PipelineOptions::default());
        assert_eq!(stats.verdicts, vec![false]);
    }

    #[test]
    fn timing_phases_populated() {
        let c = corpus(200);
        let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
        let stats = run_stream(
            &mut m,
            c.docs.iter().map(|ld| ld.doc.clone()),
            PipelineOptions { workers: 2, batch_size: 32, channel_depth: 8 },
        );
        assert!(stats.times.prepare_cpu > Duration::ZERO);
        assert!(stats.times.decide > Duration::ZERO);
        assert!(stats.times.wall >= stats.times.decide);
        assert!(stats.throughput() > 0.0);
    }

    #[test]
    fn engine_run_matches_sequential() {
        let c = corpus(300);
        let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
        let expected = seq.process_all(&c.docs);
        for (w, b) in [(1usize, 16usize), (4, 8), (8, 3)] {
            let mut config = cfg();
            config.workers = w;
            let engine = crate::engine::ConcurrentEngine::from_config(&config);
            let stats = run_stream_engine(
                &engine,
                c.docs.iter().map(|ld| ld.doc.clone()),
                PipelineOptions { workers: w, batch_size: b, channel_depth: 4 },
            );
            assert_eq!(stats.verdicts, expected, "w={w} b={b}");
            assert_eq!(stats.docs, 300);
            assert_eq!(stats.workers, w);
            assert!(stats.disk_bytes > 0);
        }
    }

    #[test]
    fn engine_run_with_checkpoint_policy_writes_manifest() {
        let dir = std::env::temp_dir().join(format!("lshbloom-orch-ckpt-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let c = corpus(120);
        let mut config = cfg();
        config.workers = 2;
        let engine = crate::engine::ConcurrentEngine::from_config(&config);
        let policy = CheckpointPolicy { dir: dir.clone(), every_docs: 40 };
        let stats = run_stream_engine_checkpointed(
            &engine,
            c.docs.iter().map(|ld| ld.doc.clone()),
            PipelineOptions { workers: 2, batch_size: 8, channel_depth: 4 },
            Some(&policy),
        )
        .unwrap();
        assert_eq!(stats.docs, 120);
        // The end-of-stream checkpoint must cover the whole stream.
        let manifest = crate::persist::CheckpointManifest::load(&dir).unwrap();
        assert_eq!(manifest.docs, 120);
        assert_eq!(manifest.duplicates, stats.duplicates);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn engine_run_empty_stream() {
        let engine = crate::engine::ConcurrentEngine::from_config(&cfg());
        let stats = run_stream_engine(&engine, std::iter::empty(), PipelineOptions::default());
        assert_eq!(stats.docs, 0);
        assert!(stats.verdicts.is_empty());
    }

    #[test]
    fn tiny_channel_depth_backpressure_still_correct() {
        let c = corpus(150);
        let mut seq = lshbloom_method(&cfg(), PermFamily::Mix64);
        let expected = seq.process_all(&c.docs);
        let mut m = lshbloom_method(&cfg(), PermFamily::Mix64);
        let stats = run_stream(
            &mut m,
            c.docs.iter().map(|ld| ld.doc.clone()),
            PipelineOptions { workers: 4, batch_size: 2, channel_depth: 1 },
        );
        assert_eq!(stats.verdicts, expected);
    }
}
