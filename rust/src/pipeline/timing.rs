//! Phase timing for the Fig. 1 wall-clock breakdown.

use std::time::Duration;

/// Accumulated time per pipeline phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// CPU time spent in prepare (MinHashing / unit hashing), summed over
    /// workers — divide by worker count for wall-clock contribution.
    pub prepare_cpu: Duration,
    /// Wall time of the sequential decide (index insert/query) stage.
    pub decide: Duration,
    /// End-to-end wall time of the run.
    pub wall: Duration,
}

impl PhaseTimes {
    /// Wall-clock share of prepare assuming `workers` ran concurrently.
    pub fn prepare_wall_est(&self, workers: usize) -> Duration {
        if workers == 0 {
            self.prepare_cpu
        } else {
            self.prepare_cpu / workers as u32
        }
    }

    /// "Other" time: wall − (prepare estimate + decide), clamped at zero.
    pub fn other(&self, workers: usize) -> Duration {
        self.wall
            .saturating_sub(self.prepare_wall_est(workers))
            .saturating_sub(self.decide)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_arithmetic() {
        let t = PhaseTimes {
            prepare_cpu: Duration::from_secs(8),
            decide: Duration::from_secs(1),
            wall: Duration::from_secs(4),
        };
        assert_eq!(t.prepare_wall_est(4), Duration::from_secs(2));
        assert_eq!(t.other(4), Duration::from_secs(1));
        // Clamping.
        assert_eq!(t.other(1), Duration::ZERO);
    }
}
