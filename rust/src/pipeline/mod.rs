//! The streaming deduplication pipeline (§4.4.2).
//!
//! Topology (single leader process):
//!
//! ```text
//!  reader ──batches──▶ [bounded ch] ──▶ worker×W (prepare: MinHash etc.)
//!                                            │ (batch_idx, Vec<Prepared>)
//!                                            ▼
//!                      [bounded ch] ──▶ sequencer ──▶ decider (sequential)
//! ```
//!
//! * **Parallel prepare** — MinHashing dominates runtime (Fig. 1) and is
//!   embarrassingly parallel; W workers pull document batches.
//! * **Sequential decide** — index insertion must observe stream order so
//!   the duplicate relation stays exact (§4.4.2); the sequencer reorders
//!   out-of-order worker output before feeding the decider.
//! * **Backpressure** — both channels are bounded; a slow decider stalls
//!   workers, a slow reader starves them, memory stays O(depth · batch).
//!
//! [`timing`] instruments the two phases for the Fig. 1 breakdown;
//! [`shard`] implements the paper's §6 sharded-aggregation extension on
//! the lock-free engine (per-shard `ConcurrentEngine` ingest, bit-OR
//! filter union for cross-shard aggregation); [`supervisor`] lifts that
//! to OS **processes** — one self-exec'd worker per shard, supervised
//! with restart-and-resume, aggregated purely from the checkpoint wire
//! format (`dedup --shards N --distributed`).

// The pipeline is the crate's main entry surface; rustdoc is part of its
// contract. CI turns these warnings into errors (RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

pub mod orchestrator;
pub mod shard;
pub mod supervisor;
pub mod timing;

pub use orchestrator::{
    run_stream, run_stream_engine, run_stream_engine_checkpointed, CheckpointPolicy,
    PipelineOptions, RunStats,
};
pub use shard::{dedup_sharded, dedup_sharded_with_state, ShardedStats};
pub use supervisor::{run_distributed, run_worker, DistributedRun, SupervisorOptions};
pub use timing::PhaseTimes;
