//! Crash-safe persistence for the lock-free engine (ROADMAP
//! "shm-backed atomic filters"; §4.4.2 codesign, now for the concurrent
//! path).
//!
//! LSHBloom's whole dedup state is a few GB of Bloom filter bits — 18×
//! smaller than MinhashLSH on peS2o (§4.4) — which makes whole-index
//! persistence actually tractable at billion-document scale. This
//! subsystem turns that size advantage into durable, resumable runs:
//!
//! * [`ShmAtomicBitArray`] — an mmap-backed bit store viewed as
//!   `&[AtomicU64]`, so [`crate::engine::AtomicBloomFilter`] (and with
//!   it the whole [`crate::engine::ConcurrentEngine`]) can be backed by
//!   a file instead of the heap with identical `fetch_or`/relaxed-probe
//!   semantics and unchanged FP math.
//! * [`CheckpointManifest`] — a versioned `manifest.json` + one raw
//!   filter file per band, recording geometry, engine counters, and
//!   per-file checksums; restore verifies geometry strictly and refuses
//!   torn snapshots.
//! * [`write_checkpoint`] / [`restore_index`] — the engine-facing
//!   checkpoint/restore primitives ([`crate::engine::ConcurrentEngine::checkpoint`]
//!   and [`crate::engine::ConcurrentEngine::restore`] wrap them).
//! * [`union_from_checkpoint`] — bit-OR a sibling *process's* persisted
//!   shard filters into a live index (the cross-process half of the §6
//!   sharded-aggregation seam; `pipeline::shard` drives it).
//! * [`restore_band_slice`] — load just one contiguous band range of a
//!   full-index checkpoint, so the band-partitioned serving tier
//!   ([`crate::engine::band_slice`], `serve --serve-shards` and router
//!   backends) warm-starts each slice owner from the same manifest a
//!   single engine would restore whole.
//! * [`open_durable_slice`] / [`write_slice_checkpoint`] — the
//!   mmap-backed variant of the above for replicated slice backends:
//!   open (or create) just the owned band files as live mappings so
//!   every insert is on disk before it is acknowledged, and publish the
//!   owned slice of the manifest read-modify-write so several slices
//!   can tile one checkpoint directory between them.
//! * [`WorkerManifest`] — the completion marker a distributed shard
//!   worker *process* publishes next to its checkpoint so the
//!   supervising orchestrator ([`crate::pipeline::supervisor`]) can tell
//!   a finished worker from a torn one.
//!
//! ## Crash-consistency contract
//!
//! Bloom bit-sets are monotone, so a filter restored after a crash is a
//! *superset* of the last checkpoint and a *subset* of everything ever
//! inserted: restored state may **over-approximate** membership (a few
//! extra duplicate flags for documents ingested after the final
//! checkpoint) but never under-approximates — no checkpointed insert is
//! ever lost, so resumed runs admit **zero false negatives** relative to
//! an uninterrupted run.

// The persistence wire format is the contract between processes (and,
// eventually, hosts); rustdoc is part of that contract. CI turns these
// warnings into errors (RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

// Filter files are little-endian u64 words, and the mmap path reads them
// as native words; the bloom::shm libc shim already restricts builds to
// 64-bit Linux, and this keeps the file format honest on the (exotic)
// big-endian variants.
#[cfg(target_endian = "big")]
compile_error!(
    "persist's filter files are little-endian; the mmap-backed path would \
     reinterpret them as big-endian words on this target"
);

pub mod checkpoint;
pub mod manifest;
pub mod shm_atomic;
pub mod worker;

pub use checkpoint::{
    open_durable_slice, restore_band_slice, restore_index, union_from_checkpoint,
    write_checkpoint, write_slice_checkpoint,
};

pub(crate) use checkpoint::{
    restore_band_slice_from, write_checkpoint_filters, write_checkpoint_generations,
};
pub use manifest::{CheckpointManifest, CheckpointMode, ChecksumStream, MANIFEST_FILE};
pub use shm_atomic::ShmAtomicBitArray;
pub use worker::{
    worker_dir_name, WorkerManifest, WORKER_CHECKPOINT_DIR, WORKER_MANIFEST_FILE,
    WORKER_OUTCOMES_FILE,
};

/// Atomically publish `bytes` at `path`: write `<name>.tmp` in the same
/// directory, fsync, rename. The one home of the durability-critical
/// publish idiom every manifest writer uses — a crash leaves either the
/// previous complete file or none.
pub(crate) fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> crate::error::Result<()> {
    use crate::error::Error;
    use std::io::Write;
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| Error::Format(format!("write_atomic: bad path {}", path.display())))?;
    let tmp = path.with_file_name(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| Error::io(tmp.display().to_string(), e))?;
        f.write_all(bytes).map_err(|e| Error::io(tmp.display().to_string(), e))?;
        f.sync_all().map_err(|e| Error::io(tmp.display().to_string(), e))?;
    }
    std::fs::rename(&tmp, path).map_err(|e| Error::io(path.display().to_string(), e))?;
    Ok(())
}

/// Remove `path` if it exists; a missing file is fine, any other
/// failure is a hard error (callers use this to clear stale markers
/// whose survival would corrupt a later restore).
pub(crate) fn remove_file_if_exists(path: &std::path::Path) -> crate::error::Result<()> {
    match std::fs::remove_file(path) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(crate::error::Error::io(path.display().to_string(), e)),
    }
}
