//! Crash-safe persistence for the lock-free engine (ROADMAP
//! "shm-backed atomic filters"; §4.4.2 codesign, now for the concurrent
//! path).
//!
//! LSHBloom's whole dedup state is a few GB of Bloom filter bits — 18×
//! smaller than MinhashLSH on peS2o (§4.4) — which makes whole-index
//! persistence actually tractable at billion-document scale. This
//! subsystem turns that size advantage into durable, resumable runs:
//!
//! * [`ShmAtomicBitArray`] — an mmap-backed bit store viewed as
//!   `&[AtomicU64]`, so [`crate::engine::AtomicBloomFilter`] (and with
//!   it the whole [`crate::engine::ConcurrentEngine`]) can be backed by
//!   a file instead of the heap with identical `fetch_or`/relaxed-probe
//!   semantics and unchanged FP math.
//! * [`CheckpointManifest`] — a versioned `manifest.json` + one raw
//!   filter file per band, recording geometry, engine counters, and
//!   per-file checksums; restore verifies geometry strictly and refuses
//!   torn snapshots.
//! * [`write_checkpoint`] / [`restore_index`] — the engine-facing
//!   checkpoint/restore primitives ([`crate::engine::ConcurrentEngine::checkpoint`]
//!   and [`crate::engine::ConcurrentEngine::restore`] wrap them).
//! * [`union_from_checkpoint`] — bit-OR a sibling *process's* persisted
//!   shard filters into a live index (the cross-process half of the §6
//!   sharded-aggregation seam; `pipeline::shard` drives it).
//!
//! ## Crash-consistency contract
//!
//! Bloom bit-sets are monotone, so a filter restored after a crash is a
//! *superset* of the last checkpoint and a *subset* of everything ever
//! inserted: restored state may **over-approximate** membership (a few
//! extra duplicate flags for documents ingested after the final
//! checkpoint) but never under-approximates — no checkpointed insert is
//! ever lost, so resumed runs admit **zero false negatives** relative to
//! an uninterrupted run.

// Filter files are little-endian u64 words, and the mmap path reads them
// as native words; the bloom::shm libc shim already restricts builds to
// 64-bit Linux, and this keeps the file format honest on the (exotic)
// big-endian variants.
#[cfg(target_endian = "big")]
compile_error!(
    "persist's filter files are little-endian; the mmap-backed path would \
     reinterpret them as big-endian words on this target"
);

pub mod checkpoint;
pub mod manifest;
pub mod shm_atomic;

pub use checkpoint::{restore_index, union_from_checkpoint, write_checkpoint};
pub use manifest::{CheckpointManifest, CheckpointMode, ChecksumStream, MANIFEST_FILE};
pub use shm_atomic::ShmAtomicBitArray;
