//! mmap-backed bit store viewed as `&[AtomicU64]`.
//!
//! The lock-free engine's crash-safety seam (ROADMAP: "shm-backed atomic
//! filters"): [`crate::bloom::shm::ShmBitArray`] gives the *sequential*
//! filter file semantics, but its `&mut`-oriented API cannot back the
//! concurrent engine, whose whole point is `fetch_or` from many threads
//! at once. This sibling maps the same file format (raw u64 words,
//! page-aligned by mmap) and hands out the mapping as a shared slice of
//! atomics, so [`crate::engine::AtomicBloomFilter`] keeps its exact
//! `fetch_or`-insert / atomic-probe semantics (and its release/acquire
//! ordering discipline) — and unchanged FP math — while every bit lands
//! in a file.
//!
//! Durability model: `fetch_or` writes dirty the mapped pages; the kernel
//! writes them back on its own schedule, [`ShmAtomicBitArray::sync`]
//! (msync) forces it, and drop syncs before unmapping. After a crash the
//! file holds *some superset of the last-synced state and subset of the
//! last-written state* — for monotone Bloom bit-sets that means a
//! restored filter can only over-approximate membership (extra duplicate
//! flags), never under-approximate (never a lost insert that was synced,
//! so no false negatives for checkpointed documents).

use crate::bloom::shm::libc;
use crate::error::{Error, Result};
use std::fs::{File, OpenOptions};
use std::os::fd::AsRawFd;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicU64;

/// A u64-word bit array backed by a shared file mapping, viewed as
/// atomics so any number of threads may `fetch_or`/load concurrently.
pub struct ShmAtomicBitArray {
    ptr: *mut AtomicU64,
    words: usize,
    path: PathBuf,
}

// SAFETY: the raw pointer is only what blocks the auto-trait; the
// mapping is plain owned memory whose sole access path is `words`, and
// tearing it down is Drop's munmap, so ownership may move threads.
unsafe impl Send for ShmAtomicBitArray {}
// SAFETY: all shared access goes through `&[AtomicU64]` — every read
// and write is an atomic op, so data races are impossible by
// construction; no interior non-atomic mutation exists.
unsafe impl Sync for ShmAtomicBitArray {}

impl ShmAtomicBitArray {
    /// Create (or truncate to zeros) a file of `words * 8` bytes and map
    /// it shared.
    pub fn create(path: &Path, words: usize) -> Result<Self> {
        assert!(words > 0);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        file.set_len((words * 8) as u64)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::map(file, path, words)
    }

    /// Map an existing array created by [`ShmAtomicBitArray::create`] (or
    /// any checkpointed filter file of exactly `words * 8` bytes).
    ///
    /// Same exact-size discipline as [`crate::bloom::shm::ShmBitArray::open`]:
    /// a missing file is an I/O error (fabricating a zeroed array would
    /// turn every restored key into a Bloom false negative), and a size
    /// mismatch is [`Error::Format`] (remapping a live filter at the
    /// wrong geometry silently corrupts the membership contract).
    pub fn open(path: &Path, words: usize) -> Result<Self> {
        assert!(words > 0);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let actual = file
            .metadata()
            .map_err(|e| Error::io(path.display().to_string(), e))?
            .len();
        let expected = (words * 8) as u64;
        if actual != expected {
            return Err(Error::Format(format!(
                "shm atomic bit array {}: file is {actual} bytes but {words} words need \
                 {expected}; refusing to remap a mismatched filter",
                path.display()
            )));
        }
        Self::map(file, path, words)
    }

    fn map(file: File, path: &Path, words: usize) -> Result<Self> {
        let bytes = words * 8;
        // SAFETY: same contract as `bloom::shm::ShmBitArray::map` — null
        // addr (kernel picks), live fd borrowed from `file`, kernel
        // validates the rest and reports failure as MAP_FAILED (checked
        // below); MAP_SHARED keeps the inode alive past `file`'s close.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(Error::io(
                path.display().to_string(),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(Self { ptr: ptr as *mut AtomicU64, words, path: path.to_path_buf() })
    }

    /// The words as a shared slice of atomics — mmap guarantees the page
    /// alignment `AtomicU64` needs, and `MAP_SHARED` makes every
    /// `fetch_or` visible to other mappings of the same file on this
    /// host (the cross-process sharing half of the §4.4.2 codesign).
    #[inline(always)]
    pub fn words(&self) -> &[AtomicU64] {
        // SAFETY: `ptr` is a live mapping of exactly `words * 8` bytes
        // (file length validated in `open`, set in `create`),
        // page-aligned by mmap so AtomicU64-aligned, and unmapped only
        // in Drop, which cannot run while this borrow of self is live.
        // AtomicU64 tolerates concurrent mutation from other mappings
        // of the same file by definition.
        unsafe { std::slice::from_raw_parts(self.ptr, self.words) }
    }

    /// Flush dirty pages to the backing file (msync, blocking until the
    /// writeback completes).
    pub fn sync(&self) -> Result<()> {
        // SAFETY: `ptr`/len describe the live mapping (see `words`);
        // msync only schedules writeback and reports errors via rc.
        let rc = unsafe { libc::msync(self.ptr as *mut _, self.words * 8, libc::MS_SYNC) };
        if rc != 0 {
            return Err(Error::io(
                self.path.display().to_string(),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(())
    }

    /// Backing path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for ShmAtomicBitArray {
    fn drop(&mut self) {
        // Same rationale as `ShmBitArray::drop`: flush the unsynced tail
        // before unmapping so a clean shutdown never silently drops
        // writes. Errors are unreportable here; durability-critical
        // paths call `sync()` explicitly and observe the Result.
        // SAFETY: `ptr`/len describe the mapping created in `map`; Drop
        // takes &mut self, so no `words()` borrow can outlive it and
        // nothing dereferences the pointer after munmap.
        unsafe {
            let _ = libc::msync(self.ptr as *mut _, self.words * 8, libc::MS_SYNC);
            libc::munmap(self.ptr as *mut _, self.words * 8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lshbloom-shma-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn create_fetch_or_reopen() {
        let path = tmp("a.bits");
        {
            let arr = ShmAtomicBitArray::create(&path, 16).unwrap();
            arr.words()[0].fetch_or(0xDEAD_BEEF, Ordering::Relaxed);
            arr.words()[15].store(u64::MAX, Ordering::Relaxed);
            arr.sync().unwrap();
        }
        {
            let arr = ShmAtomicBitArray::open(&path, 16).unwrap();
            assert_eq!(arr.words()[0].load(Ordering::Relaxed), 0xDEAD_BEEF);
            assert_eq!(arr.words()[15].load(Ordering::Relaxed), u64::MAX);
            assert_eq!(arr.words()[7].load(Ordering::Relaxed), 0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn drop_syncs_without_explicit_msync() {
        // Write, drop with NO sync() call, reopen: the Drop-side msync
        // must have pushed the words to the file.
        let path = tmp("dropsync.bits");
        {
            let arr = ShmAtomicBitArray::create(&path, 8).unwrap();
            arr.words()[3].store(0x5151_5151, Ordering::Relaxed);
        }
        let arr = ShmAtomicBitArray::open(&path, 8).unwrap();
        assert_eq!(arr.words()[3].load(Ordering::Relaxed), 0x5151_5151);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn open_missing_or_mismatched_refused() {
        let path = tmp("missing.bits");
        std::fs::remove_file(&path).ok();
        assert!(ShmAtomicBitArray::open(&path, 8).is_err());
        assert!(!path.exists(), "open must not fabricate a file");

        let path = tmp("sized.bits");
        {
            let arr = ShmAtomicBitArray::create(&path, 16).unwrap();
            arr.words()[0].store(7, Ordering::Relaxed);
        }
        for words in [8usize, 32] {
            let err = ShmAtomicBitArray::open(&path, words).unwrap_err();
            assert!(err.to_string().contains("refusing to remap"), "{err}");
        }
        // Refused opens left the contents intact.
        let arr = ShmAtomicBitArray::open(&path, 16).unwrap();
        assert_eq!(arr.words()[0].load(Ordering::Relaxed), 7);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn concurrent_fetch_or_lands_in_file() {
        let path = tmp("conc.bits");
        {
            let arr = ShmAtomicBitArray::create(&path, 64).unwrap();
            std::thread::scope(|s| {
                for t in 0..8u64 {
                    let arr = &arr;
                    s.spawn(move || {
                        for w in arr.words() {
                            w.fetch_or(1u64 << t, Ordering::Relaxed);
                        }
                    });
                }
            });
            arr.sync().unwrap();
        }
        let arr = ShmAtomicBitArray::open(&path, 64).unwrap();
        for (i, w) in arr.words().iter().enumerate() {
            assert_eq!(w.load(Ordering::Relaxed), 0xFF, "word {i}");
        }
        std::fs::remove_file(&path).ok();
    }
}
