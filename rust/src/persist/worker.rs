//! Worker completion manifest: the durable handshake between a shard
//! worker *process* and its supervising orchestrator.
//!
//! A distributed run (`dedup --shards N --distributed`) gives every
//! shard its own OS process. The only channel between the supervisor and
//! a worker is the filesystem, so each worker publishes its results as a
//! small directory:
//!
//! ```text
//! <state>/worker-{s:03}/
//!   checkpoint/        engine checkpoint: manifest.json + band bit files
//!   outcomes.jsonl     one line per shard document, in-shard order
//!   worker-manifest.json   THIS file — written last, tmp + rename
//!   worker.log         the worker process's stdout/stderr
//! ```
//!
//! The worker manifest doubles as the **completion marker**: it is
//! written only after the final engine checkpoint and the outcomes file
//! are durable, and it publishes atomically (tmp + rename, fsync'd).
//! A worker directory without a readable, consistent manifest is
//! therefore a *torn worker* — crashed, killed, or still running — and
//! the supervisor restarts it (with `--resume`) instead of aggregating
//! half-written state.

use crate::error::{Error, Result};
use crate::json::{self, obj, Value};
use std::path::Path;

/// Worker manifest format version; bumped on incompatible layout change.
pub const WORKER_MANIFEST_VERSION: u64 = 1;

/// File name of the worker manifest inside a worker directory.
pub const WORKER_MANIFEST_FILE: &str = "worker-manifest.json";

/// Conventional name of the engine-checkpoint subdirectory.
pub const WORKER_CHECKPOINT_DIR: &str = "checkpoint";

/// Conventional name of the per-document outcomes file.
pub const WORKER_OUTCOMES_FILE: &str = "outcomes.jsonl";

/// Conventional worker directory name for shard `s` under a state root.
pub fn worker_dir_name(shard: usize) -> String {
    format!("worker-{shard:03}")
}

/// Completion record one shard worker leaves for the supervisor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerManifest {
    /// Format version ([`WORKER_MANIFEST_VERSION`]).
    pub version: u64,
    /// The shard slice this worker processed (`stream_pos % num_shards
    /// == shard`, round-robin — the same split `pipeline::shard` uses).
    pub shard: usize,
    /// Total shard count of the run (fixes the slice *and* the
    /// position arithmetic `pos = line_index * num_shards + shard`).
    pub num_shards: usize,
    /// Documents this worker processed (= complete lines in the
    /// outcomes file). The supervisor cross-checks this against the
    /// shard size it derived from the input; a mismatch marks the
    /// worker torn.
    pub docs: u64,
    /// Documents flagged duplicate within the shard (phase 1).
    pub dropped: u64,
    /// Shard survivors handed to phase-2 aggregation.
    pub survivors: u64,
}

impl WorkerManifest {
    /// Serialize to the manifest JSON document.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("version", Value::u64(self.version)),
            ("shard", Value::u64(self.shard as u64)),
            ("num_shards", Value::u64(self.num_shards as u64)),
            ("docs", Value::u64(self.docs)),
            ("dropped", Value::u64(self.dropped)),
            ("survivors", Value::u64(self.survivors)),
        ])
    }

    /// Parse a manifest document; rejects unknown versions and
    /// internally inconsistent counters.
    pub fn from_json(v: &Value) -> Result<Self> {
        let u = |k: &str| -> Result<u64> {
            v.get(k)
                .and_then(|x| x.as_u64())
                .ok_or_else(|| Error::Format(format!("worker manifest '{k}' missing or not u64")))
        };
        let version = u("version")?;
        if version != WORKER_MANIFEST_VERSION {
            return Err(Error::Format(format!(
                "worker manifest version {version} unsupported \
                 (expected {WORKER_MANIFEST_VERSION})"
            )));
        }
        let m = Self {
            version,
            shard: u("shard")? as usize,
            num_shards: u("num_shards")? as usize,
            docs: u("docs")?,
            dropped: u("dropped")?,
            survivors: u("survivors")?,
        };
        if m.num_shards == 0 || m.shard >= m.num_shards {
            return Err(Error::Format(format!(
                "worker manifest shard {} out of range for {} shards",
                m.shard, m.num_shards
            )));
        }
        if m.dropped + m.survivors != m.docs {
            return Err(Error::Format(format!(
                "worker manifest counters disagree: {} dropped + {} survivors != {} docs",
                m.dropped, m.survivors, m.docs
            )));
        }
        Ok(m)
    }

    /// Write to `dir/worker-manifest.json` atomically (the shared
    /// `persist::write_atomic` tmp+fsync+rename publish) — the worker's
    /// very last act, so the manifest's existence *is* the completion
    /// marker.
    pub fn save(&self, dir: &Path) -> Result<()> {
        crate::persist::write_atomic(
            &dir.join(WORKER_MANIFEST_FILE),
            self.to_json().to_json().as_bytes(),
        )
    }

    /// Load and parse `dir/worker-manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join(WORKER_MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let v = json::parse(&text)
            .map_err(|e| Error::parse("worker manifest", e.to_string()))?;
        Self::from_json(&v)
    }

    /// Whether `dir` holds a completed worker run.
    pub fn exists(dir: &Path) -> bool {
        dir.join(WORKER_MANIFEST_FILE).is_file()
    }

    /// Remove a stale manifest so a (re)starting worker cannot be
    /// mistaken for complete while it is mid-ingest. Failure to remove
    /// an *existing* marker is a hard error — leaving it would let the
    /// supervisor aggregate a half-written shard.
    pub fn remove_stale(dir: &Path) -> Result<()> {
        for name in [WORKER_MANIFEST_FILE.to_string(), format!("{WORKER_MANIFEST_FILE}.tmp")] {
            crate::persist::remove_file_if_exists(&dir.join(name))?;
        }
        Ok(())
    }

    /// Supervisor-side consistency check: the manifest must describe
    /// exactly the shard slice the supervisor expects. Any disagreement
    /// marks the worker torn (eligible for restart), never silently
    /// aggregated.
    pub fn verify(&self, shard: usize, num_shards: usize, expect_docs: u64) -> Result<()> {
        if self.shard != shard || self.num_shards != num_shards {
            return Err(Error::Format(format!(
                "worker manifest describes shard {}/{} but the supervisor expected {}/{}",
                self.shard, self.num_shards, shard, num_shards
            )));
        }
        if self.docs != expect_docs {
            return Err(Error::Format(format!(
                "worker manifest for shard {shard} covers {} documents but the shard \
                 slice holds {expect_docs}; treating the worker as torn",
                self.docs
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WorkerManifest {
        WorkerManifest {
            version: WORKER_MANIFEST_VERSION,
            shard: 2,
            num_shards: 4,
            docs: 100,
            dropped: 37,
            survivors: 63,
        }
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lshbloom-wm-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        assert_eq!(WorkerManifest::from_json(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn save_load_and_completion_marker() {
        let dir = tmp_dir("roundtrip");
        let m = sample();
        assert!(!WorkerManifest::exists(&dir));
        m.save(&dir).unwrap();
        assert!(WorkerManifest::exists(&dir));
        assert_eq!(WorkerManifest::load(&dir).unwrap(), m);
        assert!(!dir.join(format!("{WORKER_MANIFEST_FILE}.tmp")).exists());
        WorkerManifest::remove_stale(&dir).unwrap();
        assert!(!WorkerManifest::exists(&dir));
        // Removing again is a no-op, not an error.
        WorkerManifest::remove_stale(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inconsistent_counters_rejected() {
        let mut m = sample();
        m.dropped += 1;
        let err = WorkerManifest::from_json(&m.to_json()).unwrap_err();
        assert!(err.to_string().contains("disagree"), "{err}");
    }

    #[test]
    fn unknown_version_rejected() {
        let mut m = sample();
        m.version = 99;
        assert!(WorkerManifest::from_json(&m.to_json()).is_err());
    }

    #[test]
    fn shard_out_of_range_rejected() {
        let mut m = sample();
        m.shard = 4; // == num_shards
        assert!(WorkerManifest::from_json(&m.to_json()).is_err());
    }

    #[test]
    fn verify_cross_checks_the_slice() {
        let m = sample();
        m.verify(2, 4, 100).unwrap();
        assert!(m.verify(1, 4, 100).is_err(), "wrong shard");
        assert!(m.verify(2, 8, 100).is_err(), "wrong shard count");
        let err = m.verify(2, 4, 101).unwrap_err();
        assert!(err.to_string().contains("torn"), "{err}");
    }
}
