//! Checkpoint write / restore / cross-process union over a
//! [`ConcurrentLshBloomIndex`].
//!
//! Write order is crash-safe by construction: every filter file is
//! durable (written-then-fsynced copies, or msync'd live mappings)
//! *before* the manifest publishes via tmp + rename. A crash mid-
//! checkpoint therefore leaves either the previous complete checkpoint
//! or none; restore never sees a manifest describing half-written
//! filters it cannot detect.
//!
//! Restore is strict (`ShmBitArray::open` discipline): geometry, file
//! size, and — for snapshot checkpoints — per-file checksums must match,
//! or restore refuses with a clear error instead of silently admitting
//! Bloom false negatives.
//!
//! ## Generations on disk
//!
//! A rotated index ([`ConcurrentLshBloomIndex`] generations) persists
//! generation 0 at the checkpoint root — byte-identical to the legacy
//! single-generation layout — and each later generation under a
//! `gen{g:03}/` subdirectory listed in the manifest's `generations`
//! array. All generations share one geometry (they are sized from the
//! same plan), so every per-file check applies uniformly; a manifest
//! that records a generation whose directory or files are missing is a
//! torn checkpoint and restore refuses it by name.

use super::manifest::{
    band_file_name, generation_dir_name, CheckpointManifest, CheckpointMode, ChecksumStream,
    FilterFile, GenerationEntry, MANIFEST_VERSION, MANIFEST_VERSION_GENERATIONAL,
};
use crate::engine::{AtomicBloomFilter, ConcurrentLshBloomIndex};
use crate::error::{Error, Result};
use crate::index::lshbloom::LshBloomConfig;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;

/// Words per IO chunk when copying a filter (64 KiB buffers).
const COPY_CHUNK_WORDS: usize = 8 * 1024;

/// Checksum a live filter's mapped/heap words (chunked acquire loads,
/// so the checksum covers at least every insert that happened-before
/// the checkpoint call).
fn checksum_filter(filter: &AtomicBloomFilter) -> u64 {
    let mut cs = ChecksumStream::new();
    for chunk in filter.words().chunks(COPY_CHUNK_WORDS) {
        let vals: Vec<u64> = chunk.iter().map(|w| w.load(Ordering::Acquire)).collect();
        cs.update(&vals);
    }
    cs.finish()
}

/// The one checksum-mismatch error, shared by every verify site so the
/// writer and verifiers can never drift apart on wording or layout.
fn checksum_mismatch(path: &Path, got: u64, want: u64) -> Error {
    Error::Format(format!(
        "checkpoint file {}: checksum {got:#018x} does not match manifest \
         {want:#018x}; refusing to restore a torn filter",
        path.display()
    ))
}

/// Directory that holds generation `g`'s files: the checkpoint root for
/// generation 0, `gen{g:03}/` after that.
fn generation_dir(dir: &Path, g: usize) -> PathBuf {
    if g == 0 {
        dir.to_path_buf()
    } else {
        dir.join(generation_dir_name(g))
    }
}

/// A manifest-listed generation directory that is absent on disk is a
/// torn checkpoint — named refusal, never silent false negatives.
fn missing_generation_dir(gdir: &Path) -> Error {
    Error::Format(format!(
        "checkpoint generation directory {} is missing but the manifest records it; \
         refusing to restore a torn generational checkpoint",
        gdir.display()
    ))
}

/// Persist `index` (plus the engine counters `docs`/`duplicates`) into
/// `dir`, returning the manifest that was written.
///
/// Filters already mmap-backed *inside `dir`* are checkpointed in place
/// (msync, no copy, no checksum — the periodic-checkpoint fast path;
/// restore never verifies live-mode checksums, so none are computed);
/// anything else is copied out as a checksummed cold snapshot. A rotated
/// index writes generation 0 at the root and later generations under
/// `gen{g:03}/` (see the module docs). For exact counters, call between
/// batches — concurrent inserts during the call are safe either way
/// (the files only ever gain bits).
///
/// # Examples
///
/// Persist an engine's index and read it back ([`restore_index`]):
///
/// ```
/// use lshbloom::config::PipelineConfig;
/// use lshbloom::corpus::Doc;
/// use lshbloom::engine::ConcurrentEngine;
/// use lshbloom::persist::{restore_index, write_checkpoint};
///
/// let cfg = PipelineConfig { num_perms: 32, expected_docs: 1_000, ..Default::default() };
/// let dir = std::env::temp_dir().join(format!("lshbloom-doc-ckpt-{}", std::process::id()));
/// # std::fs::remove_dir_all(&dir).ok();
/// let engine = ConcurrentEngine::from_config(&cfg);
/// engine.submit(vec![Doc { id: 0, text: "checkpointed document".into() }]);
/// let manifest = write_checkpoint(engine.index(), 1, 0, &dir)?;
/// assert_eq!(manifest.docs, 1);
///
/// let (restored, manifest) = restore_index(&dir, &engine.index().config(), false)?;
/// assert_eq!(restored.len(), 1);
/// assert_eq!(manifest.duplicates, 0);
/// # std::fs::remove_dir_all(&dir).ok();
/// # Ok::<(), lshbloom::error::Error>(())
/// ```
pub fn write_checkpoint(
    index: &ConcurrentLshBloomIndex,
    docs: u64,
    duplicates: u64,
    dir: &Path,
) -> Result<CheckpointManifest> {
    let _wall = crate::obs::span("persist.checkpoint");
    crate::obs::global().counter("persist.checkpoints.total").inc();
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let config = index.config();
    let params = crate::index::LshBloomIndex::filter_params(&config);
    let gens = index.generation_snapshot();
    let mut live = 0usize;
    let mut per_gen_files: Vec<Vec<FilterFile>> = Vec::with_capacity(gens.len());
    for (g, filters) in gens.iter().enumerate() {
        let gdir = generation_dir(dir, g);
        if g > 0 {
            std::fs::create_dir_all(&gdir)
                .map_err(|e| Error::io(gdir.display().to_string(), e))?;
        }
        per_gen_files.push(write_generation_files(filters.iter(), &gdir, &mut live)?);
    }
    let mut per_gen_files = per_gen_files.into_iter();
    let files = per_gen_files.next().unwrap_or_default();
    let generations: Vec<GenerationEntry> = per_gen_files
        .enumerate()
        .map(|(i, files)| GenerationEntry { dir: generation_dir_name(i + 1), files })
        .collect();
    let manifest = CheckpointManifest {
        version: if generations.is_empty() {
            MANIFEST_VERSION
        } else {
            MANIFEST_VERSION_GENERATIONAL
        },
        // Any in-place file means the bytes can keep moving under the
        // manifest, so checksums are meaningless there (and unrecorded).
        mode: if live > 0 { CheckpointMode::Live } else { CheckpointMode::Snapshot },
        num_bands: config.lsh.num_bands,
        rows_per_band: config.lsh.rows_per_band,
        p_effective: config.p_effective,
        expected_docs: config.expected_docs,
        filter_params: params,
        inserted: index.len(),
        docs,
        duplicates,
        files,
        generations,
    };
    manifest.save(dir)?;
    Ok(manifest)
}

/// Write one generation's band files into `gdir` (live msync or cold
/// copy per filter), returning the manifest entries. `live` counts the
/// in-place files so callers can pick the manifest mode.
fn write_generation_files<'a>(
    filters: impl IntoIterator<Item = &'a AtomicBloomFilter>,
    gdir: &Path,
    live: &mut usize,
) -> Result<Vec<FilterFile>> {
    let mut files = Vec::new();
    for (i, filter) in filters.into_iter().enumerate() {
        let name = band_file_name(i);
        let target = gdir.join(&name);
        let words = filter.word_count() as u64;
        let checksum = if filter.backing_path() == Some(target.as_path()) {
            // Live in-place checkpoint: the mapping *is* the file. No
            // checksum — restore skips verification for live mode by
            // design (post-crash bytes may legitimately be a superset),
            // so computing one would scan every word of a multi-GB
            // index per periodic checkpoint for a number nothing reads.
            filter.sync()?;
            *live += 1;
            0
        } else {
            copy_filter_cold(filter, gdir, &name)?
        };
        files.push(FilterFile { name, words, checksum, inserted: filter.inserted() });
    }
    Ok(files)
}

/// [`write_checkpoint`] over an explicit band-ordered filter list — the
/// shared core that also lets the band-sliced serving engine
/// ([`crate::engine::BandShardedEngine`]) persist its slices as one
/// full-index checkpoint (its filters live in N slice structs, not one
/// index). Writes the single-generation layout; generational callers go
/// through [`write_checkpoint_generations`], [`write_checkpoint`], or
/// [`write_slice_checkpoint`].
pub(crate) fn write_checkpoint_filters(
    filters: &[&AtomicBloomFilter],
    config: &LshBloomConfig,
    inserted: u64,
    docs: u64,
    duplicates: u64,
    dir: &Path,
) -> Result<CheckpointManifest> {
    write_checkpoint_generations(&[filters.to_vec()], config, inserted, docs, duplicates, dir)
}

/// [`write_checkpoint_filters`] over per-generation filter lists
/// (oldest first, each in full band order) — the sharded serving
/// engine's checkpoint path once its slices carry frozen generations
/// restored from a rotated index. Writes the same on-disk layout as
/// [`write_checkpoint`]: generation 0 at the root, later generations
/// under `gen{g:03}/` recorded in the manifest's `generations` array.
pub(crate) fn write_checkpoint_generations(
    gen_filters: &[Vec<&AtomicBloomFilter>],
    config: &LshBloomConfig,
    inserted: u64,
    docs: u64,
    duplicates: u64,
    dir: &Path,
) -> Result<CheckpointManifest> {
    let _wall = crate::obs::span("persist.checkpoint");
    crate::obs::global().counter("persist.checkpoints.total").inc();
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let params = crate::index::LshBloomIndex::filter_params(config);
    let mut live = 0usize;
    let mut per_gen_files: Vec<Vec<FilterFile>> = Vec::with_capacity(gen_filters.len());
    for (g, filters) in gen_filters.iter().enumerate() {
        let gdir = generation_dir(dir, g);
        if g > 0 {
            std::fs::create_dir_all(&gdir)
                .map_err(|e| Error::io(gdir.display().to_string(), e))?;
        }
        per_gen_files.push(write_generation_files(filters.iter().copied(), &gdir, &mut live)?);
    }
    let mut per_gen_files = per_gen_files.into_iter();
    let files = per_gen_files.next().unwrap_or_default();
    let generations: Vec<GenerationEntry> = per_gen_files
        .enumerate()
        .map(|(i, files)| GenerationEntry { dir: generation_dir_name(i + 1), files })
        .collect();
    let manifest = CheckpointManifest {
        version: if generations.is_empty() {
            MANIFEST_VERSION
        } else {
            MANIFEST_VERSION_GENERATIONAL
        },
        mode: if live > 0 { CheckpointMode::Live } else { CheckpointMode::Snapshot },
        num_bands: config.lsh.num_bands,
        rows_per_band: config.lsh.rows_per_band,
        p_effective: config.p_effective,
        expected_docs: config.expected_docs,
        filter_params: params,
        inserted,
        docs,
        duplicates,
        files,
        generations,
    };
    manifest.save(dir)?;
    Ok(manifest)
}

/// Write `filter`'s words to `dir/name` as a checksummed cold copy
/// (tmp + fsync + rename), returning the checksum. Each word is read
/// once into the buffer, and both the file bytes and the checksum come
/// from that one read, so they agree even if other threads are
/// inserting concurrently.
fn copy_filter_cold(filter: &AtomicBloomFilter, dir: &Path, name: &str) -> Result<u64> {
    let target = dir.join(name);
    let tmp = dir.join(format!("{name}.tmp"));
    let file =
        std::fs::File::create(&tmp).map_err(|e| Error::io(tmp.display().to_string(), e))?;
    let mut w = std::io::BufWriter::new(file);
    let mut cs = ChecksumStream::new();
    for chunk in filter.words().chunks(COPY_CHUNK_WORDS) {
        let vals: Vec<u64> = chunk.iter().map(|x| x.load(Ordering::Acquire)).collect();
        cs.update(&vals);
        let mut bytes = Vec::with_capacity(vals.len() * 8);
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&bytes).map_err(|e| Error::io(tmp.display().to_string(), e))?;
    }
    let file = w
        .into_inner()
        .map_err(|e| Error::io(tmp.display().to_string(), e.into_error()))?;
    file.sync_all().map_err(|e| Error::io(tmp.display().to_string(), e))?;
    std::fs::rename(&tmp, &target).map_err(|e| Error::io(target.display().to_string(), e))?;
    Ok(cs.finish())
}

/// Placeholder manifest entries for every band of `config` — the shape
/// a slice writer publishes for bands it does not own when no sibling
/// has persisted them yet. `verify_geometry` checks recorded word
/// counts, never file bytes, so a placeholder keeps the manifest
/// restorable by the bands' real owner while costing nothing on disk.
fn placeholder_files(expect_words: u64, num_bands: usize) -> Vec<FilterFile> {
    (0..num_bands)
        .map(|g| FilterFile {
            name: band_file_name(g),
            words: expect_words,
            checksum: 0,
            inserted: 0,
        })
        .collect()
}

/// Open — or create — the durable mmap-backed filters for the bands
/// `range` of the checkpoint in `dir`: the crash-safe backing store of
/// a `serve --slice-index --state-dir` replica
/// ([`crate::engine::BandSliceIndex::open_durable`] wraps it).
///
/// With a manifest present the geometry is verified with full-restore
/// strictness, each owned band file — of *every* recorded generation —
/// is re-attached in place (`ShmAtomicBitArray::open`'s exact-size
/// discipline — a torn or truncated file is a named error, never a
/// silent false-negative source) and, for snapshot checkpoints,
/// checksum-verified before the manifest is republished in live mode
/// (the files mutate in place from here on, so stale snapshot checksums
/// must not survive to reject the next restart). A manifest entry whose
/// file is missing is recreated zeroed only when it records zero inserts
/// (a sibling slice's placeholder); a missing file with recorded inserts
/// — or a whole missing generation directory — is a hard error. Without
/// a manifest, fresh zeroed files are created for the owned range and a
/// live-mode manifest with placeholder entries for the other bands is
/// published.
///
/// Returns the owned filters per generation (oldest first, each in band
/// order) plus the manifest's document counter (0 for fresh state).
/// Bits reach the backing files on every insert (mmap), so a crash
/// loses no inserts; the *counters* are only as fresh as the last
/// manifest publish — re-converge them through the serving tier's
/// anti-entropy pull before trusting them.
pub fn open_durable_slice(
    expect: &LshBloomConfig,
    range: std::ops::Range<usize>,
    dir: &Path,
) -> Result<(Vec<Vec<AtomicBloomFilter>>, u64)> {
    let _wall = crate::obs::span("persist.restore");
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let params = crate::index::LshBloomIndex::filter_params(expect);
    let expect_words = params.bits.div_ceil(64);
    if CheckpointManifest::exists(dir) {
        let mut manifest = CheckpointManifest::load(dir)?;
        manifest.verify_geometry(expect)?;
        let mut generations = Vec::with_capacity(manifest.num_generations());
        for g in 0..manifest.num_generations() {
            let gdir = generation_dir(dir, g);
            if g > 0 && !gdir.is_dir() {
                return Err(missing_generation_dir(&gdir));
            }
            let entries =
                if g == 0 { &manifest.files } else { &manifest.generations[g - 1].files };
            let mut filters = Vec::with_capacity(range.len());
            for entry in &entries[range.clone()] {
                let path = gdir.join(&entry.name);
                let filter = if path.is_file() {
                    let filter = AtomicBloomFilter::open_shm(params, &path, entry.inserted)?;
                    if manifest.mode == CheckpointMode::Snapshot {
                        let got = checksum_filter(&filter);
                        if got != entry.checksum {
                            return Err(checksum_mismatch(&path, got, entry.checksum));
                        }
                    }
                    filter
                } else if entry.inserted == 0 {
                    // A sibling slice published the manifest with a
                    // placeholder for this band; materialize it zeroed.
                    AtomicBloomFilter::new_shm(params, &path)?
                } else {
                    return Err(Error::Format(format!(
                        "checkpoint file {} is missing but its manifest entry records {} \
                         inserts; refusing to restore a torn slice",
                        path.display(),
                        entry.inserted
                    )));
                };
                filters.push(filter);
            }
            generations.push(filters);
        }
        // The owned files are live mappings from here on: flip the
        // manifest to live mode and zero the owned checksums so a
        // crash-restart does not reject legitimately moved-on bytes.
        if manifest.mode == CheckpointMode::Snapshot {
            manifest.mode = CheckpointMode::Live;
        }
        for g in range.clone() {
            manifest.files[g].checksum = 0;
        }
        for gen in &mut manifest.generations {
            for g in range.clone() {
                gen.files[g].checksum = 0;
            }
        }
        let inserted = manifest.inserted;
        manifest.save(dir)?;
        Ok((generations, inserted))
    } else {
        let mut filters = Vec::with_capacity(range.len());
        for g in range.clone() {
            filters.push(AtomicBloomFilter::new_shm(params, &dir.join(band_file_name(g)))?);
        }
        let manifest = CheckpointManifest {
            version: MANIFEST_VERSION,
            mode: CheckpointMode::Live,
            num_bands: expect.lsh.num_bands,
            rows_per_band: expect.lsh.rows_per_band,
            p_effective: expect.p_effective,
            expected_docs: expect.expected_docs,
            filter_params: params,
            inserted: 0,
            docs: 0,
            duplicates: 0,
            files: placeholder_files(expect_words, expect.lsh.num_bands),
            generations: Vec::new(),
        };
        manifest.save(dir)?;
        Ok((vec![filters], 0))
    }
}

/// Publish/refresh the entries for the bands `range` of the checkpoint
/// manifest in `dir` — the slice-owned half of [`write_checkpoint`],
/// used by a durable slice replica at orderly shutdown (and after an
/// anti-entropy merge). Read-modify-write: an existing
/// geometry-compatible manifest keeps its entries for bands outside
/// `range` (so N slices sharing one directory tile a full-index
/// manifest between them), a missing one starts from placeholders; the
/// manifest's generation list grows (with placeholder entries) to cover
/// every generation this writer holds, and generations only the
/// manifest knows about are preserved. `gen_filters` are the owned
/// filters per generation, each in band order; mmap-backed filters
/// already living at their target path are msync'd in place, anything
/// else is cold-copied. The manifest always publishes in live mode —
/// entries owned by *other* slices may describe files still mutating in
/// place, so snapshot-grade checksums cannot be promised for the
/// directory as a whole.
///
/// The manifest-global counters (`inserted`/`docs`/`duplicates`) are
/// published as `max(existing, this writer's view)`: they are monotone
/// under both crash-restart and shared-directory tiling, so a slice
/// that served no traffic cannot wipe a sibling's (or a full
/// checkpoint's) corpus history. The serving tier treats them as
/// advisory either way and re-converges replica counters over the wire.
pub fn write_slice_checkpoint(
    gen_filters: &[Vec<AtomicBloomFilter>],
    config: &LshBloomConfig,
    range: std::ops::Range<usize>,
    inserted: u64,
    docs: u64,
    duplicates: u64,
    dir: &Path,
) -> Result<CheckpointManifest> {
    let _wall = crate::obs::span("persist.checkpoint");
    crate::obs::global().counter("persist.checkpoints.total").inc();
    for filters in gen_filters {
        if filters.len() != range.len() {
            return Err(Error::Format(format!(
                "write_slice_checkpoint: {} filters for band range {range:?}",
                filters.len()
            )));
        }
    }
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let params = crate::index::LshBloomIndex::filter_params(config);
    let expect_words = params.bits.div_ceil(64);
    let mut inserted = inserted;
    let mut docs = docs;
    let mut duplicates = duplicates;
    let (mut files, mut generations) = if CheckpointManifest::exists(dir) {
        let existing = CheckpointManifest::load(dir)?;
        // Refusing a mismatched directory beats silently clobbering a
        // foreign checkpoint's manifest with wrong-geometry entries.
        existing.verify_geometry(config)?;
        inserted = inserted.max(existing.inserted);
        docs = docs.max(existing.docs);
        duplicates = duplicates.max(existing.duplicates);
        (existing.files, existing.generations)
    } else {
        (placeholder_files(expect_words, config.lsh.num_bands), Vec::new())
    };
    while generations.len() + 1 < gen_filters.len() {
        generations.push(GenerationEntry {
            dir: generation_dir_name(generations.len() + 1),
            files: placeholder_files(expect_words, config.lsh.num_bands),
        });
    }
    for (g, filters) in gen_filters.iter().enumerate() {
        let gdir = generation_dir(dir, g);
        if g > 0 {
            std::fs::create_dir_all(&gdir)
                .map_err(|e| Error::io(gdir.display().to_string(), e))?;
        }
        for (filter, band) in filters.iter().zip(range.clone()) {
            let name = band_file_name(band);
            let target = gdir.join(&name);
            if filter.backing_path() == Some(target.as_path()) {
                filter.sync()?;
            } else {
                copy_filter_cold(filter, &gdir, &name)?;
            }
            let entry = FilterFile {
                name,
                words: filter.word_count() as u64,
                // Live-mode manifests carry no meaningful checksums; zero
                // even the cold-copied ones so no reader can mistake a
                // partially-checksummed directory for a verified snapshot.
                checksum: 0,
                inserted: filter.inserted(),
            };
            if g == 0 {
                files[band] = entry;
            } else {
                generations[g - 1].files[band] = entry;
            }
        }
    }
    let manifest = CheckpointManifest {
        version: if generations.is_empty() {
            MANIFEST_VERSION
        } else {
            MANIFEST_VERSION_GENERATIONAL
        },
        mode: CheckpointMode::Live,
        num_bands: config.lsh.num_bands,
        rows_per_band: config.lsh.rows_per_band,
        p_effective: config.p_effective,
        expected_docs: config.expected_docs,
        filter_params: params,
        inserted,
        docs,
        duplicates,
        files,
        generations,
    };
    manifest.save(dir)?;
    Ok(manifest)
}

/// Read one whole band file, verifying its size (and, in snapshot mode,
/// its checksum) before handing the words back.
fn read_band_words(
    dir: &Path,
    entry: &FilterFile,
    mode: CheckpointMode,
    expect_words: u64,
) -> Result<Vec<u64>> {
    let path = dir.join(&entry.name);
    let bytes = std::fs::read(&path).map_err(|e| Error::io(path.display().to_string(), e))?;
    if bytes.len() as u64 != expect_words * 8 {
        return Err(Error::Format(format!(
            "checkpoint file {}: {} bytes on disk but the geometry needs {} \
             ({} words); refusing to restore a torn filter",
            path.display(),
            bytes.len(),
            expect_words * 8,
            expect_words
        )));
    }
    let words: Vec<u64> = bytes
        .chunks_exact(8)
        .map(|c| {
            // chunks_exact(8) guarantees the width; no fallible cast.
            let mut le = [0u8; 8];
            le.copy_from_slice(c);
            u64::from_le_bytes(le)
        })
        .collect();
    if mode == CheckpointMode::Snapshot {
        let mut cs = ChecksumStream::new();
        cs.update(&words);
        let got = cs.finish();
        if got != entry.checksum {
            return Err(checksum_mismatch(&path, got, entry.checksum));
        }
    }
    Ok(words)
}

/// Restore one generation's filters from `gdir` (full band set).
fn restore_generation(
    gdir: &Path,
    entries: &[FilterFile],
    mode: CheckpointMode,
    params: crate::bloom::BloomParams,
    expect_words: u64,
    mmap: bool,
) -> Result<Vec<AtomicBloomFilter>> {
    let mut filters = Vec::with_capacity(entries.len());
    for entry in entries {
        if mmap {
            let path = gdir.join(&entry.name);
            let filter = AtomicBloomFilter::open_shm(params, &path, entry.inserted)?;
            if mode == CheckpointMode::Snapshot {
                let got = checksum_filter(&filter);
                if got != entry.checksum {
                    return Err(checksum_mismatch(&path, got, entry.checksum));
                }
            }
            filters.push(filter);
        } else {
            let words = read_band_words(gdir, entry, mode, expect_words)?;
            filters.push(AtomicBloomFilter::from_heap_words(words, entry.inserted, params));
        }
    }
    Ok(filters)
}

/// Restore an index from the checkpoint in `dir`.
///
/// `expect` is the geometry the caller is about to serve with; any
/// mismatch with the manifest is a hard error (a wrong-geometry filter
/// silently answers `false` for keys it was never probed at — Bloom
/// false negatives). Every recorded generation is re-attached, so a
/// rotated index resumes with its full membership history and keeps
/// inserting into the newest generation. With `mmap` the band files
/// become the live backing store (subsequent inserts mutate them in
/// place and the next [`write_checkpoint`] is an msync); without it the
/// words are copied to heap atomics and `dir` is left untouched.
///
/// See [`write_checkpoint`] for a runnable write-then-restore example.
pub fn restore_index(
    dir: &Path,
    expect: &LshBloomConfig,
    mmap: bool,
) -> Result<(ConcurrentLshBloomIndex, CheckpointManifest)> {
    let _wall = crate::obs::span("persist.restore");
    let manifest = CheckpointManifest::load(dir)?;
    manifest.verify_geometry(expect)?;
    let params = manifest.filter_params;
    let expect_words = params.bits.div_ceil(64);
    let mut generations = Vec::with_capacity(manifest.num_generations());
    generations.push(restore_generation(
        dir,
        &manifest.files,
        manifest.mode,
        params,
        expect_words,
        mmap,
    )?);
    for gen in &manifest.generations {
        let gdir = dir.join(&gen.dir);
        if !gdir.is_dir() {
            return Err(missing_generation_dir(&gdir));
        }
        generations.push(restore_generation(
            &gdir,
            &gen.files,
            manifest.mode,
            params,
            expect_words,
            mmap,
        )?);
    }
    let index = ConcurrentLshBloomIndex::from_generations(generations, *expect, manifest.inserted);
    Ok((index, manifest))
}

/// Restore only the bands `range` of the checkpoint in `dir` — the
/// slice-aware half of [`restore_index`], used by the band-partitioned
/// serving tier ([`crate::engine::BandSliceIndex::restore`]) so each of
/// N slice owners loads just its own filters from one full-index
/// checkpoint (e.g. the aggregated output of a `dedup --distributed`
/// run).
///
/// Geometry is verified against the *full* expected config first, with
/// the same strictness as a full restore; per-file size (and, for
/// snapshot checkpoints, checksum) checks cover exactly the files in
/// `range`, in every recorded generation. The filters come back as heap
/// copies per generation (oldest first, each in band order) and the
/// checkpoint directory is left untouched — slices are read-only views
/// of a checkpoint, re-persisted (if at all) through
/// [`crate::engine::BandShardedEngine::checkpoint`].
pub fn restore_band_slice(
    dir: &Path,
    expect: &LshBloomConfig,
    range: std::ops::Range<usize>,
) -> Result<(Vec<Vec<AtomicBloomFilter>>, CheckpointManifest)> {
    let _wall = crate::obs::span("persist.restore");
    let manifest = CheckpointManifest::load(dir)?;
    let generations = restore_band_slice_from(&manifest, dir, expect, range)?;
    Ok((generations, manifest))
}

/// [`restore_band_slice`] against an already-loaded manifest — the
/// many-slices path ([`crate::engine::BandShardedEngine::restore`])
/// loads and parses `manifest.json` once instead of once per slice.
pub(crate) fn restore_band_slice_from(
    manifest: &CheckpointManifest,
    dir: &Path,
    expect: &LshBloomConfig,
    range: std::ops::Range<usize>,
) -> Result<Vec<Vec<AtomicBloomFilter>>> {
    manifest.verify_geometry(expect)?;
    let params = manifest.filter_params;
    let expect_words = params.bits.div_ceil(64);
    let mut generations = Vec::with_capacity(manifest.num_generations());
    let restore_range = |gdir: &Path, entries: &[FilterFile]| -> Result<Vec<AtomicBloomFilter>> {
        let mut filters = Vec::with_capacity(range.len());
        for entry in &entries[range.clone()] {
            let words = read_band_words(gdir, entry, manifest.mode, expect_words)?;
            filters.push(AtomicBloomFilter::from_heap_words(words, entry.inserted, params));
        }
        Ok(filters)
    };
    generations.push(restore_range(dir, &manifest.files)?);
    for gen in &manifest.generations {
        let gdir = dir.join(&gen.dir);
        if !gdir.is_dir() {
            return Err(missing_generation_dir(&gdir));
        }
        generations.push(restore_range(&gdir, &gen.files)?);
    }
    Ok(generations)
}

/// Bit-OR a *persisted* checkpoint into a live index — the cross-process
/// half of the sharded-aggregation seam (paper §6): a sibling process
/// checkpoints its shard filters, and this process folds them in
/// straight from the files, no re-MinHashing, no IPC beyond the
/// filesystem. Generations align by position (both sides derive every
/// generation from the same plan) and the live index opens fresh
/// generations as needed to absorb a checkpoint that rotated further.
/// Returns the merged checkpoint's document count.
///
/// Geometry is verified strictly against `index.config()` first, and in
/// snapshot mode each file's checksum is verified *before* any of its
/// bits are OR'd in, so a torn file cannot pollute the aggregate.
pub fn union_from_checkpoint(index: &ConcurrentLshBloomIndex, dir: &Path) -> Result<u64> {
    let manifest = CheckpointManifest::load(dir)?;
    manifest.verify_geometry(&index.config())?;
    let expect_words = manifest.filter_params.bits.div_ceil(64);
    index.ensure_generations(manifest.num_generations())?;
    let gens = index.generation_snapshot();
    merge_generation(&gens[0], dir, &manifest.files, manifest.mode, expect_words)?;
    for (g, gen) in manifest.generations.iter().enumerate() {
        let gdir = dir.join(&gen.dir);
        if !gdir.is_dir() {
            return Err(missing_generation_dir(&gdir));
        }
        merge_generation(&gens[g + 1], &gdir, &gen.files, manifest.mode, expect_words)?;
    }
    index.add_inserted(manifest.inserted);
    Ok(manifest.docs)
}

/// OR one persisted generation's files into the matching live filters.
fn merge_generation(
    filters: &[AtomicBloomFilter],
    gdir: &Path,
    entries: &[FilterFile],
    mode: CheckpointMode,
    expect_words: u64,
) -> Result<()> {
    debug_assert_eq!(filters.len(), entries.len());
    for (filter, entry) in filters.iter().zip(entries) {
        let words = read_band_words(gdir, entry, mode, expect_words)?;
        if words.len() != filter.word_count() {
            return Err(Error::Format(format!(
                "checkpoint file {}: {} words but the live filter has {}",
                entry.name,
                words.len(),
                filter.word_count()
            )));
        }
        filter.or_words_at(0, &words);
        filter.add_inserted(entry.inserted);
    }
    Ok(())
}
