//! Versioned checkpoint manifest: the durable description of one
//! persisted [`crate::engine::ConcurrentLshBloomIndex`].
//!
//! A checkpoint directory holds one raw bit file per band
//! (`band{i:03}.bits`, little-endian u64 words — the exact bytes an
//! mmap-backed filter leaves on disk) plus a `manifest.json` recording:
//!
//! * the full index geometry (band count, rows per band, the derived
//!   per-filter [`BloomParams`], and the config inputs they came from),
//! * the engine counters at checkpoint time (docs seen, duplicates
//!   flagged, index inserts),
//! * per-file word counts and checksums.
//!
//! The manifest is written *last* (tmp + rename) so a crash mid-
//! checkpoint leaves either the previous complete manifest or none —
//! never a manifest describing half-written filters. Restore verifies
//! geometry strictly (mirroring `ShmBitArray::open`'s exact-size
//! discipline: admitting a mismatched filter would manufacture false
//! negatives) and, for `snapshot` checkpoints, per-file checksums.
//! `live` checkpoints — manifests over filter files that an engine keeps
//! mutating in place — verify geometry and size but not checksums: after
//! a crash the kernel may have written back bits from documents ingested
//! *after* the checkpoint, which is exactly the documented
//! over-approximation (never under-approximation) contract.

use crate::bloom::BloomParams;
use crate::error::{Error, Result};
use crate::index::lshbloom::LshBloomConfig;
use crate::json::{self, obj, Value};
use crate::minhash::LshParams;
use crate::rng::mix64;
use std::path::Path;

/// Manifest format version; bumped on any incompatible layout change.
pub const MANIFEST_VERSION: u64 = 1;

/// Manifest format version for generational checkpoints (more than one
/// filter set). Single-generation checkpoints keep writing
/// [`MANIFEST_VERSION`] so pre-generational readers stay compatible.
pub const MANIFEST_VERSION_GENERATIONAL: u64 = 2;

/// File name of the manifest inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// How the filter files relate to the manifest that describes them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointMode {
    /// Cold copy: files were written once and not touched since; restore
    /// verifies checksums exactly.
    Snapshot,
    /// Files are (or were) a live mmap an engine mutates in place;
    /// post-crash bytes may legitimately be a superset of the manifest's
    /// snapshot, so checksums are neither recorded (stored as 0) nor
    /// verified on restore — geometry and exact size still are.
    Live,
}

impl CheckpointMode {
    fn as_str(self) -> &'static str {
        match self {
            CheckpointMode::Snapshot => "snapshot",
            CheckpointMode::Live => "live",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "snapshot" => Ok(CheckpointMode::Snapshot),
            "live" => Ok(CheckpointMode::Live),
            other => Err(Error::Format(format!("unknown checkpoint mode '{other}'"))),
        }
    }
}

/// Per-band filter file entry.
#[derive(Clone, Debug)]
pub struct FilterFile {
    /// File name inside the checkpoint directory (`band{i:03}.bits`).
    pub name: String,
    /// u64 word count (file size / 8).
    pub words: u64,
    /// [`ChecksumStream`] digest over the words at checkpoint time
    /// (snapshot mode only; 0 and meaningless for live checkpoints).
    pub checksum: u64,
    /// Keys inserted into this filter at checkpoint time.
    pub inserted: u64,
}

/// One generation beyond generation 0: its subdirectory inside the
/// checkpoint and the per-band files it holds. Generation 0's files live
/// at the checkpoint root (the legacy single-generation layout), so a
/// non-rotated index round-trips byte-identically to the v1 format.
#[derive(Clone, Debug)]
pub struct GenerationEntry {
    /// Subdirectory name inside the checkpoint dir (`gen{g:03}`).
    pub dir: String,
    /// One entry per band, band order.
    pub files: Vec<FilterFile>,
}

/// The manifest proper.
#[derive(Clone, Debug)]
pub struct CheckpointManifest {
    /// Format version ([`MANIFEST_VERSION`]).
    pub version: u64,
    /// Snapshot (checksummed cold copy) vs live (in-place mmap) files.
    pub mode: CheckpointMode,
    /// Index geometry inputs (reconstructs [`LshBloomConfig`]): LSH
    /// band count…
    pub num_bands: usize,
    /// …rows hashed per band…
    pub rows_per_band: usize,
    /// …index-wide effective false-positive bound (§4.3)…
    pub p_effective: f64,
    /// …and planned corpus cardinality (sizes each band filter).
    pub expected_docs: u64,
    /// Derived per-filter geometry, recorded redundantly so a manifest
    /// is self-checking even if the derivation formula ever drifts.
    pub filter_params: BloomParams,
    /// Documents inserted into the index at checkpoint time.
    pub inserted: u64,
    /// Engine counter at checkpoint time: documents processed…
    pub docs: u64,
    /// …and duplicates flagged among them.
    pub duplicates: u64,
    /// One entry per band, band order (generation 0, checkpoint root).
    pub files: Vec<FilterFile>,
    /// Generations beyond 0, oldest first (`gen{g:03}/` subdirectories);
    /// empty for a never-rotated index, which keeps the manifest at
    /// [`MANIFEST_VERSION`].
    pub generations: Vec<GenerationEntry>,
}

/// Conventional file name for band `i`.
pub fn band_file_name(band: usize) -> String {
    format!("band{band:03}.bits")
}

/// Conventional subdirectory name for generation `g` (generation 0 lives
/// at the checkpoint root; rotated generations in `gen{g:03}/`).
pub fn generation_dir_name(generation: usize) -> String {
    format!("gen{generation:03}")
}

/// Inverse of [`generation_dir_name`]: `Some(g)` when `name` names a
/// generation subdirectory.
pub fn parse_generation_dir_name(name: &str) -> Option<usize> {
    let digits = name.strip_prefix("gen")?;
    if digits.len() < 3 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Running checksum over a stream of u64 words, fed in chunks.
///
/// mix64-chained (not a plain XOR/sum, which would miss word swaps);
/// finish with [`ChecksumStream::finish`], which folds in the length so
/// truncation changes the digest.
pub struct ChecksumStream {
    acc: u64,
    words: u64,
}

impl ChecksumStream {
    /// Fresh stream (FNV offset-basis seed).
    pub fn new() -> Self {
        Self { acc: 0xcbf2_9ce4_8422_2325, words: 0 }
    }

    /// Fold a chunk of words into the digest.
    #[inline]
    pub fn update(&mut self, words: &[u64]) {
        for &w in words {
            self.acc = mix64(self.acc ^ w);
        }
        self.words += words.len() as u64;
    }

    /// Finalize, folding in the total length so truncation is detected.
    pub fn finish(self) -> u64 {
        mix64(self.acc ^ self.words)
    }
}

impl Default for ChecksumStream {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot checksum of a full word slice.
pub fn checksum_words(words: impl IntoIterator<Item = u64>) -> u64 {
    let mut cs = ChecksumStream::new();
    for w in words {
        cs.update(std::slice::from_ref(&w));
    }
    cs.finish()
}

impl CheckpointManifest {
    /// The [`LshBloomConfig`] this checkpoint was taken under.
    pub fn index_config(&self) -> LshBloomConfig {
        LshBloomConfig {
            lsh: LshParams { num_bands: self.num_bands, rows_per_band: self.rows_per_band },
            p_effective: self.p_effective,
            expected_docs: self.expected_docs,
            blocked: false,
        }
    }

    /// Strict geometry check against a config the caller is about to
    /// serve with. Everything that shapes filter bits must agree;
    /// anything less silently corrupts the membership contract
    /// (admitting false negatives), so mismatches are hard errors.
    pub fn verify_geometry(&self, expect: &LshBloomConfig) -> Result<()> {
        let mismatch = |what: &str, want: String, got: String| {
            Err(Error::Format(format!(
                "checkpoint geometry mismatch on {what}: manifest has {got}, \
                 run config needs {want}; refusing to restore a mismatched index"
            )))
        };
        if self.num_bands != expect.lsh.num_bands {
            return mismatch(
                "num_bands",
                expect.lsh.num_bands.to_string(),
                self.num_bands.to_string(),
            );
        }
        if self.rows_per_band != expect.lsh.rows_per_band {
            return mismatch(
                "rows_per_band",
                expect.lsh.rows_per_band.to_string(),
                self.rows_per_band.to_string(),
            );
        }
        let want = crate::index::LshBloomIndex::filter_params(expect);
        if self.filter_params != want {
            return mismatch(
                "filter params",
                format!("{want:?}"),
                format!("{:?}", self.filter_params),
            );
        }
        // Self-consistency: the recorded params must also re-derive from
        // the recorded inputs, so a hand-edited manifest cannot smuggle
        // mismatched geometry past the input fields.
        let rederived = crate::index::LshBloomIndex::filter_params(&self.index_config());
        if self.filter_params != rederived {
            return Err(Error::Format(format!(
                "checkpoint manifest is self-inconsistent: recorded filter params \
                 {:?} do not re-derive from its own config inputs ({rederived:?})",
                self.filter_params
            )));
        }
        if self.files.len() != self.num_bands {
            return Err(Error::Format(format!(
                "checkpoint manifest lists {} filter files for {} bands",
                self.files.len(),
                self.num_bands
            )));
        }
        let expect_words = self.filter_params.bits.div_ceil(64);
        for f in &self.files {
            if f.words != expect_words {
                return Err(Error::Format(format!(
                    "checkpoint file {} records {} words but the geometry needs {expect_words}",
                    f.name, f.words
                )));
            }
        }
        // Every generation shares one geometry (they are all sized from
        // the same plan), so the same word-count discipline applies.
        for (gi, g) in self.generations.iter().enumerate() {
            if g.files.len() != self.num_bands {
                return Err(Error::Format(format!(
                    "checkpoint manifest generation {} lists {} filter files for {} bands",
                    gi + 1,
                    g.files.len(),
                    self.num_bands
                )));
            }
            for f in &g.files {
                if f.words != expect_words {
                    return Err(Error::Format(format!(
                        "checkpoint generation file {}/{} records {} words but the geometry \
                         needs {expect_words}",
                        g.dir, f.name, f.words
                    )));
                }
            }
        }
        Ok(())
    }

    /// Total generations described (1 + rotated generations).
    pub fn num_generations(&self) -> usize {
        1 + self.generations.len()
    }

    /// Serialize to the manifest JSON document.
    pub fn to_json(&self) -> Value {
        fn file_json(f: &FilterFile) -> Value {
            obj(vec![
                ("name", Value::str(f.name.clone())),
                ("words", Value::u64(f.words)),
                // u64 checksums exceed f64's mantissa; the crate's
                // json keeps the raw token so they round-trip exactly.
                ("checksum", Value::u64(f.checksum)),
                ("inserted", Value::u64(f.inserted)),
            ])
        }
        let files: Vec<Value> = self.files.iter().map(file_json).collect();
        let mut fields = vec![
            ("version", Value::u64(self.version)),
            ("mode", Value::str(self.mode.as_str())),
            ("num_bands", Value::u64(self.num_bands as u64)),
            ("rows_per_band", Value::u64(self.rows_per_band as u64)),
            ("p_effective", Value::num(self.p_effective)),
            ("expected_docs", Value::u64(self.expected_docs)),
            ("filter_bits", Value::u64(self.filter_params.bits)),
            ("filter_hashes", Value::u64(self.filter_params.hashes as u64)),
            ("filter_capacity", Value::u64(self.filter_params.capacity)),
            ("inserted", Value::u64(self.inserted)),
            ("docs", Value::u64(self.docs)),
            ("duplicates", Value::u64(self.duplicates)),
            ("files", Value::Arr(files)),
        ];
        if !self.generations.is_empty() {
            let gens: Vec<Value> = self
                .generations
                .iter()
                .map(|g| {
                    obj(vec![
                        ("dir", Value::str(g.dir.clone())),
                        ("files", Value::Arr(g.files.iter().map(file_json).collect())),
                    ])
                })
                .collect();
            fields.push(("generations", Value::Arr(gens)));
        }
        obj(fields)
    }

    /// Parse a manifest document; rejects unknown versions.
    pub fn from_json(v: &Value) -> Result<Self> {
        let field = |k: &str| {
            v.get(k).ok_or_else(|| Error::Format(format!("checkpoint manifest missing '{k}'")))
        };
        let u = |k: &str| -> Result<u64> {
            field(k)?
                .as_u64()
                .ok_or_else(|| Error::Format(format!("checkpoint manifest '{k}' not a u64")))
        };
        let version = u("version")?;
        if version != MANIFEST_VERSION && version != MANIFEST_VERSION_GENERATIONAL {
            return Err(Error::Format(format!(
                "checkpoint manifest version {version} unsupported (expected \
                 {MANIFEST_VERSION} or {MANIFEST_VERSION_GENERATIONAL})"
            )));
        }
        let mode = CheckpointMode::parse(
            field("mode")?
                .as_str()
                .ok_or_else(|| Error::Format("checkpoint manifest 'mode' not a string".into()))?,
        )?;
        let p_effective = field("p_effective")?
            .as_f64()
            .ok_or_else(|| Error::Format("checkpoint manifest 'p_effective' not a number".into()))?;
        fn parse_files(arr: &[Value], ctx: &str) -> Result<Vec<FilterFile>> {
            let mut files = Vec::with_capacity(arr.len());
            for (i, fv) in arr.iter().enumerate() {
                let fu = |k: &str| -> Result<u64> {
                    fv.get(k).and_then(|x| x.as_u64()).ok_or_else(|| {
                        Error::Format(format!(
                            "checkpoint manifest {ctx}[{i}].{k} missing or not u64"
                        ))
                    })
                };
                files.push(FilterFile {
                    name: fv
                        .get("name")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| {
                            Error::Format(format!("checkpoint manifest {ctx}[{i}].name missing"))
                        })?
                        .to_string(),
                    words: fu("words")?,
                    checksum: fu("checksum")?,
                    inserted: fu("inserted")?,
                });
            }
            Ok(files)
        }
        let files_json = field("files")?
            .as_arr()
            .ok_or_else(|| Error::Format("checkpoint manifest 'files' not an array".into()))?;
        let files = parse_files(files_json, "files")?;
        let mut generations = Vec::new();
        if let Some(gens_json) = v.get("generations").and_then(|x| x.as_arr()) {
            for (gi, gv) in gens_json.iter().enumerate() {
                let dir = gv
                    .get("dir")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| {
                        Error::Format(format!(
                            "checkpoint manifest generations[{gi}].dir missing"
                        ))
                    })?
                    .to_string();
                let gfiles = gv.get("files").and_then(|x| x.as_arr()).ok_or_else(|| {
                    Error::Format(format!(
                        "checkpoint manifest generations[{gi}].files missing or not an array"
                    ))
                })?;
                generations.push(GenerationEntry {
                    dir,
                    files: parse_files(gfiles, "generations.files")?,
                });
            }
        }
        Ok(Self {
            version,
            mode,
            num_bands: u("num_bands")? as usize,
            rows_per_band: u("rows_per_band")? as usize,
            p_effective,
            expected_docs: u("expected_docs")?,
            filter_params: BloomParams {
                bits: u("filter_bits")?,
                hashes: u("filter_hashes")? as u32,
                capacity: u("filter_capacity")?,
            },
            inserted: u("inserted")?,
            docs: u("docs")?,
            duplicates: u("duplicates")?,
            files,
            generations,
        })
    }

    /// Write to `dir/manifest.json` atomically (tmp + rename), fsyncing
    /// the temp file so the rename publishes durable bytes.
    pub fn save(&self, dir: &Path) -> Result<()> {
        crate::persist::write_atomic(&dir.join(MANIFEST_FILE), self.to_json().to_json().as_bytes())
    }

    /// Load and parse `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let v = json::parse(&text)
            .map_err(|e| Error::parse("checkpoint manifest", e.to_string()))?;
        Self::from_json(&v)
    }

    /// Whether `dir` holds a (complete) checkpoint.
    pub fn exists(dir: &Path) -> bool {
        dir.join(MANIFEST_FILE).is_file()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CheckpointManifest {
        let config = LshBloomConfig {
            lsh: LshParams { num_bands: 4, rows_per_band: 8 },
            p_effective: 1e-8,
            expected_docs: 10_000,
            blocked: false,
        };
        let params = crate::index::LshBloomIndex::filter_params(&config);
        let words = params.bits.div_ceil(64);
        CheckpointManifest {
            version: MANIFEST_VERSION,
            mode: CheckpointMode::Snapshot,
            num_bands: 4,
            rows_per_band: 8,
            p_effective: 1e-8,
            expected_docs: 10_000,
            filter_params: params,
            inserted: 123,
            docs: 150,
            duplicates: 27,
            files: (0..4)
                .map(|i| FilterFile {
                    name: band_file_name(i),
                    words,
                    checksum: 0xDEAD_BEEF_0000_0001 + i as u64,
                    inserted: 123,
                })
                .collect(),
            generations: Vec::new(),
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let m = sample();
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.version, m.version);
        assert_eq!(back.mode, m.mode);
        assert_eq!(back.num_bands, m.num_bands);
        assert_eq!(back.rows_per_band, m.rows_per_band);
        assert_eq!(back.p_effective, m.p_effective);
        assert_eq!(back.expected_docs, m.expected_docs);
        assert_eq!(back.filter_params, m.filter_params);
        assert_eq!(back.inserted, m.inserted);
        assert_eq!(back.docs, m.docs);
        assert_eq!(back.duplicates, m.duplicates);
        assert_eq!(back.files.len(), 4);
        // u64 checksums survive the f64-mantissa trap via raw tokens.
        assert_eq!(back.files[0].checksum, m.files[0].checksum);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lshbloom-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample();
        m.save(&dir).unwrap();
        assert!(CheckpointManifest::exists(&dir));
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back.docs, m.docs);
        assert!(!dir.join(format!("{MANIFEST_FILE}.tmp")).exists(), "tmp must be renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn geometry_verification_catches_drift() {
        let m = sample();
        m.verify_geometry(&m.index_config()).unwrap();
        let mut other = m.index_config();
        other.expected_docs = 99_999;
        let err = m.verify_geometry(&other).unwrap_err();
        assert!(err.to_string().contains("geometry mismatch"), "{err}");
        let mut other = m.index_config();
        other.lsh.num_bands = 5;
        assert!(m.verify_geometry(&other).is_err());
    }

    #[test]
    fn self_inconsistent_manifest_rejected() {
        let mut m = sample();
        m.filter_params.bits += 64; // no longer derives from the inputs
        for f in &mut m.files {
            f.words = m.filter_params.bits.div_ceil(64);
        }
        let err = m.verify_geometry(&m.index_config()).unwrap_err();
        assert!(err.to_string().contains("mismatch"), "{err}");
    }

    #[test]
    fn unknown_version_rejected() {
        let mut v = sample().to_json();
        if let Value::Obj(map) = &mut v {
            map.insert("version".into(), Value::u64(99));
        }
        let err = CheckpointManifest::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn single_generation_manifest_stays_version_one() {
        // The legacy layout must keep round-tripping through version 1
        // with no `generations` key, so pre-generational readers accept
        // checkpoints from never-rotated indexes.
        let m = sample();
        let j = m.to_json();
        assert_eq!(j.get("version").and_then(|v| v.as_u64()), Some(MANIFEST_VERSION));
        assert!(j.get("generations").is_none());
        assert_eq!(CheckpointManifest::from_json(&j).unwrap().num_generations(), 1);
    }

    #[test]
    fn generational_manifest_roundtrips() {
        let mut m = sample();
        m.version = MANIFEST_VERSION_GENERATIONAL;
        m.generations = vec![GenerationEntry {
            dir: generation_dir_name(1),
            files: m.files.clone(),
        }];
        m.verify_geometry(&m.index_config()).unwrap();
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.version, MANIFEST_VERSION_GENERATIONAL);
        assert_eq!(back.num_generations(), 2);
        assert_eq!(back.generations[0].dir, "gen001");
        assert_eq!(back.generations[0].files.len(), 4);
        assert_eq!(back.generations[0].files[0].checksum, m.files[0].checksum);
    }

    #[test]
    fn generation_with_wrong_band_count_rejected() {
        let mut m = sample();
        m.version = MANIFEST_VERSION_GENERATIONAL;
        let mut files = m.files.clone();
        files.pop();
        m.generations = vec![GenerationEntry { dir: generation_dir_name(1), files }];
        let err = m.verify_geometry(&m.index_config()).unwrap_err();
        assert!(err.to_string().contains("generation"), "{err}");
    }

    #[test]
    fn generation_dir_names_roundtrip() {
        assert_eq!(generation_dir_name(1), "gen001");
        assert_eq!(parse_generation_dir_name("gen001"), Some(1));
        assert_eq!(parse_generation_dir_name("gen123"), Some(123));
        assert_eq!(parse_generation_dir_name("band003.bits"), None);
        assert_eq!(parse_generation_dir_name("gen01"), None);
        assert_eq!(parse_generation_dir_name("genxyz"), None);
    }

    #[test]
    fn checksum_detects_reorder_and_truncation() {
        let a = checksum_words([1u64, 2, 3]);
        let b = checksum_words([3u64, 2, 1]);
        let c = checksum_words([1u64, 2]);
        assert_ne!(a, b, "order must matter");
        assert_ne!(a, c, "length must matter");
        assert_eq!(a, checksum_words([1u64, 2, 3]), "deterministic");
    }
}
