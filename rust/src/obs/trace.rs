//! Dependency-free distributed request tracing.
//!
//! Metrics (the rest of [`crate::obs`]) aggregate; traces explain one
//! request. This module adds the request-scoped layer on top of the
//! same std-only substrate:
//!
//! * **Identity** — process-unique 128-bit trace IDs and 64-bit span
//!   IDs (splitmix64 over a per-process seed + atomic counter), with a
//!   compact `"<32 hex>-<16 hex>"` wire encoding ([`TraceContext`])
//!   carried in the `trace` field of the JSON line protocol and in the
//!   `LSHBLOOM_TRACE_PARENT` environment variable across `worker`
//!   process spawns.
//! * **Storage** — a fixed-capacity lock-free ring of finished spans
//!   ([`RING_CAPACITY`] slots). Writers claim a slot with one
//!   `fetch_add` and publish through a per-slot seqlock (odd = mid-
//!   write); readers that observe a torn slot skip it. Drop-oldest,
//!   every field an atomic, no `unsafe`, and zero heap allocation on
//!   the record path once the per-thread scratch is warm.
//! * **Sampling** — per-listener [`TraceParams`]: errors and requests
//!   slower than `slow_ms` always record; the rest record with
//!   probability `sample`, decided deterministically from the trace ID
//!   so every hop of a distributed request agrees without coordination.
//!
//! A request handler opens a [`RootGuard`] ([`start_root`] to mint,
//! [`adopt_root`] when the peer supplied a context). In-flight child
//! spans — including every [`crate::obs::span`] guard dropped on the
//! same thread — buffer into thread-local scratch and flush to the
//! ring only if the root ends up recorded, so an error discovered late
//! still promotes the full span set. Finished traces are served by
//! [`traces_json`]/[`slowest_json`] (the `/debug/traces` HTTP routes
//! and the `{"op":"trace_dump"}` wire op).
//!
//! The ring's own bookkeeping counters (`trace.spans_recorded.total`,
//! `trace.spans_dropped.total`) live in the global registry but are
//! deliberately absent from the OPERATIONS.md metric catalog: they are
//! observability-internal, like the registry's own uptime gauge.

use std::collections::BTreeMap;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::json::{obj, Value};

/// Environment variable carrying a [`TraceContext`] across process
/// spawns (supervisor → worker).
pub const TRACE_PARENT_ENV: &str = "LSHBLOOM_TRACE_PARENT";

/// Finished-span ring capacity (power of two; drop-oldest).
pub const RING_CAPACITY: usize = 2048;

/// Per-root cap on buffered child spans; beyond it children are
/// counted as dropped rather than grown without bound.
const MAX_CHILDREN: usize = 64;

/// Span label bytes stored inline in a ring slot (longer labels are
/// truncated; rendered lossily).
const NAME_BYTES: usize = 40;
const NAME_WORDS: usize = NAME_BYTES / 8;

// ---------------------------------------------------------------------
// Identity
// ---------------------------------------------------------------------

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-process ID seed: wall clock ⊕ pid ⊕ a stack address, mixed.
fn process_seed() -> u64 {
    static SEED: OnceLock<u64> = OnceLock::new();
    *SEED.get_or_init(|| {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        let pid = u64::from(std::process::id());
        let stack = &t as *const u64 as usize as u64;
        splitmix64(t ^ pid.rotate_left(32) ^ stack)
    })
}

static ID_COUNTER: AtomicU64 = AtomicU64::new(1);

/// A fresh process-unique nonzero 64-bit span ID.
pub fn new_span_id() -> u64 {
    let n = ID_COUNTER.fetch_add(1, Ordering::Relaxed);
    let id = splitmix64(process_seed() ^ n);
    if id == 0 {
        1
    } else {
        id
    }
}

/// A fresh process-unique nonzero 128-bit trace ID.
pub fn new_trace_id() -> u128 {
    (u128::from(new_span_id()) << 64) | u128::from(new_span_id())
}

/// Wire-propagated trace identity: which trace, and which span is the
/// parent of whatever the receiver does next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceContext {
    /// 128-bit trace identity shared by every span in the tree.
    pub trace_id: u128,
    /// Span ID of the sender's current span (the receiver's parent).
    pub span_id: u64,
}

impl TraceContext {
    /// Encode as the wire/env form `"<32 hex>-<16 hex>"`.
    pub fn encode(&self) -> String {
        format!("{:032x}-{:016x}", self.trace_id, self.span_id)
    }

    /// Parse the wire/env form. Anything malformed (wrong shape, bad
    /// hex, zero trace ID) yields `None` — a garbled or missing trace
    /// field degrades to untraced, never to an error.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        if s.len() != 49 || s.as_bytes()[32] != b'-' {
            return None;
        }
        let trace_id = u128::from_str_radix(&s[..32], 16).ok()?;
        let span_id = u64::from_str_radix(&s[33..], 16).ok()?;
        if trace_id == 0 {
            return None;
        }
        Some(Self { trace_id, span_id })
    }

    /// Parse [`TRACE_PARENT_ENV`] from the process environment.
    pub fn from_env() -> Option<Self> {
        std::env::var(TRACE_PARENT_ENV).ok().as_deref().and_then(Self::parse)
    }
}

// ---------------------------------------------------------------------
// Sampling
// ---------------------------------------------------------------------

/// Per-listener tracing knobs (`--trace-sample`, `--trace-slow-ms`).
///
/// Carried by each server/router instance rather than a process global
/// so in-process fleets (tests, benches) with different settings do
/// not race. The default is fully off: sample `0.0`, no slow threshold.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct TraceParams {
    /// Probability in `[0, 1]` that a non-error, non-slow trace records.
    pub sample: f64,
    /// Slow-request threshold in milliseconds; `0` disables. Requests
    /// at or above it always record and emit a slow-request log line.
    pub slow_ms: u64,
}

impl TraceParams {
    /// Deterministic sampling verdict for `trace_id` — every process
    /// that sees the same trace ID at the same rate agrees.
    pub fn sampled(&self, trace_id: u128) -> bool {
        if self.sample >= 1.0 {
            return true;
        }
        if self.sample <= 0.0 {
            return false;
        }
        let mixed = splitmix64(trace_id as u64 ^ (trace_id >> 64) as u64);
        let unit = (mixed >> 11) as f64 / (1u64 << 53) as f64;
        unit < self.sample
    }
}

// ---------------------------------------------------------------------
// Fixed labels
// ---------------------------------------------------------------------

/// A span label stored inline (no heap) — truncated at [`NAME_BYTES`].
#[derive(Clone, Copy)]
struct Name {
    bytes: [u8; NAME_BYTES],
    len: u8,
}

impl Name {
    fn new(s: &str) -> Self {
        let mut bytes = [0u8; NAME_BYTES];
        let take = s.len().min(NAME_BYTES);
        bytes[..take].copy_from_slice(&s.as_bytes()[..take]);
        Self { bytes, len: take as u8 }
    }

    fn render(&self) -> String {
        String::from_utf8_lossy(&self.bytes[..usize::from(self.len)]).into_owned()
    }
}

// ---------------------------------------------------------------------
// The finished-span ring
// ---------------------------------------------------------------------

/// One finished span, as read back out of the ring.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Trace this span belongs to.
    pub trace_id: u128,
    /// This span's ID.
    pub span_id: u64,
    /// Parent span ID (`0` = root with no parent).
    pub parent_id: u64,
    /// Span label (op name, `hop <addr>`, …).
    pub name: String,
    /// Wall-clock start, microseconds since the Unix epoch.
    pub start_us: u64,
    /// Duration in nanoseconds as measured by the recording process.
    pub dur_ns: u64,
    /// For cross-process hop spans: the far side's self-reported
    /// duration in nanoseconds (`0` = not a hop / not reported).
    pub remote_ns: u64,
}

/// A span staged in thread-local scratch before the root decides
/// whether the trace records at all.
#[derive(Clone, Copy)]
struct Pending {
    span_id: u64,
    parent_id: u64,
    name: Name,
    start_us: u64,
    dur_ns: u64,
    remote_ns: u64,
}

/// Ring slot: a seqlock (odd `seq` = mid-write) over all-atomic
/// fields. Torn reads are detected and skipped, never UB.
struct Slot {
    seq: AtomicU64,
    trace_hi: AtomicU64,
    trace_lo: AtomicU64,
    span_id: AtomicU64,
    parent_id: AtomicU64,
    start_us: AtomicU64,
    dur_ns: AtomicU64,
    remote_ns: AtomicU64,
    name_len: AtomicU64,
    name: [AtomicU64; NAME_WORDS],
}

impl Slot {
    fn empty() -> Self {
        Self {
            seq: AtomicU64::new(0),
            trace_hi: AtomicU64::new(0),
            trace_lo: AtomicU64::new(0),
            span_id: AtomicU64::new(0),
            parent_id: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_ns: AtomicU64::new(0),
            remote_ns: AtomicU64::new(0),
            name_len: AtomicU64::new(0),
            name: [const { AtomicU64::new(0) }; NAME_WORDS],
        }
    }

    fn publish(&self, ticket: u64, trace_id: u128, p: &Pending) {
        // Seqlock write: go odd, fence, write fields, go even.
        self.seq.store(ticket * 2 + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        self.trace_hi.store((trace_id >> 64) as u64, Ordering::Relaxed);
        self.trace_lo.store(trace_id as u64, Ordering::Relaxed);
        self.span_id.store(p.span_id, Ordering::Relaxed);
        self.parent_id.store(p.parent_id, Ordering::Relaxed);
        self.start_us.store(p.start_us, Ordering::Relaxed);
        self.dur_ns.store(p.dur_ns, Ordering::Relaxed);
        self.remote_ns.store(p.remote_ns, Ordering::Relaxed);
        self.name_len.store(u64::from(p.name.len), Ordering::Relaxed);
        for (word, chunk) in self.name.iter().zip(p.name.bytes.chunks_exact(8)) {
            let mut b = [0u8; 8];
            b.copy_from_slice(chunk);
            word.store(u64::from_le_bytes(b), Ordering::Relaxed);
        }
        self.seq.store(ticket * 2 + 2, Ordering::Release);
    }

    fn read(&self) -> Option<SpanRecord> {
        for _ in 0..3 {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                return None; // never written, or mid-write right now
            }
            let hi = self.trace_hi.load(Ordering::Relaxed);
            let lo = self.trace_lo.load(Ordering::Relaxed);
            let rec = SpanRecord {
                trace_id: (u128::from(hi) << 64) | u128::from(lo),
                span_id: self.span_id.load(Ordering::Relaxed),
                parent_id: self.parent_id.load(Ordering::Relaxed),
                name: {
                    let mut bytes = [0u8; NAME_BYTES];
                    for (chunk, word) in bytes.chunks_exact_mut(8).zip(self.name.iter()) {
                        chunk.copy_from_slice(&word.load(Ordering::Relaxed).to_le_bytes());
                    }
                    let len = (self.name_len.load(Ordering::Relaxed) as usize).min(NAME_BYTES);
                    String::from_utf8_lossy(&bytes[..len]).into_owned()
                },
                start_us: self.start_us.load(Ordering::Relaxed),
                dur_ns: self.dur_ns.load(Ordering::Relaxed),
                remote_ns: self.remote_ns.load(Ordering::Relaxed),
            };
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) == s1 {
                return Some(rec);
            }
        }
        None // persistently torn under write pressure: skip
    }
}

struct Ring {
    slots: Vec<Slot>,
    cursor: AtomicU64,
}

fn ring() -> &'static Ring {
    static RING: OnceLock<Ring> = OnceLock::new();
    RING.get_or_init(|| Ring {
        slots: (0..RING_CAPACITY).map(|_| Slot::empty()).collect(),
        cursor: AtomicU64::new(0),
    })
}

fn push_record(trace_id: u128, p: &Pending) {
    let r = ring();
    let ticket = r.cursor.fetch_add(1, Ordering::Relaxed);
    r.slots[ticket as usize % RING_CAPACITY].publish(ticket, trace_id, p);
    recorded_counter().add(1);
}

fn recorded_counter() -> &'static crate::obs::Counter {
    static C: OnceLock<std::sync::Arc<crate::obs::Counter>> = OnceLock::new();
    &**C.get_or_init(|| crate::obs::global().counter("trace.spans_recorded.total"))
}

fn dropped_counter() -> &'static crate::obs::Counter {
    static C: OnceLock<std::sync::Arc<crate::obs::Counter>> = OnceLock::new();
    &**C.get_or_init(|| crate::obs::global().counter("trace.spans_dropped.total"))
}

/// All currently-readable finished spans, oldest first by start time.
pub fn snapshot() -> Vec<SpanRecord> {
    let mut out: Vec<SpanRecord> = ring().slots.iter().filter_map(Slot::read).collect();
    out.sort_by_key(|r| (r.start_us, r.span_id));
    out
}

// ---------------------------------------------------------------------
// The active trace (thread-local)
// ---------------------------------------------------------------------

struct Active {
    trace_id: u128,
    root_span: u64,
    root_parent: u64,
    root_name: Name,
    start: Instant,
    start_us: u64,
    sampled: bool,
    forced: bool,
    slow_ms: u64,
    children: Vec<Pending>,
    dropped: u32,
}

thread_local! {
    static ACTIVE: std::cell::RefCell<Option<Active>> =
        const { std::cell::RefCell::new(None) };
    /// Child-span scratch recycled across roots on this thread, so a
    /// warm request thread records without heap allocation.
    static SCRATCH: std::cell::RefCell<Vec<Pending>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

fn unix_micros_now() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_micros() as u64).unwrap_or(0)
}

/// RAII guard for the root span of a request (or run) on this thread.
///
/// On drop, the trace flushes to the ring iff it was sampled, forced
/// ([`force_record`]), or at least `slow_ms` old — and in the slow case
/// also emits a slow-request log line with the per-hop breakdown.
#[must_use = "the root span records when the guard drops"]
pub struct RootGuard {
    /// True when a root was already active on this thread: this guard
    /// then records a plain child span instead of closing the trace.
    nested: bool,
    name: Name,
    start: Instant,
}

fn install_root(ctx: TraceContext, parent: u64, name: &str, params: TraceParams) -> RootGuard {
    let name = Name::new(name);
    let start = Instant::now();
    let nested = ACTIVE.with(|a| a.borrow().is_some());
    if nested {
        return RootGuard { nested: true, name, start };
    }
    let children = SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
    ACTIVE.with(|a| {
        *a.borrow_mut() = Some(Active {
            trace_id: ctx.trace_id,
            root_span: ctx.span_id,
            root_parent: parent,
            root_name: name,
            start,
            start_us: unix_micros_now(),
            sampled: params.sampled(ctx.trace_id),
            forced: false,
            slow_ms: params.slow_ms,
            children,
            dropped: 0,
        });
    });
    RootGuard { nested: false, name, start }
}

/// Mint a fresh trace and open its root span on this thread.
pub fn start_root(name: &str, params: TraceParams) -> RootGuard {
    let ctx = TraceContext { trace_id: new_trace_id(), span_id: new_span_id() };
    install_root(ctx, 0, name, params)
}

/// Open a root span that continues a trace begun elsewhere: the local
/// root's parent is the remote sender's span.
pub fn adopt_root(ctx: TraceContext, name: &str, params: TraceParams) -> RootGuard {
    let local = TraceContext { trace_id: ctx.trace_id, span_id: new_span_id() };
    install_root(local, ctx.span_id, name, params)
}

/// Adopt [`TRACE_PARENT_ENV`] if present and well-formed; the returned
/// guard is pre-forced (process-level runs always record).
pub fn root_from_env(name: &str, params: TraceParams) -> Option<RootGuard> {
    let ctx = TraceContext::from_env()?;
    let guard = adopt_root(ctx, name, params);
    force_record();
    Some(guard)
}

/// The active trace's identity on this thread (trace ID + root span),
/// ready to stamp onto an outbound request or a child process env.
pub fn current_context() -> Option<TraceContext> {
    ACTIVE.with(|a| {
        a.borrow()
            .as_ref()
            .map(|t| TraceContext { trace_id: t.trace_id, span_id: t.root_span })
    })
}

/// Whether the active trace will flush on root drop as things stand —
/// the cue for spending wire bytes on propagation. True when sampled,
/// already forced, or a slow threshold is armed (a trace that *might*
/// still be promoted needs its hop timings).
pub fn should_propagate() -> bool {
    ACTIVE.with(|a| {
        a.borrow().as_ref().map(|t| t.sampled || t.forced || t.slow_ms > 0).unwrap_or(false)
    })
}

/// Force the active trace to record regardless of sampling — error
/// paths and run-level roots call this.
pub fn force_record() {
    ACTIVE.with(|a| {
        if let Some(t) = a.borrow_mut().as_mut() {
            t.forced = true;
        }
    });
}

/// `span_id` of `0` means "mint one" — deferred so the untraced fast
/// path pays one thread-local check and nothing else.
fn stage_child(span_id: u64, name: &str, dur: Duration, remote_ns: u64) {
    ACTIVE.with(|a| {
        let mut slot = a.borrow_mut();
        let Some(t) = slot.as_mut() else {
            return; // fast path: untraced thread
        };
        if t.children.len() >= MAX_CHILDREN {
            t.dropped += 1;
            return;
        }
        t.children.push(Pending {
            span_id: if span_id == 0 { new_span_id() } else { span_id },
            parent_id: t.root_span,
            name: Name::new(name),
            start_us: unix_micros_now().saturating_sub(dur.as_micros() as u64),
            dur_ns: dur.as_nanos() as u64,
            remote_ns,
        });
    });
}

/// Record a finished in-process child span (duration just elapsed,
/// attached to the active root). No-op without an active trace —
/// [`crate::obs::Span`] calls this unconditionally on drop.
pub fn record_child(name: &str, dur: Duration) {
    stage_child(0, name, dur, 0);
}

/// Record a cross-process hop: the local (client-side) duration plus
/// the far side's self-reported span ID and duration from the reply.
/// When `remote_span` is nonzero the hop reuses it, so the same span
/// appears as the hop here and as the root in the far side's own ring
/// — two views of one RPC.
pub fn record_hop(name: &str, remote_span: u64, local_dur: Duration, remote_ns: u64) {
    stage_child(remote_span, name, local_dur, remote_ns);
}

impl Drop for RootGuard {
    fn drop(&mut self) {
        if self.nested {
            record_child(&self.name.render(), self.start.elapsed());
            return;
        }
        let Some(mut t) = ACTIVE.with(|a| a.borrow_mut().take()) else {
            return;
        };
        let dur = t.start.elapsed();
        let dur_ns = dur.as_nanos() as u64;
        let slow = t.slow_ms > 0 && dur >= Duration::from_millis(t.slow_ms);
        if t.sampled || t.forced || slow {
            push_record(
                t.trace_id,
                &Pending {
                    span_id: t.root_span,
                    parent_id: t.root_parent,
                    name: t.root_name,
                    start_us: t.start_us,
                    dur_ns,
                    remote_ns: 0,
                },
            );
            for child in &t.children {
                push_record(t.trace_id, child);
            }
            if t.dropped > 0 {
                dropped_counter().add(u64::from(t.dropped));
            }
            if slow {
                let hops: Vec<(String, f64, f64)> = t
                    .children
                    .iter()
                    .map(|c| (c.name.render(), c.dur_ns as f64 / 1e6, c.remote_ns as f64 / 1e6))
                    .collect();
                crate::logging::slow_request(
                    &t.root_name.render(),
                    dur.as_secs_f64() * 1e3,
                    &format!("{:032x}", t.trace_id),
                    &hops,
                );
            }
        }
        // Hand the scratch buffer back for the thread's next root.
        t.children.clear();
        SCRATCH.with(|s| *s.borrow_mut() = t.children);
    }
}

// ---------------------------------------------------------------------
// Trace assembly + JSON exposition
// ---------------------------------------------------------------------

struct Tree {
    trace_id: u128,
    op: String,
    start_us: u64,
    duration_ns: u64,
    complete: bool,
    spans: Vec<SpanRecord>,
}

/// Group the ring's spans into per-trace trees. A trace is `complete`
/// when exactly one span qualifies as its root (parent `0` or parent
/// not present locally — a wrapped-out parent or a remote one); with
/// drop-oldest eviction a tree can lose its root while children
/// survive, and such partial trees are reported, flagged, not dropped.
fn assemble() -> Vec<Tree> {
    let mut by: BTreeMap<u128, Vec<SpanRecord>> = BTreeMap::new();
    for rec in snapshot() {
        by.entry(rec.trace_id).or_default().push(rec);
    }
    by.into_iter()
        .map(|(trace_id, spans)| {
            let ids: std::collections::BTreeSet<u64> =
                spans.iter().map(|s| s.span_id).collect();
            let mut roots =
                spans.iter().filter(|s| s.parent_id == 0 || !ids.contains(&s.parent_id));
            let root = roots.next();
            let complete = root.is_some() && roots.next().is_none();
            let (op, start_us, duration_ns) = match root {
                Some(r) => (r.name.clone(), r.start_us, r.dur_ns),
                None => (String::new(), spans.first().map(|s| s.start_us).unwrap_or(0), 0),
            };
            Tree { trace_id, op, start_us, duration_ns, complete, spans }
        })
        .collect()
}

fn tree_json(t: &Tree) -> Value {
    let spans: Vec<Value> = t
        .spans
        .iter()
        .map(|s| {
            let mut pairs = vec![
                ("span_id", Value::u64(s.span_id)),
                ("parent_id", Value::u64(s.parent_id)),
                ("name", Value::str(s.name.as_str())),
                ("start_us", Value::u64(s.start_us)),
                ("dur_ns", Value::u64(s.dur_ns)),
            ];
            if s.remote_ns > 0 {
                pairs.push(("server_dur_ns", Value::u64(s.remote_ns)));
            }
            obj(pairs)
        })
        .collect();
    obj(vec![
        ("trace_id", Value::str(format!("{:032x}", t.trace_id))),
        ("op", Value::str(t.op.as_str())),
        ("start_us", Value::u64(t.start_us)),
        ("duration_ns", Value::u64(t.duration_ns)),
        ("complete", Value::Bool(t.complete)),
        ("spans", Value::Arr(spans)),
    ])
}

/// Recent traces as JSON, newest first: `{"traces": [...]}`.
/// `op` filters on the root span's exact name; `min_dur_ns` on the
/// root duration; `limit` caps the result.
pub fn traces_json(op: Option<&str>, min_dur_ns: u64, limit: usize) -> Value {
    let mut trees: Vec<Tree> = assemble()
        .into_iter()
        .filter(|t| op.is_none_or(|o| t.op == o) && t.duration_ns >= min_dur_ns)
        .collect();
    trees.sort_by(|a, b| b.start_us.cmp(&a.start_us));
    trees.truncate(limit);
    obj(vec![("traces", Value::Arr(trees.iter().map(tree_json).collect()))])
}

/// The `limit` slowest traces by root duration, slowest first.
pub fn slowest_json(limit: usize) -> Value {
    let mut trees = assemble();
    trees.sort_by(|a, b| b.duration_ns.cmp(&a.duration_ns));
    trees.truncate(limit);
    obj(vec![("traces", Value::Arr(trees.iter().map(tree_json).collect()))])
}

/// The span ring is process-global; tests (here and in sibling obs
/// modules) that write it or assert on its contents serialize on this
/// lock so wraparound tests cannot evict another test's spans
/// mid-assertion.
#[cfg(test)]
pub(crate) fn test_ring_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring_lock() -> std::sync::MutexGuard<'static, ()> {
        test_ring_lock()
    }

    fn params(sample: f64) -> TraceParams {
        TraceParams { sample, slow_ms: 0 }
    }

    fn spans_of(trace_id: u128) -> Vec<SpanRecord> {
        snapshot().into_iter().filter(|s| s.trace_id == trace_id).collect()
    }

    #[test]
    fn context_encode_parse_roundtrip() {
        let ctx = TraceContext { trace_id: new_trace_id(), span_id: new_span_id() };
        assert_eq!(TraceContext::parse(&ctx.encode()), Some(ctx));
    }

    #[test]
    fn garbled_context_is_none_never_a_panic() {
        let bads = [
            String::new(),
            "nonsense".to_string(),
            "123-456".to_string(),
            "f".repeat(49), // right length, no separator
            format!("{}-{}", "g".repeat(32), "0".repeat(16)), // not hex
            format!("{}-{}", "0".repeat(32), "0".repeat(16)), // zero trace id
            format!("{}+{}", "a".repeat(32), "b".repeat(16)), // wrong separator
        ];
        for bad in &bads {
            assert_eq!(TraceContext::parse(bad), None, "input {bad:?}");
        }
    }

    #[test]
    fn ids_are_unique_and_nonzero() {
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            let id = new_span_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "span id repeated");
        }
        assert_ne!(new_trace_id(), 0);
    }

    #[test]
    fn sampling_is_deterministic_and_roughly_proportional() {
        let p = TraceParams { sample: 0.5, slow_ms: 0 };
        let ids: Vec<u128> = (0..2000).map(|_| new_trace_id()).collect();
        let hits = ids.iter().filter(|&&id| p.sampled(id)).count();
        assert!((700..1300).contains(&hits), "0.5 sampling hit {hits}/2000");
        for &id in &ids[..50] {
            assert_eq!(p.sampled(id), p.sampled(id), "same id must decide the same way");
        }
        assert!(params(1.0).sampled(ids[0]));
        assert!(!params(0.0).sampled(ids[0]));
    }

    #[test]
    fn sampled_root_flushes_root_and_children() {
        let _g = ring_lock();
        let tid;
        {
            let _root = start_root("test.sampled_op", params(1.0));
            tid = current_context().unwrap().trace_id;
            record_child("test.child_a", Duration::from_micros(50));
            record_child("test.child_b", Duration::from_micros(70));
        }
        let spans = spans_of(tid);
        assert_eq!(spans.len(), 3, "root + two children");
        let root =
            spans.iter().find(|s| s.name == "test.sampled_op").expect("root recorded");
        assert_eq!(root.parent_id, 0);
        for child in spans.iter().filter(|s| s.span_id != root.span_id) {
            assert_eq!(child.parent_id, root.span_id);
        }
    }

    #[test]
    fn unsampled_root_records_nothing_but_error_forces() {
        let _g = ring_lock();
        let quiet;
        {
            let _root = start_root("test.unsampled_op", params(0.0));
            quiet = current_context().unwrap().trace_id;
            record_child("test.lost_child", Duration::from_micros(10));
        }
        assert!(spans_of(quiet).is_empty(), "sampling=0 must add no spans");

        let forced;
        {
            let _root = start_root("test.error_op", params(0.0));
            forced = current_context().unwrap().trace_id;
            record_child("test.pre_error_child", Duration::from_micros(10));
            force_record();
        }
        let spans = spans_of(forced);
        assert_eq!(spans.len(), 2, "forced trace keeps buffered children");
        assert!(spans.iter().any(|s| s.name == "test.pre_error_child"));
    }

    #[test]
    fn adopt_root_parents_under_the_remote_span() {
        let _g = ring_lock();
        let remote = TraceContext { trace_id: new_trace_id(), span_id: 0xDEAD_BEEF };
        {
            let _root = adopt_root(remote, "test.adopted_op", params(1.0));
            assert_eq!(current_context().unwrap().trace_id, remote.trace_id);
        }
        let spans = spans_of(remote.trace_id);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].parent_id, 0xDEAD_BEEF);
        // Parent lives in another process: locally this is still a
        // single-root, complete tree.
        let trees = assemble();
        let t = trees.iter().find(|t| t.trace_id == remote.trace_id).unwrap();
        assert!(t.complete);
        assert_eq!(t.op, "test.adopted_op");
    }

    #[test]
    fn nested_root_guard_degrades_to_a_child_span() {
        let _g = ring_lock();
        let tid;
        {
            let _outer = start_root("test.outer_op", params(1.0));
            tid = current_context().unwrap().trace_id;
            {
                let _inner = start_root("test.inner_op", params(1.0));
                // The outer root still owns the thread's context.
                assert_eq!(current_context().unwrap().trace_id, tid);
            }
        }
        let spans = spans_of(tid);
        assert_eq!(spans.len(), 2);
        let outer = spans.iter().find(|s| s.name == "test.outer_op").unwrap();
        let inner = spans.iter().find(|s| s.name == "test.inner_op").unwrap();
        assert_eq!(inner.parent_id, outer.span_id);
    }

    #[test]
    fn hop_spans_carry_the_remote_duration() {
        let _g = ring_lock();
        let tid;
        {
            let _root = start_root("test.hop_op", params(1.0));
            tid = current_context().unwrap().trace_id;
            record_hop("hop 10.0.0.1:9000", 0x77, Duration::from_micros(900), 650_000);
        }
        let spans = spans_of(tid);
        let hop = spans.iter().find(|s| s.name.starts_with("hop ")).unwrap();
        assert_eq!(hop.span_id, 0x77, "hop reuses the far side's span id");
        assert_eq!(hop.remote_ns, 650_000);
        assert!(hop.dur_ns >= hop.remote_ns, "client side includes the wire");
        let json = traces_json(Some("test.hop_op"), 0, 10);
        let trace = json.get("traces").unwrap().as_arr().unwrap()[0].clone();
        let spans = trace.get("spans").unwrap().as_arr().unwrap();
        assert!(spans
            .iter()
            .any(|s| s.get("server_dur_ns").and_then(|v| v.as_u64()) == Some(650_000)));
    }

    #[test]
    fn wraparound_keeps_reported_trees_self_consistent() {
        let _g = ring_lock();
        // Overfill the ring several times over with small sampled
        // traces, then check every reported tree: span parents are
        // either 0, in-tree, or the tree is flagged incomplete.
        for i in 0..(RING_CAPACITY + 200) {
            let _root = start_root("test.wrap_op", params(1.0));
            if i % 3 == 0 {
                record_child("test.wrap_child", Duration::from_nanos(100));
            }
        }
        for tree in assemble() {
            let ids: std::collections::BTreeSet<u64> =
                tree.spans.iter().map(|s| s.span_id).collect();
            let orphans = tree
                .spans
                .iter()
                .filter(|s| s.parent_id != 0 && !ids.contains(&s.parent_id))
                .count();
            if tree.complete {
                assert!(orphans <= 1, "complete tree has at most the adopted root orphan");
            }
            assert!(!tree.spans.is_empty());
        }
        // The ring holds at most RING_CAPACITY spans.
        assert!(snapshot().len() <= RING_CAPACITY);
    }

    #[test]
    fn ring_is_readable_under_concurrent_writes() {
        let _g = ring_lock();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let _root =
                            start_root(&format!("test.concurrent_{w}"), params(1.0));
                        n += 1;
                        if n > 20_000 {
                            break;
                        }
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            for rec in snapshot() {
                // A torn slot would show as garbage; stable reads must
                // carry the invariants every writer maintains.
                assert_ne!(rec.trace_id, 0);
                assert_ne!(rec.span_id, 0);
            }
        }
        stop.store(true, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
    }

    #[test]
    fn slowest_json_orders_by_duration() {
        let _g = ring_lock();
        let v = slowest_json(5);
        let traces = v.get("traces").unwrap().as_arr().unwrap();
        let durs: Vec<u64> = traces
            .iter()
            .map(|t| t.get("duration_ns").unwrap().as_u64().unwrap())
            .collect();
        for pair in durs.windows(2) {
            assert!(pair[0] >= pair[1], "slowest first: {durs:?}");
        }
    }
}
