//! Lock-free metric primitives: counters, gauges, and log-scale
//! latency histograms.
//!
//! Everything here is a thin shell over `AtomicU64` so the hot paths
//! (engine submit, per-request serving) can record without taking a
//! lock. Histograms use a fixed log-linear bucket layout (4 sub-buckets
//! per power of two, ≤ 25 % relative width) so two histograms recorded
//! on different threads — or different processes, once serialized —
//! merge *exactly*: merging is element-wise bucket addition, never an
//! approximation.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// New counter at zero.
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value (fill ratio, in-flight
/// requests, estimated FP rate). Stored as `f64` bits in an atomic so
/// readers never see a torn value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// New gauge at zero.
    pub fn new() -> Self {
        Self(AtomicU64::new(0f64.to_bits()))
    }

    /// Overwrite the value.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Add `delta` (may be negative — used for in-flight tracking).
    #[inline]
    pub fn add(&self, delta: f64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some((f64::from_bits(bits) + delta).to_bits())
            });
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Sub-bucket resolution: each power of two is split into
/// `2^SUB_BITS = 4` linear sub-buckets, bounding the relative error of
/// any reconstructed quantile at `1/4 = 25 %` (in practice ~12 % at the
/// bucket midpoint).
const SUB_BITS: u32 = 2;
const SUBS: usize = 1 << SUB_BITS; // 4

/// Number of buckets needed to cover the full `u64` range of
/// nanosecond values: 4 small linear buckets (values 0–3) plus 4
/// sub-buckets for each of the 62 remaining octaves.
pub const NUM_BUCKETS: usize = SUBS + (63 - SUB_BITS as usize + 1) * SUBS; // 252

/// Map a recorded value (nanoseconds) to its bucket index.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros();
    let sub = ((v >> (exp - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    (exp as usize - 1) * SUBS + sub
}

/// Inclusive lower bound of bucket `i` in nanoseconds.
pub fn bucket_floor(i: usize) -> u64 {
    if i < SUBS {
        return i as u64;
    }
    let exp = (i / SUBS + 1) as u32;
    let sub = (i % SUBS) as u64;
    (1u64 << exp) + (sub << (exp - SUB_BITS))
}

/// Exclusive upper bound of bucket `i` in nanoseconds (`u64::MAX` for
/// the last bucket).
pub fn bucket_ceil(i: usize) -> u64 {
    if i + 1 >= NUM_BUCKETS {
        u64::MAX
    } else {
        bucket_floor(i + 1)
    }
}

/// A fixed-bucket log-scale latency histogram.
///
/// Values are recorded in integer nanoseconds. The bucket layout is
/// identical for every histogram in the process (and across processes
/// of the same build), so [`Histogram::merge_from`] is exact: bucket
/// counts simply add. Quantiles are reconstructed by walking the
/// cumulative distribution and linearly interpolating inside the
/// target bucket; the log-linear layout bounds the relative error at
/// 25 %.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// New empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one value (nanoseconds).
    #[inline]
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record a [`std::time::Duration`].
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values in nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Raw bucket counts (index `i` covers
    /// `[bucket_floor(i), bucket_ceil(i))` nanoseconds).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Fold another histogram into this one. Exact: the bucket layout
    /// is shared, so counts add with no re-binning error.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns(), Ordering::Relaxed);
    }

    /// Reconstruct the `q`-quantile (`0.0 < q <= 1.0`) in nanoseconds.
    /// Returns 0 for an empty histogram. Linear interpolation inside
    /// the target bucket; error bounded by the 25 % bucket width.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                let lo = bucket_floor(i) as f64;
                let hi = bucket_ceil(i) as f64;
                let within = (rank - cum) as f64 / c as f64;
                return (lo + (hi - lo) * within) as u64;
            }
            cum += c;
        }
        bucket_ceil(NUM_BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_contiguous_and_monotone() {
        // Every value maps into a bucket whose [floor, ceil) contains it,
        // and floors strictly increase.
        let probes = [
            0u64,
            1,
            2,
            3,
            4,
            5,
            7,
            8,
            9,
            15,
            16,
            17,
            1_000,
            1_000_000,
            1_000_000_000,
            u64::MAX / 2,
            u64::MAX,
        ];
        for &v in &probes {
            let i = bucket_index(v);
            assert!(bucket_floor(i) <= v, "floor({i}) <= {v}");
            assert!(v <= bucket_ceil(i) - (i + 1 != NUM_BUCKETS) as u64, "{v} < ceil({i})");
        }
        for i in 1..NUM_BUCKETS {
            assert!(bucket_floor(i) > bucket_floor(i - 1), "floors monotone at {i}");
            assert_eq!(bucket_ceil(i - 1), bucket_floor(i), "contiguous at {i}");
        }
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn quantiles_of_known_distribution() {
        // Uniform 1..=100_000 ns: p50 ≈ 50_000, p99 ≈ 99_000. The
        // log-linear layout bounds the error at 25 %.
        let h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100_000);
        let p50 = h.quantile_ns(0.50) as f64;
        let p99 = h.quantile_ns(0.99) as f64;
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.25, "p50={p50}");
        assert!((p99 - 99_000.0).abs() / 99_000.0 < 0.25, "p99={p99}");
        // Quantiles are monotone in q.
        assert!(h.quantile_ns(0.99) >= h.quantile_ns(0.90));
        assert!(h.quantile_ns(0.90) >= h.quantile_ns(0.50));
    }

    #[test]
    fn zero_sample_edge_cases() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_ns(), 0);
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.quantile_ns(0.99), 0);
        // A single zero-valued sample lands in bucket 0.
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile_ns(0.5), 0);
    }

    #[test]
    fn cross_thread_merge_is_exact() {
        // Two threads record disjoint halves into private histograms;
        // the merged histogram is bucket-identical to one that saw
        // every sample.
        let a = std::sync::Arc::new(Histogram::new());
        let b = std::sync::Arc::new(Histogram::new());
        let whole = Histogram::new();
        for v in 1..=10_000u64 {
            whole.record(v * 37);
        }
        let (a2, b2) = (a.clone(), b.clone());
        let ta = std::thread::spawn(move || {
            for v in 1..=5_000u64 {
                a2.record(v * 37);
            }
        });
        let tb = std::thread::spawn(move || {
            for v in 5_001..=10_000u64 {
                b2.record(v * 37);
            }
        });
        ta.join().unwrap();
        tb.join().unwrap();
        let merged = Histogram::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.sum_ns(), whole.sum_ns());
        assert_eq!(merged.bucket_counts(), whole.bucket_counts());
        assert_eq!(merged.quantile_ns(0.5), whole.quantile_ns(0.5));
        assert_eq!(merged.quantile_ns(0.99), whole.quantile_ns(0.99));
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(0.75);
        assert!((g.get() - 0.75).abs() < 1e-12);
        g.add(1.0);
        g.add(-0.5);
        assert!((g.get() - 1.25).abs() < 1e-12);
    }
}
