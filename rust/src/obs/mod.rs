//! Fleet-wide observability: lock-free metrics and span timing.
//!
//! The paper's claims are quantitative — false-positive rate as a
//! function of Bloom-filter fill (§ sizing analysis) and order-of-
//! magnitude runtime wins — so the running system has to be able to
//! report both. This module is the shared substrate every tier records
//! into:
//!
//! * [`metrics`] — atomic [`Counter`]s, [`Gauge`]s, and fixed-bucket
//!   log-scale [`Histogram`]s with exact cross-thread merging and
//!   p50/p90/p99 extraction;
//! * [`registry`] — the name → metric [`Registry`] with JSON and
//!   Prometheus text exposition;
//! * [`span`] — RAII timing guards that record into histograms, into
//!   the thread's active trace (when one is open), and, at
//!   `LSHBLOOM_LOG=trace`, emit timed trace lines through
//!   [`crate::logging`];
//! * [`http`] — the `--metrics-addr` listener: a minimal hand-rolled
//!   HTTP/1.1 responder (std-only, same discipline as the line
//!   protocol in `service/proto.rs`) serving `GET /metrics`
//!   (Prometheus text), `GET /metrics.json`, liveness/readiness
//!   probes (`/healthz`, `/readyz`), and the trace explorer
//!   (`/debug/traces`, `/debug/traces/slowest`);
//! * [`trace`] — distributed request tracing: 128-bit trace IDs
//!   propagated through the wire protocol's `trace` field and the
//!   `LSHBLOOM_TRACE_PARENT` env var, a lock-free ring of finished
//!   spans, and error/slow/probabilistic sampling.
//!
//! Instrumented tiers: engine submit phases, per-band filter
//! fill/estimated-FP gauges, persist checkpoint/restore walls, server
//! per-op request latency + in-flight gauge, router per-backend
//! fan-out latency + error counters, supervisor restart counters. The
//! same registry is exposed over the wire (`{"op":"metrics"}`), over
//! HTTP (`--metrics-addr`), and as periodic JSONL snapshots
//! (`dedup --metrics-out`).
//!
//! ```
//! use lshbloom::obs;
//!
//! {
//!     let _timer = obs::span("example.work");
//! } // records into histogram "example.work.seconds" on drop
//! let h = obs::global().histogram("example.work.seconds");
//! assert_eq!(h.count(), 1);
//! ```
#![warn(missing_docs)]

pub mod http;
pub mod metrics;
pub mod registry;
pub mod trace;

pub use http::MetricsHttp;
pub use metrics::{Counter, Gauge, Histogram};
pub use registry::Registry;
pub use trace::{TraceContext, TraceParams};

use std::sync::OnceLock;
use std::time::Instant;

/// The process-global registry every instrumented tier records into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Process-start anchor for `uptime_seconds`. Lazily initialized on
/// first observability touch; long-lived processes (serve, route,
/// dedup) call [`init`] at startup so the anchor matches process start.
fn process_start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Anchor the uptime clock. Idempotent; call once at process startup.
pub fn init() {
    process_start();
}

/// Seconds since [`init`] (or since the first metric was touched).
pub fn uptime_seconds() -> f64 {
    process_start().elapsed().as_secs_f64()
}

/// An RAII span-timing guard returned by [`span`].
///
/// On drop it records the elapsed wall time into the global histogram
/// `<name>.seconds` and, when the logger is at trace level, emits a
/// `span <name> … ms` line — so `LSHBLOOM_LOG=trace` turns any
/// instrumented binary into a per-hop timing trace at zero cost to
/// non-trace runs beyond the histogram update.
#[must_use = "a span records when dropped; binding it to _ drops immediately"]
pub struct Span {
    name: &'static str,
    start: Instant,
}

/// Start timing the named operation; the returned guard records on
/// drop. Names are dotted (`"router.fan_out"`) and land in the global
/// registry as `<name>.seconds`.
pub fn span(name: &'static str) -> Span {
    Span { name, start: Instant::now() }
}

impl Drop for Span {
    fn drop(&mut self) {
        let elapsed = self.start.elapsed();
        global().histogram(&format!("{}.seconds", self.name)).record_duration(elapsed);
        // If this thread has an active trace, the same measurement
        // becomes a child span of it (no-op otherwise).
        trace::record_child(self.name, elapsed);
        if crate::logging::enabled(crate::logging::Level::Trace) {
            crate::log_trace!("span {} {:.3}ms", self.name, elapsed.as_secs_f64() * 1e3);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_into_global_histogram() {
        let h = global().histogram("obs.test_span.seconds");
        let before = h.count();
        {
            let _s = span("obs.test_span");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(h.count(), before + 1);
        // 2 ms sleep must land at ≥ 2 ms even at the bucket floor.
        assert!(h.sum_ns() >= 2_000_000, "sum_ns={}", h.sum_ns());
    }

    #[test]
    fn uptime_is_monotone() {
        init();
        let a = uptime_seconds();
        let b = uptime_seconds();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
