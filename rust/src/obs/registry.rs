//! The metric registry: named counters, gauges, and histograms with
//! JSON and Prometheus text exposition.
//!
//! Registration (name → metric lookup) takes a short `RwLock`; the
//! returned `Arc` handles are lock-free to record into, so hot paths
//! either cache a handle or pay one brief shared read-lock per lookup
//! — never an exclusive lock after the first registration.
//!
//! ## Naming convention
//!
//! Internal names are dotted (`server.request.seconds`) with optional
//! Prometheus-style labels appended verbatim
//! (`engine.band_fill_ratio{band="3"}`). Exposition sanitizes the base
//! name (`.` → `_`), prefixes `lshbloom_`, and passes labels through,
//! so the example above scrapes as
//! `lshbloom_engine_band_fill_ratio{band="3"}`. By convention counters
//! end in `.total` and duration histograms in `.seconds` (values are
//! recorded in nanoseconds and converted at exposition).

use super::metrics::{bucket_ceil, Counter, Gauge, Histogram, NUM_BUCKETS};
use crate::json::{obj, Value};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// A process-wide (or test-local) collection of named metrics.
#[derive(Debug, Default)]
pub struct Registry {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

/// Get-or-create in one of the registry maps: a shared read-lock on
/// the hit path, an exclusive lock only the first time a name is seen.
fn intern<T: Default>(map: &RwLock<BTreeMap<String, Arc<T>>>, name: &str) -> Arc<T> {
    if let Some(m) = map.read().expect("metric registry poisoned").get(name) {
        return m.clone();
    }
    map.write()
        .expect("metric registry poisoned")
        .entry(name.to_string())
        .or_default()
        .clone()
}

impl Registry {
    /// New empty registry (tests; production code uses
    /// [`crate::obs::global`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        intern(&self.counters, name)
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        intern(&self.gauges, name)
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        intern(&self.histograms, name)
    }

    /// Render the full registry as a JSON object:
    ///
    /// ```json
    /// {"uptime_seconds": 12.3, "version": "0.6.0",
    ///  "counters": {"server.requests.total": 41},
    ///  "gauges": {"engine.band_fill_ratio{band=\"0\"}": 0.013},
    ///  "histograms": {"server.request.seconds":
    ///     {"count": 41, "sum_ns": 90210,
    ///      "p50_ns": 1800, "p90_ns": 2600, "p99_ns": 4100}}}
    /// ```
    pub fn to_json(&self) -> Value {
        let counters = self
            .counters
            .read()
            .expect("metric registry poisoned")
            .iter()
            .map(|(k, c)| (k.clone(), Value::u64(c.get())))
            .collect::<BTreeMap<_, _>>();
        let gauges = self
            .gauges
            .read()
            .expect("metric registry poisoned")
            .iter()
            .map(|(k, g)| (k.clone(), Value::num(g.get())))
            .collect::<BTreeMap<_, _>>();
        let histograms = self
            .histograms
            .read()
            .expect("metric registry poisoned")
            .iter()
            .map(|(k, h)| {
                let summary = obj(vec![
                    ("count", Value::u64(h.count())),
                    ("sum_ns", Value::u64(h.sum_ns())),
                    ("p50_ns", Value::u64(h.quantile_ns(0.50))),
                    ("p90_ns", Value::u64(h.quantile_ns(0.90))),
                    ("p99_ns", Value::u64(h.quantile_ns(0.99))),
                ]);
                (k.clone(), summary)
            })
            .collect::<BTreeMap<_, _>>();
        obj(vec![
            ("uptime_seconds", Value::num(super::uptime_seconds())),
            ("version", Value::str(env!("CARGO_PKG_VERSION"))),
            ("counters", Value::Obj(counters)),
            ("gauges", Value::Obj(gauges)),
            ("histograms", Value::Obj(histograms)),
        ])
    }

    /// One JSONL snapshot line (`--metrics-out`): the [`Registry::to_json`]
    /// object plus a monotone `seq` so offline tooling can order and
    /// diff successive snapshots.
    pub fn snapshot_line(&self, seq: u64) -> String {
        let mut v = self.to_json();
        if let Value::Obj(map) = &mut v {
            map.insert("seq".to_string(), Value::u64(seq));
        }
        v.to_json()
    }

    /// Render the registry in Prometheus text exposition format
    /// (version 0.0.4). Histograms emit cumulative `_bucket{le="…"}`
    /// series (only buckets that hold samples, plus `+Inf` — the
    /// cumulative encoding stays exact), `_sum`, and `_count`, with
    /// nanosecond internals converted to seconds.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_type_base = String::new();
        let mut type_line = |out: &mut String, base: &str, kind: &str| {
            if last_type_base != base {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                last_type_base = base.to_string();
            }
        };
        for (name, c) in self.counters.read().expect("metric registry poisoned").iter() {
            let (base, labels) = split_labels(name);
            type_line(&mut out, &base, "counter");
            out.push_str(&format!("{base}{labels} {}\n", c.get()));
        }
        for (name, g) in self.gauges.read().expect("metric registry poisoned").iter() {
            let (base, labels) = split_labels(name);
            type_line(&mut out, &base, "gauge");
            out.push_str(&format!("{base}{labels} {}\n", g.get()));
        }
        for (name, h) in self.histograms.read().expect("metric registry poisoned").iter() {
            let (base, labels) = split_labels(name);
            type_line(&mut out, &base, "histogram");
            let inner = labels.trim_start_matches('{').trim_end_matches('}');
            let sep = if inner.is_empty() { "" } else { "," };
            let mut cum = 0u64;
            for (i, n) in h.bucket_counts().into_iter().enumerate() {
                if n == 0 {
                    continue;
                }
                cum += n;
                if i + 1 >= NUM_BUCKETS {
                    // The top bucket (≈585 years) has no finite upper
                    // bound; its samples surface via the +Inf series.
                    continue;
                }
                let le = le_seconds(i);
                out.push_str(&format!("{base}_bucket{{{inner}{sep}le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{base}_bucket{{{inner}{sep}le=\"+Inf\"}} {cum}\n"));
            out.push_str(&format!("{base}_sum{labels} {}\n", h.sum_ns() as f64 / 1e9));
            out.push_str(&format!("{base}_count{labels} {}\n", h.count()));
        }
        out
    }
}

/// Upper bound of histogram bucket `i` as seconds, for `le` labels.
fn le_seconds(i: usize) -> f64 {
    if i + 1 >= NUM_BUCKETS {
        f64::INFINITY
    } else {
        bucket_ceil(i) as f64 / 1e9
    }
}

/// Split an internal metric name into its sanitized, `lshbloom_`-prefixed
/// Prometheus base name and the pass-through label block (`{…}` or "").
fn split_labels(name: &str) -> (String, &str) {
    let (base, labels) = match name.find('{') {
        Some(pos) => (&name[..pos], &name[pos..]),
        None => (name, ""),
    };
    let mut sanitized = String::with_capacity(base.len() + 9);
    sanitized.push_str("lshbloom_");
    for ch in base.chars() {
        if ch.is_ascii_alphanumeric() || ch == '_' || ch == ':' {
            sanitized.push(ch);
        } else {
            sanitized.push('_');
        }
    }
    (sanitized, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn handles_are_shared_by_name() {
        let r = Registry::new();
        r.counter("a.total").add(2);
        r.counter("a.total").add(3);
        assert_eq!(r.counter("a.total").get(), 5);
        r.gauge("g").set(1.5);
        assert!((r.gauge("g").get() - 1.5).abs() < 1e-12);
        r.histogram("h.seconds").record(10);
        assert_eq!(r.histogram("h.seconds").count(), 1);
    }

    #[test]
    fn json_roundtrips_through_crate_parser() {
        let r = Registry::new();
        r.counter("server.requests.total").add(7);
        r.gauge("engine.band_fill_ratio{band=\"0\"}").set(0.25);
        r.histogram("server.request.seconds").record(1_000_000);
        let parsed = json::parse(&r.snapshot_line(3)).unwrap();
        assert_eq!(parsed.get("seq").and_then(|v| v.as_u64()), Some(3));
        assert_eq!(
            parsed
                .get("counters")
                .and_then(|c| c.get("server.requests.total"))
                .and_then(|v| v.as_u64()),
            Some(7)
        );
        let h = parsed.get("histograms").and_then(|h| h.get("server.request.seconds")).unwrap();
        assert_eq!(h.get("count").and_then(|v| v.as_u64()), Some(1));
        assert!(parsed.get("uptime_seconds").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        assert_eq!(
            parsed.get("version").and_then(|v| v.as_str()),
            Some(env!("CARGO_PKG_VERSION"))
        );
    }

    #[test]
    fn prometheus_text_shape() {
        let r = Registry::new();
        r.counter("server.requests.total").add(4);
        r.gauge("engine.band_fill_ratio{band=\"2\"}").set(0.5);
        let h = r.histogram("server.request.seconds");
        h.record(1_000);
        h.record(2_000);
        h.record(4_000_000);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE lshbloom_server_requests_total counter"), "{text}");
        assert!(text.contains("lshbloom_server_requests_total 4"), "{text}");
        assert!(
            text.contains("lshbloom_engine_band_fill_ratio{band=\"2\"} 0.5"),
            "{text}"
        );
        assert!(text.contains("# TYPE lshbloom_server_request_seconds histogram"), "{text}");
        assert!(text.contains("lshbloom_server_request_seconds_count 3"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 3"), "{text}");
        // Cumulative bucket counts are nondecreasing and end at count.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
        assert_eq!(last, 3);
    }

    #[test]
    fn histogram_labels_merge_with_le() {
        let r = Registry::new();
        r.histogram("router.backend.seconds{backend=\"127.0.0.1:9\"}").record(500);
        let text = r.to_prometheus();
        assert!(
            text.contains("lshbloom_router_backend_seconds_bucket{backend=\"127.0.0.1:9\",le="),
            "{text}"
        );
        assert!(
            text.contains("lshbloom_router_backend_seconds_count{backend=\"127.0.0.1:9\"} 1"),
            "{text}"
        );
    }
}
