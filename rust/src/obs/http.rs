//! The `--metrics-addr` endpoint: a minimal hand-rolled HTTP/1.1
//! responder serving the global registry and the trace ring.
//!
//! Std-only, same discipline as the line protocol in
//! `service/proto.rs`: no HTTP library, bounded reads, one response
//! per connection (`Connection: close`). Routes:
//!
//! * `GET /metrics` (or `/`) — Prometheus text exposition 0.0.4
//! * `GET /metrics.json` — the registry as JSON (same shape as the
//!   `{"op":"metrics"}` wire op)
//! * `GET /healthz` — liveness: `200 ok` whenever the process can
//!   answer at all
//! * `GET /readyz` — readiness: `200 ready` or `503 not ready` from
//!   the listener's [`ReadyHook`] (a router is ready only while its
//!   backend fleet is reachable; no hook means always ready)
//! * `GET /debug/traces` — recent traces from the
//!   [`super::trace`] ring as JSON; query parameters `op=<root op>`,
//!   `min_ms=<n>` (root duration floor), `limit=<n>` (default 64)
//! * `GET /debug/traces/slowest` — the slowest traces by root
//!   duration; `limit=<n>` (default 16)
//!
//! Scrapes are cheap (atomic loads + one string render), so requests
//! are handled inline on the listener thread — a scrape endpoint does
//! not need a connection pool.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Largest request head (request line + headers) accepted before the
/// connection is dropped — a scrape request is a few hundred bytes.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Hook run before rendering a scrape so on-demand gauges (per-band
/// fill ratios, estimated FP) reflect the current filter state.
pub type RefreshHook = Box<dyn Fn() + Send + Sync>;

/// Readiness probe backing `GET /readyz`: `true` = ready. Liveness
/// (`/healthz`) is unconditional — a process that can answer is live.
pub type ReadyHook = Box<dyn Fn() -> bool + Send + Sync>;

/// A running metrics HTTP listener (see module docs).
pub struct MetricsHttp {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for MetricsHttp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsHttp").field("addr", &self.addr).finish()
    }
}

impl MetricsHttp {
    /// Bind `addr` (`HOST:PORT`, port 0 for ephemeral) and start the
    /// listener thread. `refresh` (if any) runs before every scrape;
    /// `ready` (if any) answers `/readyz`.
    ///
    /// Both hooks are owned by the listener thread and dropped only
    /// when it exits — see [`MetricsHttp::stop`] for the ordering
    /// contract that makes capturing `Arc`s of caller state safe.
    pub fn bind(
        addr: &str,
        refresh: Option<RefreshHook>,
        ready: Option<ReadyHook>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("metrics-http".to_string())
            .spawn(move || listen_loop(listener, flag, refresh, ready))
            .expect("spawn metrics-http thread");
        crate::log_info!("metrics endpoint listening on http://{local}/metrics");
        Ok(Self { addr: local, shutdown, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and wait for it to exit.
    ///
    /// Ordering contract: the shutdown flag is raised and the accept
    /// thread is *joined* before this returns. The refresh/ready hooks
    /// live inside that thread, so any state they borrow (via captured
    /// `Arc`s) cannot be observed mid-teardown: once `stop` (or the
    /// `Drop` that routes through it) returns, the hooks have run for
    /// the last time and have been dropped. A scrape in flight at stop
    /// time is served to completion first.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.stop();
    }
}

fn listen_loop(
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    refresh: Option<RefreshHook>,
    ready: Option<ReadyHook>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            // Exiting here drops `listener`, `refresh`, and `ready`:
            // the hooks outlive every scrape that could call them.
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => handle_scrape(stream, refresh.as_deref(), ready.as_deref()),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => {
                crate::log_warn!("metrics listener accept error: {e}");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// First value of `key` in an `a=1&b=2` query string, if any.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

fn parsed_param<T: std::str::FromStr>(query: &str, key: &str, default: T) -> T {
    query_param(query, key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Read the request head (bounded), pick a route, write one response.
fn handle_scrape(
    mut stream: TcpStream,
    refresh: Option<&(dyn Fn() + Send + Sync)>,
    ready: Option<&(dyn Fn() -> bool + Send + Sync)>,
) {
    stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    let complete = loop {
        match stream.read(&mut buf) {
            Ok(0) => break false,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.windows(2).any(|w| w == b"\n\n")
                {
                    break true;
                }
                if head.len() > MAX_REQUEST_BYTES {
                    break false;
                }
            }
            Err(_) => break false,
        }
    };
    if !complete {
        return;
    }
    let request_line = std::str::from_utf8(&head)
        .ok()
        .and_then(|s| s.lines().next())
        .unwrap_or("")
        .to_string();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    const TEXT: &str = "text/plain; charset=utf-8";
    const JSON: &str = "application/json";
    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", TEXT, "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" | "/" => {
                if let Some(r) = refresh {
                    r();
                }
                (
                    "200 OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    super::global().to_prometheus(),
                )
            }
            "/metrics.json" => {
                if let Some(r) = refresh {
                    r();
                }
                ("200 OK", JSON, super::global().to_json().to_json() + "\n")
            }
            "/healthz" => ("200 OK", TEXT, "ok\n".to_string()),
            "/readyz" => {
                if ready.is_none_or(|r| r()) {
                    ("200 OK", TEXT, "ready\n".to_string())
                } else {
                    ("503 Service Unavailable", TEXT, "not ready\n".to_string())
                }
            }
            "/debug/traces" => {
                let op = query_param(query, "op");
                let min_ms: u64 = parsed_param(query, "min_ms", 0);
                let limit: usize = parsed_param(query, "limit", 64);
                let dump = super::trace::traces_json(op, min_ms.saturating_mul(1_000_000), limit);
                ("200 OK", JSON, dump.to_json() + "\n")
            }
            "/debug/traces/slowest" => {
                let limit: usize = parsed_param(query, "limit", 16);
                ("200 OK", JSON, super::trace::slowest_json(limit).to_json() + "\n")
            }
            _ => ("404 Not Found", TEXT, "not found\n".to_string()),
        }
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut reader = BufReader::new(stream);
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        let mut line = String::new();
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line.trim().is_empty() {
                break;
            }
        }
        let mut body = String::new();
        reader.read_to_string(&mut body).unwrap();
        (status.trim().to_string(), body)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP listener
    fn scrape_routes_and_refresh_hook() {
        let hits = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let h = hits.clone();
        let refresh: RefreshHook = Box::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        crate::obs::global().counter("obs.http_test.total").add(9);
        let mut server = MetricsHttp::bind("127.0.0.1:0", Some(refresh), None).unwrap();
        let addr = server.local_addr();

        let (status, body) = http_get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");
        assert!(body.contains("lshbloom_obs_http_test_total 9"), "{body}");

        let (status, body) = http_get(addr, "/metrics.json");
        assert!(status.contains("200"), "{status}");
        let parsed = crate::json::parse(body.trim()).unwrap();
        assert_eq!(
            parsed
                .get("counters")
                .and_then(|c| c.get("obs.http_test.total"))
                .and_then(|v| v.as_u64()),
            Some(9)
        );

        let (status, _) = http_get(addr, "/nope");
        assert!(status.contains("404"), "{status}");
        assert_eq!(hits.load(Ordering::SeqCst), 2, "refresh runs per scrape, not per 404");

        server.stop();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP listener
    fn health_and_readiness_probes() {
        let ready_flag = Arc::new(AtomicBool::new(false));
        let rf = ready_flag.clone();
        let ready: ReadyHook = Box::new(move || rf.load(Ordering::SeqCst));
        let mut server = MetricsHttp::bind("127.0.0.1:0", None, Some(ready)).unwrap();
        let addr = server.local_addr();

        // Liveness is unconditional; readiness follows the hook.
        let (status, body) = http_get(addr, "/healthz");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body, "ok\n");
        let (status, body) = http_get(addr, "/readyz");
        assert!(status.contains("503"), "{status}");
        assert_eq!(body, "not ready\n");
        ready_flag.store(true, Ordering::SeqCst);
        let (status, body) = http_get(addr, "/readyz");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body, "ready\n");

        // Without a hook, a bound listener is simply ready.
        let mut plain = MetricsHttp::bind("127.0.0.1:0", None, None).unwrap();
        let (status, _) = http_get(plain.local_addr(), "/readyz");
        assert!(status.contains("200"), "{status}");
        plain.stop();
        server.stop();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP listener
    fn debug_traces_routes_serve_the_ring() {
        use crate::obs::trace;
        let _g = trace::test_ring_lock();
        {
            let _root = trace::start_root(
                "obs.http_trace_route_op",
                trace::TraceParams { sample: 1.0, slow_ms: 0 },
            );
            trace::record_child("obs.http_trace_child", Duration::from_micros(40));
        }
        let mut server = MetricsHttp::bind("127.0.0.1:0", None, None).unwrap();
        let addr = server.local_addr();

        let (status, body) = http_get(addr, "/debug/traces?op=obs.http_trace_route_op");
        assert!(status.contains("200"), "{status}");
        let parsed = crate::json::parse(body.trim()).unwrap();
        let traces = parsed.get("traces").unwrap().as_arr().unwrap();
        assert!(!traces.is_empty(), "filtered trace must be present: {body}");
        let spans = traces[0].get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2, "root + child: {body}");

        // A min-duration floor far above anything recorded here.
        let (_, body) = http_get(addr, "/debug/traces?op=obs.http_trace_route_op&min_ms=600000");
        let parsed = crate::json::parse(body.trim()).unwrap();
        assert!(parsed.get("traces").unwrap().as_arr().unwrap().is_empty(), "{body}");

        let (status, body) = http_get(addr, "/debug/traces/slowest?limit=3");
        assert!(status.contains("200"), "{status}");
        let parsed = crate::json::parse(body.trim()).unwrap();
        assert!(parsed.get("traces").unwrap().as_arr().unwrap().len() <= 3, "{body}");
        server.stop();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP listener
    fn drop_under_load_joins_accept_thread_before_hook_teardown() {
        // Regression: stop()/Drop must join the accept thread *before*
        // the caller proceeds to tear down state the hooks borrow. The
        // hooks observe `alive`; the owner flips it to false only
        // after drop returns — any hook run after that is a violation.
        let alive = Arc::new(AtomicBool::new(true));
        let violated = Arc::new(AtomicBool::new(false));
        let (a, v) = (alive.clone(), violated.clone());
        let refresh: RefreshHook = Box::new(move || {
            if !a.load(Ordering::SeqCst) {
                v.store(true, Ordering::SeqCst);
            }
        });
        let (a, v) = (alive.clone(), violated.clone());
        let ready: ReadyHook = Box::new(move || {
            if !a.load(Ordering::SeqCst) {
                v.store(true, Ordering::SeqCst);
            }
            true
        });
        let server = MetricsHttp::bind("127.0.0.1:0", Some(refresh), Some(ready)).unwrap();
        let addr = server.local_addr();
        let (status, _) = http_get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");

        // Hammer both hook-bearing routes from several threads while
        // the server drops out from under them.
        let hammers: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || loop {
                    let Ok(mut s) = TcpStream::connect(addr) else { break };
                    s.set_read_timeout(Some(Duration::from_millis(500))).ok();
                    s.set_write_timeout(Some(Duration::from_millis(500))).ok();
                    let path = if i % 2 == 0 { "/metrics" } else { "/readyz" };
                    let req = format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n");
                    if s.write_all(req.as_bytes()).is_err() {
                        break;
                    }
                    let mut sink = Vec::new();
                    let _ = s.read_to_end(&mut sink);
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        drop(server); // Drop routes through stop(): flag, then join.
        alive.store(false, Ordering::SeqCst); // "teardown" happens after.
        for h in hammers {
            h.join().unwrap();
        }
        assert!(!violated.load(Ordering::SeqCst), "a hook ran after drop returned");
    }
}
