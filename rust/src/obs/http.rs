//! The `--metrics-addr` endpoint: a minimal hand-rolled HTTP/1.1
//! responder serving the global registry.
//!
//! Std-only, same discipline as the line protocol in
//! `service/proto.rs`: no HTTP library, bounded reads, one response
//! per connection (`Connection: close`). Routes:
//!
//! * `GET /metrics` (or `/`) — Prometheus text exposition 0.0.4
//! * `GET /metrics.json` — the registry as JSON (same shape as the
//!   `{"op":"metrics"}` wire op)
//!
//! Scrapes are cheap (atomic loads + one string render), so requests
//! are handled inline on the listener thread — a scrape endpoint does
//! not need a connection pool.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Largest request head (request line + headers) accepted before the
/// connection is dropped — a scrape request is a few hundred bytes.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Hook run before rendering a scrape so on-demand gauges (per-band
/// fill ratios, estimated FP) reflect the current filter state.
pub type RefreshHook = Box<dyn Fn() + Send + Sync>;

/// A running metrics HTTP listener (see module docs).
pub struct MetricsHttp {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for MetricsHttp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsHttp").field("addr", &self.addr).finish()
    }
}

impl MetricsHttp {
    /// Bind `addr` (`HOST:PORT`, port 0 for ephemeral) and start the
    /// listener thread. `refresh` (if any) runs before every scrape.
    pub fn bind(addr: &str, refresh: Option<RefreshHook>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("metrics-http".to_string())
            .spawn(move || listen_loop(listener, flag, refresh))
            .expect("spawn metrics-http thread");
        crate::log_info!("metrics endpoint listening on http://{local}/metrics");
        Ok(Self { addr: local, shutdown, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and wait for it to exit.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.stop();
    }
}

fn listen_loop(listener: TcpListener, shutdown: Arc<AtomicBool>, refresh: Option<RefreshHook>) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => handle_scrape(stream, refresh.as_deref()),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => {
                crate::log_warn!("metrics listener accept error: {e}");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

/// Read the request head (bounded), pick a route, write one response.
fn handle_scrape(mut stream: TcpStream, refresh: Option<&(dyn Fn() + Send + Sync)>) {
    stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    let complete = loop {
        match stream.read(&mut buf) {
            Ok(0) => break false,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.windows(2).any(|w| w == b"\n\n")
                {
                    break true;
                }
                if head.len() > MAX_REQUEST_BYTES {
                    break false;
                }
            }
            Err(_) => break false,
        }
    };
    if !complete {
        return;
    }
    let request_line = std::str::from_utf8(&head)
        .ok()
        .and_then(|s| s.lines().next())
        .unwrap_or("")
        .to_string();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain; charset=utf-8", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" | "/" => {
                if let Some(r) = refresh {
                    r();
                }
                (
                    "200 OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    super::global().to_prometheus(),
                )
            }
            "/metrics.json" => {
                if let Some(r) = refresh {
                    r();
                }
                ("200 OK", "application/json", super::global().to_json().to_json() + "\n")
            }
            _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
        }
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut reader = BufReader::new(stream);
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        let mut line = String::new();
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line.trim().is_empty() {
                break;
            }
        }
        let mut body = String::new();
        reader.read_to_string(&mut body).unwrap();
        (status.trim().to_string(), body)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // binds a real TCP listener
    fn scrape_routes_and_refresh_hook() {
        let hits = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let h = hits.clone();
        let refresh: RefreshHook = Box::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        crate::obs::global().counter("obs.http_test.total").add(9);
        let mut server = MetricsHttp::bind("127.0.0.1:0", Some(refresh)).unwrap();
        let addr = server.local_addr();

        let (status, body) = http_get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");
        assert!(body.contains("lshbloom_obs_http_test_total 9"), "{body}");

        let (status, body) = http_get(addr, "/metrics.json");
        assert!(status.contains("200"), "{status}");
        let parsed = crate::json::parse(body.trim()).unwrap();
        assert_eq!(
            parsed
                .get("counters")
                .and_then(|c| c.get("obs.http_test.total"))
                .and_then(|v| v.as_u64()),
            Some(9)
        );

        let (status, _) = http_get(addr, "/nope");
        assert!(status.contains("404"), "{status}");
        assert_eq!(hits.load(Ordering::SeqCst), 2, "refresh runs per scrape, not per 404");

        server.stop();
    }
}
