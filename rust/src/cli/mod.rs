//! Declarative CLI argument parser (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean switches, defaults,
//! required flags, and generated `--help`. Each binary declares an
//! [`ArgSpec`] list and gets back a typed [`Args`] map.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath; the same flow is
//! // covered by this module's unit tests)
//! use lshbloom::cli::{ArgSpec, Command};
//! let cmd = Command::new("demo", "demo tool")
//!     .arg(ArgSpec::opt("docs", "number of documents").default("1000"))
//!     .arg(ArgSpec::switch("verbose", "chatty output"));
//! let args = cmd.parse_from(vec!["--docs".into(), "5".into()]).unwrap();
//! assert_eq!(args.get_usize("docs"), 5);
//! assert!(!args.get_bool("verbose"));
//! ```

use std::collections::BTreeMap;

/// Declaration of one flag.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub required: bool,
    pub is_switch: bool,
}

impl ArgSpec {
    /// Optional value flag (`--name value`).
    pub fn opt(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: false, is_switch: false }
    }

    /// Required value flag.
    pub fn req(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: true, is_switch: false }
    }

    /// Boolean switch (`--name`, default false).
    pub fn switch(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: false, is_switch: true }
    }

    /// Set a default value.
    pub fn default(mut self, v: &'static str) -> Self {
        self.default = Some(v);
        self
    }
}

/// A command: name, description, flags.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

/// CLI parse failure (message already user-formatted).
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Command {
    /// New command with no flags.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, specs: Vec::new() }
    }

    /// Add a flag.
    pub fn arg(mut self, spec: ArgSpec) -> Self {
        self.specs.push(spec);
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for spec in &self.specs {
            let kind = if spec.is_switch {
                String::new()
            } else if let Some(d) = spec.default {
                format!(" <value, default {d}>")
            } else if spec.required {
                " <value, required>".to_string()
            } else {
                " <value>".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, kind, spec.help));
        }
        s.push_str("  --help\n      print this message\n");
        s
    }

    /// Parse a raw token stream (excluding program/subcommand names).
    pub fn parse_from(&self, tokens: Vec<String>) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in &self.specs {
            if spec.is_switch {
                args.switches.insert(spec.name.to_string(), false);
            } else if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help()));
            }
            let Some(stripped) = tok.strip_prefix("--") else {
                return Err(CliError(format!(
                    "unexpected positional argument '{tok}' (see --help)"
                )));
            };
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            let Some(spec) = self.specs.iter().find(|s| s.name == name) else {
                return Err(CliError(format!("unknown flag '--{name}' (see --help)")));
            };
            if spec.is_switch {
                if let Some(v) = inline_val {
                    let b = match v.as_str() {
                        "true" | "1" => true,
                        "false" | "0" => false,
                        _ => return Err(CliError(format!("bad boolean for --{name}: {v}"))),
                    };
                    args.switches.insert(name, b);
                } else {
                    args.switches.insert(name, true);
                }
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => it
                        .next()
                        .ok_or_else(|| CliError(format!("flag --{name} needs a value")))?,
                };
                args.values.insert(name, val);
            }
        }
        for spec in &self.specs {
            if spec.required && !args.values.contains_key(spec.name) {
                return Err(CliError(format!("missing required flag --{}", spec.name)));
            }
        }
        Ok(args)
    }
}

impl Args {
    /// Raw string value (panics if the flag wasn't declared with a default
    /// and wasn't provided — use `get_opt` for truly optional values).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} has no value"))
    }

    /// Optional string value.
    pub fn get_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Switch state.
    pub fn get_bool(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// usize value with clear panic on malformed input.
    pub fn get_usize(&self, name: &str) -> usize {
        self.parse_or_exit(name)
    }

    /// u64 value.
    pub fn get_u64(&self, name: &str) -> u64 {
        self.parse_or_exit(name)
    }

    /// f64 value.
    pub fn get_f64(&self, name: &str) -> f64 {
        self.parse_or_exit(name)
    }

    fn parse_or_exit<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self.get(name);
        raw.parse().unwrap_or_else(|_| {
            eprintln!("invalid value for --{name}: '{raw}'");
            std::process::exit(2);
        })
    }

    /// Insert a value programmatically (tests, config overlay).
    pub fn set(&mut self, name: &str, value: impl Into<String>) {
        self.values.insert(name.to_string(), value.into());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .arg(ArgSpec::opt("n", "count").default("10"))
            .arg(ArgSpec::req("path", "input path"))
            .arg(ArgSpec::switch("fast", "go fast"))
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let args = cmd().parse_from(toks(&["--path", "/x"])).unwrap();
        assert_eq!(args.get_usize("n"), 10);
        assert_eq!(args.get("path"), "/x");
        assert!(!args.get_bool("fast"));
    }

    #[test]
    fn equals_syntax_and_switch() {
        let args = cmd().parse_from(toks(&["--path=/y", "--n=42", "--fast"])).unwrap();
        assert_eq!(args.get_usize("n"), 42);
        assert!(args.get_bool("fast"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cmd().parse_from(toks(&["--n", "5"])).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        let e = cmd().parse_from(toks(&["--path", "/x", "--bogus", "1"])).unwrap_err();
        assert!(e.0.contains("bogus"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse_from(toks(&["--path"])).is_err());
    }

    #[test]
    fn help_lists_flags() {
        let h = cmd().help();
        assert!(h.contains("--n"));
        assert!(h.contains("--path"));
        assert!(h.contains("--fast"));
    }

    #[test]
    fn switch_with_explicit_bool() {
        let args = cmd().parse_from(toks(&["--path", "/x", "--fast=false"])).unwrap();
        assert!(!args.get_bool("fast"));
        let args = cmd().parse_from(toks(&["--path", "/x", "--fast=1"])).unwrap();
        assert!(args.get_bool("fast"));
    }
}
