//! Thin, typed wrapper over the `xla` crate's PJRT CPU client.
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus a cache of compiled executables.
///
/// One engine is created per process; executables are cheap to call
/// repeatedly and internally thread-safe at the PJRT level, but we keep
/// usage single-threaded per executable (the ingest pipeline executes
/// batches from the sequential insert stage).
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

impl PjrtEngine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Name of the underlying PJRT platform (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact produced by `python/compile/aot.py` and
    /// compile it for this client.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<PjrtExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling HLO artifact {}", path.display()))?;
        Ok(PjrtExecutable { exe })
    }
}

/// A compiled HLO artifact ready to execute.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtExecutable {
    /// Execute with literal inputs; returns the elements of the output
    /// tuple (artifacts are lowered with `return_tuple=True`).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.execute_refs(&inputs.iter().collect::<Vec<_>>())
    }

    /// Execute with borrowed inputs (callers can cache constant literals,
    /// e.g. the permutation-seed vector, across batches).
    pub fn execute_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut result = self.exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // `return_tuple=True` always yields a tuple literal; decompose it.
        let parts = result.decompose_tuple()?;
        anyhow::ensure!(!parts.is_empty(), "expected non-empty tuple output");
        Ok(parts)
    }
}
