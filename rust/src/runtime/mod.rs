//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from rust.
//!
//! The python side (`python/compile/aot.py`) lowers the Layer-2 JAX model
//! (which calls the Layer-1 Pallas kernels) to **HLO text** once at build
//! time; this module loads that text, compiles it on the PJRT CPU client,
//! and exposes typed batch entry points used by the ingest pipeline.
//!
//! HLO *text* (not a serialized `HloModuleProto`) is the interchange format:
//! jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
pub mod minhash_xla;
mod pjrt;

pub use minhash_xla::{lshbloom_method_xla, XlaBandPreparer};
pub use pjrt::{PjrtEngine, PjrtExecutable};
