//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from rust.
//!
//! The python side (`python/compile/aot.py`) lowers the Layer-2 JAX model
//! (which calls the Layer-1 Pallas kernels) to **HLO text** once at build
//! time; this module loads that text, compiles it on the PJRT CPU client,
//! and exposes typed batch entry points used by the ingest pipeline.
//!
//! HLO *text* (not a serialized `HloModuleProto`) is the interchange format:
//! jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The whole backend is gated behind the **`xla` cargo feature** because
//! it links the external `xla` (PJRT) and `anyhow` crates, which are not
//! vendored in offline environments. Without the feature, the private
//! `stub` module (`src/runtime/stub.rs`)
//! provides the same public surface with constructors that return
//! [`crate::error::Error::Runtime`] — every caller already handles that
//! path (it is indistinguishable from "artifacts missing").

#[cfg(feature = "xla")]
pub mod minhash_xla;
#[cfg(feature = "xla")]
mod pjrt;

#[cfg(feature = "xla")]
pub use minhash_xla::{lshbloom_method_xla, XlaBandPreparer};
#[cfg(feature = "xla")]
pub use pjrt::{PjrtEngine, PjrtExecutable};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{lshbloom_method_xla, PjrtEngine, XlaBandPreparer};
