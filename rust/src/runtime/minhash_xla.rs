//! The XLA MinHash backend: batched signature/band computation through
//! the AOT artifacts (Layer 1+2 executed from rust via PJRT).
//!
//! Two artifacts are used (see `python/compile/aot.py`):
//! * `minhash_bands_*` — fused tokens→bands for documents whose shingle
//!   count fits the artifact's static L dimension (the common case).
//! * `minhash_sigs_*` — tokens→signatures for *longer* documents: the
//!   document is split into L-sized chunk rows, each chunk's signature is
//!   computed on-device, and the chunks are min-combined in rust (valid
//!   because `min` distributes over set union), then band-hashed with the
//!   same wrapping sum the kernel uses. Both paths are bit-identical to
//!   the native backend — `rust/tests/xla_backend.rs` enforces it.

use crate::corpus::Doc;
use crate::error::{Error, Result};
use crate::hash::band::band_hashes_for_doc;
use crate::json;
use crate::methods::{Prepared, Preparer};
use crate::minhash::{LshParams, MinHasher, PermFamily};
use crate::text::normalize;
use std::path::Path;
use std::sync::Mutex;

use super::pjrt::{PjrtEngine, PjrtExecutable};

/// Sentinel padding value (must match `kernels/common.py::PAD_SENTINEL`).
pub const PAD_SENTINEL: u64 = u64::MAX;

/// Geometry of a loaded artifact pair.
#[derive(Clone, Copy, Debug)]
pub struct ArtifactDims {
    pub batch: usize,
    pub max_tokens: usize,
    pub num_perms: usize,
    pub lsh: LshParams,
}

struct XlaState {
    // Note: PjRtClient is Rc-based; every Rc clone (client, executables)
    // lives inside this struct and is only touched while the Mutex in
    // `XlaBandPreparer` is held, so moving the whole struct across
    // threads is sound. Do NOT hand out clones of these fields.
    _engine: PjrtEngine,
    fused: PjrtExecutable,
    sigs: PjrtExecutable,
    /// Cached permutation-seed literal (constant across batches — §Perf).
    seeds_lit: xla::Literal,
}

/// A [`Preparer`] that computes band hashes through the XLA artifacts.
pub struct XlaBandPreparer {
    state: Mutex<XlaState>,
    dims: ArtifactDims,
    /// Shingling + seed derivation (and the long-doc band hashing) reuse
    /// the native mix64 machinery; signatures themselves come from XLA.
    hasher: MinHasher,
}

// SAFETY: all Rc-carrying XLA objects are owned exclusively by `state`
// and only accessed under its Mutex; no Rc clone escapes. The PJRT CPU
// client itself is thread-safe; the Rc refcounts are only manipulated
// from whichever thread holds the lock at that moment.
unsafe impl Send for XlaBandPreparer {}
// SAFETY: same argument as Send above — every path into the non-Sync
// `state` internals goes through the Mutex, so concurrent `&self` calls
// serialize on the lock and the Rc refcounts are never touched by two
// threads at once; the other fields (`dims`, `hasher`) are plain Sync
// data.
unsafe impl Sync for XlaBandPreparer {}

impl XlaBandPreparer {
    /// Load the artifact pair described by `manifest.json` in
    /// `artifacts_dir` whose config matches (threshold, num_perms).
    pub fn from_manifest(artifacts_dir: &Path, threshold: f64, num_perms: usize, ngram: usize) -> Result<Self> {
        let manifest_path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| Error::io(manifest_path.display().to_string(), e))?;
        let manifest =
            json::parse(&text).map_err(|e| Error::parse("manifest.json", e.to_string()))?;
        let configs = manifest
            .get("configs")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| Error::Format("manifest.json missing configs".into()))?;

        let mut fused_entry = None;
        let mut sigs_entry = None;
        for cfg in configs {
            let Some(arts) = cfg.get("artifacts").and_then(|a| a.as_arr()) else { continue };
            for a in arts {
                let kind = a.get("kind").and_then(|k| k.as_str()).unwrap_or("");
                let p = a.get("P").and_then(|v| v.as_usize()).unwrap_or(0);
                let t = a.get("threshold").and_then(|v| v.as_f64());
                match kind {
                    "minhash_bands" if p == num_perms && t == Some(threshold) => {
                        fused_entry = Some(a.clone());
                    }
                    "minhash_sigs" if p == num_perms => {
                        sigs_entry = Some(a.clone());
                    }
                    _ => {}
                }
            }
        }
        let fused_entry = fused_entry.ok_or_else(|| {
            Error::Config(format!(
                "no minhash_bands artifact for T={threshold} P={num_perms}; re-run `make artifacts`"
            ))
        })?;
        let sigs_entry = sigs_entry
            .ok_or_else(|| Error::Config(format!("no minhash_sigs artifact for P={num_perms}")))?;

        let dims = ArtifactDims {
            batch: fused_entry.get("B").and_then(|v| v.as_usize()).unwrap_or(0),
            max_tokens: fused_entry.get("L").and_then(|v| v.as_usize()).unwrap_or(0),
            num_perms,
            lsh: LshParams {
                num_bands: fused_entry.get("num_bands").and_then(|v| v.as_usize()).unwrap_or(0),
                rows_per_band: fused_entry
                    .get("rows_per_band")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0),
            },
        };
        if dims.batch == 0 || dims.max_tokens == 0 || dims.lsh.num_bands == 0 {
            return Err(Error::Format("manifest artifact has zero dims".into()));
        }
        // The manifest's (b, r) must agree with our own optimizer — both
        // sides implement the same procedure (DESIGN.md lock-step rule).
        let expect = crate::minhash::optimal_param(threshold, num_perms);
        if expect != dims.lsh {
            return Err(Error::Config(format!(
                "manifest (b,r)=({},{}) disagrees with rust optimizer ({},{})",
                dims.lsh.num_bands, dims.lsh.rows_per_band, expect.num_bands, expect.rows_per_band
            )));
        }

        let engine = PjrtEngine::cpu().map_err(|e| Error::Runtime(format!("{e:#}")))?;
        let load = |entry: &json::Value| -> Result<PjrtExecutable> {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| Error::Format("artifact entry missing file".into()))?;
            engine
                .load_hlo_text(artifacts_dir.join(file))
                .map_err(|e| Error::Runtime(format!("{e:#}")))
        };
        let fused = load(&fused_entry)?;
        let sigs = load(&sigs_entry)?;

        let hasher = MinHasher::new(PermFamily::Mix64, num_perms, ngram);
        let seeds_lit = xla::Literal::vec1(hasher.seeds())
            .reshape(&[num_perms as i64])
            .map_err(|e| Error::Runtime(format!("{e:#}")))?;
        Ok(Self {
            state: Mutex::new(XlaState { _engine: engine, fused, sigs, seeds_lit }),
            dims,
            hasher,
        })
    }

    /// Artifact geometry.
    pub fn dims(&self) -> ArtifactDims {
        self.dims
    }

    /// Fused path: `rows` of exactly B×L token hashes -> B×bands.
    fn run_fused(&self, tokens: &[u64]) -> Vec<u64> {
        debug_assert_eq!(tokens.len(), self.dims.batch * self.dims.max_tokens);
        let lit = xla::Literal::vec1(tokens)
            .reshape(&[self.dims.batch as i64, self.dims.max_tokens as i64])
            .expect("tokens reshape");
        let state = self.state.lock().unwrap();
        let out = state
            .fused
            .execute_refs(&[&lit, &state.seeds_lit])
            .expect("fused artifact execution failed");
        out[0].to_vec::<u64>().expect("fused output marshal")
    }

    /// Sigs path: B×L token rows -> B×P signatures.
    fn run_sigs(&self, tokens: &[u64]) -> Vec<u64> {
        debug_assert_eq!(tokens.len(), self.dims.batch * self.dims.max_tokens);
        let lit = xla::Literal::vec1(tokens)
            .reshape(&[self.dims.batch as i64, self.dims.max_tokens as i64])
            .expect("tokens reshape");
        let state = self.state.lock().unwrap();
        let out = state
            .sigs
            .execute_refs(&[&lit, &state.seeds_lit])
            .expect("sigs artifact execution failed");
        out[0].to_vec::<u64>().expect("sigs output marshal")
    }
}

impl Preparer for XlaBandPreparer {
    fn prepare_batch(&self, docs: &[Doc]) -> Vec<Prepared> {
        let (b_dim, l_dim) = (self.dims.batch, self.dims.max_tokens);
        let bands = self.dims.lsh;
        // Shingle outside the XLA lock (parallel-friendly).
        let shingles: Vec<Vec<u64>> = docs
            .iter()
            .map(|d| self.hasher.shingle_hashes(&normalize(&d.text)))
            .collect();

        let mut out: Vec<Option<Prepared>> = vec![None; docs.len()];
        let mut band_buf = Vec::with_capacity(bands.num_bands);

        // Short docs through the fused artifact, B at a time.
        let short_idx: Vec<usize> =
            (0..docs.len()).filter(|&i| shingles[i].len() <= l_dim).collect();
        for group in short_idx.chunks(b_dim) {
            let mut tokens = vec![PAD_SENTINEL; b_dim * l_dim];
            for (row, &i) in group.iter().enumerate() {
                tokens[row * l_dim..row * l_dim + shingles[i].len()]
                    .copy_from_slice(&shingles[i]);
            }
            let bands_out = self.run_fused(&tokens);
            for (row, &i) in group.iter().enumerate() {
                let start = row * bands.num_bands;
                out[i] = Some(Prepared::Bands(
                    bands_out[start..start + bands.num_bands].to_vec(),
                ));
            }
        }

        // Long docs: chunk rows through the sigs artifact, min-combine.
        let long_idx: Vec<usize> =
            (0..docs.len()).filter(|&i| shingles[i].len() > l_dim).collect();
        for &i in &long_idx {
            let hashes = &shingles[i];
            let mut sig = vec![u64::MAX; self.dims.num_perms];
            for chunk_group in hashes.chunks(l_dim).collect::<Vec<_>>().chunks(b_dim) {
                let mut tokens = vec![PAD_SENTINEL; b_dim * l_dim];
                for (row, chunk) in chunk_group.iter().enumerate() {
                    tokens[row * l_dim..row * l_dim + chunk.len()].copy_from_slice(chunk);
                }
                let sigs_out = self.run_sigs(&tokens);
                for row in 0..chunk_group.len() {
                    let start = row * self.dims.num_perms;
                    for (s, &v) in sig.iter_mut().zip(&sigs_out[start..start + self.dims.num_perms]) {
                        if v < *s {
                            *s = v;
                        }
                    }
                }
            }
            band_hashes_for_doc(&sig, bands.num_bands, bands.rows_per_band, &mut band_buf);
            out[i] = Some(Prepared::Bands(band_buf.clone()));
        }

        out.into_iter().map(|p| p.expect("every doc prepared")).collect()
    }
}

/// Build the full LSHBloom method with the XLA backend.
pub fn lshbloom_method_xla(cfg: &crate::config::PipelineConfig) -> Result<crate::methods::Method> {
    let preparer = XlaBandPreparer::from_manifest(
        Path::new(&cfg.artifacts_dir),
        cfg.threshold,
        cfg.num_perms,
        cfg.ngram,
    )?;
    let lsh = preparer.dims().lsh;
    Ok(crate::methods::Method {
        name: "lshbloom-xla".to_string(),
        preparer: std::sync::Arc::new(preparer),
        decider: Box::new(crate::methods::lshbloom::decider_from_config(cfg, lsh)),
    })
}
