//! No-op stand-ins for the PJRT/XLA backend when the `xla` cargo feature
//! is disabled (the default in offline builds — the external `xla` and
//! `anyhow` crates are unavailable there).
//!
//! Constructors fail with [`Error::Runtime`], which every call site
//! already treats as "XLA backend unavailable"; the instance methods are
//! unreachable because no value of these types can be constructed.

use crate::corpus::Doc;
use crate::error::{Error, Result};
use crate::methods::{Prepared, Preparer};
use std::path::Path;

fn unavailable() -> Error {
    Error::Runtime(
        "built without the `xla` cargo feature; rebuild with `--features xla` \
         (requires the xla PJRT crate and its C++ runtime)"
            .into(),
    )
}

/// Stub PJRT client; [`PjrtEngine::cpu`] always fails.
pub struct PjrtEngine {
    _private: (),
}

impl PjrtEngine {
    /// Always returns [`Error::Runtime`] in stub builds.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Unreachable: no `PjrtEngine` value can exist in stub builds.
    pub fn platform_name(&self) -> String {
        unreachable!("stub PjrtEngine cannot be constructed")
    }

    /// Unreachable: no `PjrtEngine` value can exist in stub builds.
    pub fn device_count(&self) -> usize {
        unreachable!("stub PjrtEngine cannot be constructed")
    }
}

/// Stub artifact preparer; [`XlaBandPreparer::from_manifest`] always fails.
pub struct XlaBandPreparer {
    _private: (),
}

impl XlaBandPreparer {
    /// Always returns [`Error::Runtime`] in stub builds.
    pub fn from_manifest(
        _artifacts_dir: &Path,
        _threshold: f64,
        _num_perms: usize,
        _ngram: usize,
    ) -> Result<Self> {
        Err(unavailable())
    }
}

impl Preparer for XlaBandPreparer {
    fn prepare_batch(&self, _docs: &[Doc]) -> Vec<Prepared> {
        unreachable!("stub XlaBandPreparer cannot be constructed")
    }
}

/// Always returns [`Error::Runtime`] in stub builds.
pub fn lshbloom_method_xla(_cfg: &crate::config::PipelineConfig) -> Result<crate::methods::Method> {
    Err(unavailable())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stubs_fail_with_runtime_error() {
        assert!(matches!(PjrtEngine::cpu(), Err(Error::Runtime(_))));
        assert!(XlaBandPreparer::from_manifest(Path::new("artifacts"), 0.5, 256, 1).is_err());
        let cfg = crate::config::PipelineConfig::default();
        let err = lshbloom_method_xla(&cfg).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
