//! Concurrent deduplication engine: lock-free atomic Bloom filters +
//! batched multi-threaded ingest.
//!
//! The classic serving path funnels every insert/query through a single
//! `Mutex<LshBloomDecider>`, capping index throughput at one core no
//! matter the hardware. Bloom bit-sets are naturally lock-free — setting
//! a bit is `AtomicU64::fetch_or`, probing is a relaxed load, and a set
//! bit is never unset — so this module rebuilds the LSHBloom hot path
//! around that primitive:
//!
//! * [`atomic_bloom::AtomicBloomFilter`] — `Vec<AtomicU64>` bit array
//!   sharing [`crate::bloom::BloomParams`] and the probe derivation
//!   ([`crate::bloom::probe_pair`]) with the sequential filter, so the
//!   design-bound FP math (§4.3/§4.5) is unchanged.
//! * [`concurrent_index::ConcurrentLshBloomIndex`] — one atomic filter
//!   per LSH band; `insert_if_new` on `&self` from any thread, plus a
//!   geometry-checked `union_from` bit-OR merge — the sharded
//!   aggregation primitive (`pipeline::shard`, paper §6).
//! * [`batch::ConcurrentEngine`] — `submit(Vec<Doc>) -> Vec<Decision>`:
//!   MinHash on a scoped worker pool, lock-free index probes, and an
//!   intra-batch reconcile pass that restores deterministic verdicts.
//! * [`band_slice`] — the band-partitioned serving tier: a contiguous
//!   slice of the per-band filters as a standalone unit
//!   ([`band_slice::BandSliceIndex`], the router-backend primitive) and
//!   N slices behind one preparer
//!   ([`band_slice::BandShardedEngine`], `serve --serve-shards N`),
//!   verdict-identical to the single engine by OR-reduction.
//!
//! Every layer can be backed by mmap'd files instead of the heap
//! ([`crate::persist`]): `AtomicBloomFilter::new_shm`/`open_shm`,
//! `ConcurrentLshBloomIndex::new_shm`, and
//! `ConcurrentEngine::new_persistent`/`checkpoint`/`restore` give the
//! lock-free path crash-safe persistence and cross-process sharing with
//! identical insert/probe semantics.
//!
//! ## Linearizability caveat (read before choosing this engine)
//!
//! Concurrent `insert_if_new` calls are not linearizable: twins inserted
//! from different threads at the same instant can both be reported "new"
//! (each sets part of the probe bits before the other looks). Within one
//! `submit` batch the reconcile pass catches this exactly; across
//! threads using the per-document path ([`batch::ConcurrentEngine::insert_one`])
//! the duplicate pair survives — a bounded recall loss for
//! same-instant twins, never a false positive, and never a false
//! negative once threads synchronize.
//!
//! ## Classic vs. concurrent
//!
//! Prefer the classic sequential decider (`pipeline::run_stream`) for
//! paper-faithful evaluation: exact stream-order verdicts including
//! in-batch filter false positives, every baseline method, blocked
//! filters, shm persistence. Prefer the concurrent engine when
//! throughput is the goal and callers are already concurrent — the
//! service under multi-client load, or bulk ingest on many cores
//! (`pipeline::run_stream_engine`). Follow-on scaling work (sharded
//! serving, NUMA-aware striping, shm-backed atomic filters) builds on
//! this seam — see ROADMAP.md.

// The engine is a public API surface other subsystems (persist,
// pipeline, service) build on; rustdoc is part of its contract. CI turns
// these warnings into errors (RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

pub mod atomic_bloom;
pub mod band_slice;
pub mod batch;
pub mod concurrent_index;

pub use atomic_bloom::AtomicBloomFilter;
pub use band_slice::{reconcile_in_batch, slice_range, BandShardedEngine, BandSliceIndex};
pub use batch::{ConcurrentEngine, Decision};
pub use concurrent_index::ConcurrentLshBloomIndex;

/// Strided popcount budget per filter for gauge refreshes: exact for
/// every filter up to 512 KiB of bits, an even sample above — cheap
/// enough to run on every checkpoint and every metrics scrape.
const GAUGE_SAMPLE_WORDS: usize = 1 << 16;

/// Publish per-band fill-ratio and estimated-FP gauges for `filters`
/// (bands numbered globally from `band_offset`) into the global
/// observability registry, returning `Π(1 − fp_band)` so callers can
/// combine slices into the any-band false-positive estimate
/// `1 − Π(1 − fill_i^k)` — the quantity the paper's sizing math bounds.
pub(crate) fn publish_band_fill_gauges(
    filters: &[AtomicBloomFilter],
    band_offset: usize,
) -> f64 {
    let reg = crate::obs::global();
    let mut miss_all = 1.0f64;
    for (i, f) in filters.iter().enumerate() {
        let band = band_offset + i;
        let fill = f.fill_ratio_sampled(GAUGE_SAMPLE_WORDS);
        let fp = fill.powi(f.params().hashes as i32);
        reg.gauge(&format!("engine.band_fill_ratio{{band=\"{band}\"}}")).set(fill);
        reg.gauge(&format!("engine.band_fp_estimate{{band=\"{band}\"}}")).set(fp);
        miss_all *= 1.0 - fp;
    }
    miss_all
}

/// [`publish_band_fill_gauges`] for a *frozen* generation: same base
/// gauge names with an extra `gen` label, so after a rotation the
/// unlabeled series keeps tracking the open generation instead of
/// silently reporting generation 0 forever.
pub(crate) fn publish_band_fill_gauges_gen(
    filters: &[AtomicBloomFilter],
    band_offset: usize,
    generation: usize,
) -> f64 {
    let reg = crate::obs::global();
    let mut miss_all = 1.0f64;
    for (i, f) in filters.iter().enumerate() {
        let band = band_offset + i;
        let fill = f.fill_ratio_sampled(GAUGE_SAMPLE_WORDS);
        let fp = fill.powi(f.params().hashes as i32);
        reg.gauge(&format!("engine.band_fill_ratio{{band=\"{band}\",gen=\"{generation}\"}}"))
            .set(fill);
        reg.gauge(&format!("engine.band_fp_estimate{{band=\"{band}\",gen=\"{generation}\"}}"))
            .set(fp);
        miss_all *= 1.0 - fp;
    }
    miss_all
}
