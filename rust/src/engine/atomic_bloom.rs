//! Lock-free Bloom filter backed by `Vec<AtomicU64>` — or, for
//! crash-safe persistence, by an mmap-backed
//! [`crate::persist::ShmAtomicBitArray`] with identical semantics
//! ([`AtomicBloomFilter::new_shm`] / [`AtomicBloomFilter::open_shm`]).
//!
//! Insertion is `fetch_or` per probed word; queries are acquire loads.
//! Probe positions come from the same Kirsch–Mitzenmacher derivation as
//! [`crate::bloom::BloomFilter`] ([`crate::bloom::probe_pair`]), and the
//! geometry is the same [`BloomParams`], so the design-bound FP math
//! (§4.3/§4.5) holds unchanged: the filter sets exactly the same bits the
//! sequential filter would for the same key stream.
//!
//! ## Memory-ordering contract
//!
//! Verdict-carrying operations pair release and acquire: probe loads are
//! `Acquire`, bit-publishing `fetch_or`s are `Release`, and the insert
//! `fetch_or` whose previous value feeds the duplicate verdict is
//! `AcqRel`. A probe that observes a bit of a prior insert therefore
//! also observes everything that happened-before that insert, so a
//! duplicate verdict can be acted on (dropping the document) without any
//! extra synchronization edge. The `inserted` element counter is
//! statistics, not a verdict, and stays `Relaxed` (each such load
//! carries a `lint: allow(ordering-discipline)` annotation; the
//! in-repo linter rejects relaxed loads on verdict paths). Two
//! documented races remain:
//!
//! * **Racing probes may see partial inserts.** A probe concurrent with
//!   an in-flight insert can observe only some of that insert's bits.
//!   Once the inserting thread happens-before the querying thread
//!   (thread join, channel send, or any other edge), `contains` is
//!   guaranteed `true` for the inserted key — a set bit is never unset,
//!   so any load that observes the `fetch_or`'s effect observes a
//!   superset of the bits the inserter set.
//! * **Racy duplicate verdicts.** Two threads concurrently inserting the
//!   same key can *both* observe "not previously present" (each sets a
//!   disjoint subset of probe words first). The engine layer
//!   ([`super::batch`]) reconciles such twins within a batch; across
//!   unsynchronized callers the race is documented behavior.

use crate::bloom::{probe_pair, BloomFilter, BloomParams};
use crate::error::Result;
use crate::persist::ShmAtomicBitArray;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Backing storage for the atomic word array: the heap, or an
/// mmap-backed file ([`ShmAtomicBitArray`]) for crash-safe persistence
/// and cross-process sharing. Every operation goes through
/// `&[AtomicU64]`, so insert/probe semantics — and the FP math — are
/// identical for both.
enum AtomicBits {
    Heap(Vec<AtomicU64>),
    Shm(ShmAtomicBitArray),
}

impl AtomicBits {
    #[inline(always)]
    fn words(&self) -> &[AtomicU64] {
        match self {
            AtomicBits::Heap(v) => v,
            AtomicBits::Shm(s) => s.words(),
        }
    }
}

/// A lock-free Bloom filter sharing geometry and probe derivation with
/// [`BloomFilter`].
pub struct AtomicBloomFilter {
    bits: AtomicBits,
    /// Bit-array length (= params.bits rounded up to a word multiple).
    m: u64,
    k: u32,
    inserted: AtomicU64,
    params: BloomParams,
}

impl AtomicBloomFilter {
    fn with_bits(bits: AtomicBits, inserted: u64, params: BloomParams) -> Self {
        let m = bits.words().len() as u64 * 64;
        Self { bits, m, k: params.hashes, inserted: AtomicU64::new(inserted), params }
    }

    /// Heap-backed filter with the given geometry.
    pub fn new(params: BloomParams) -> Self {
        let words = params.bits.div_ceil(64) as usize;
        let mut v = Vec::with_capacity(words);
        v.resize_with(words, || AtomicU64::new(0));
        Self::with_bits(AtomicBits::Heap(v), 0, params)
    }

    /// Heap-backed filter for `n` planned elements at rate `p`.
    pub fn with_capacity(n: u64, p: f64) -> Self {
        Self::new(BloomParams::for_capacity(n, p))
    }

    /// Filter backed by a freshly created (zeroed) mmap file — point the
    /// path at `/dev/shm/...` for the paper's DRAM-resident setup or any
    /// filesystem path for plain persistence. Same `fetch_or`/
    /// acquire-probe semantics as the heap variant.
    pub fn new_shm(params: BloomParams, path: &Path) -> Result<Self> {
        let words = params.bits.div_ceil(64) as usize;
        let shm = ShmAtomicBitArray::create(path, words)?;
        Ok(Self::with_bits(AtomicBits::Shm(shm), 0, params))
    }

    /// Filter re-attached to an existing persisted bit file (exact-size
    /// discipline — see [`ShmAtomicBitArray::open`]). `inserted` is the
    /// element count recorded alongside the file (checkpoint manifest).
    pub fn open_shm(params: BloomParams, path: &Path, inserted: u64) -> Result<Self> {
        let words = params.bits.div_ceil(64) as usize;
        let shm = ShmAtomicBitArray::open(path, words)?;
        Ok(Self::with_bits(AtomicBits::Shm(shm), inserted, params))
    }

    /// Heap-backed filter adopting pre-loaded words (checkpoint restore
    /// without keeping the file mapped).
    pub(crate) fn from_heap_words(words: Vec<u64>, inserted: u64, params: BloomParams) -> Self {
        debug_assert_eq!(words.len() as u64, params.bits.div_ceil(64));
        let v: Vec<AtomicU64> = words.into_iter().map(AtomicU64::new).collect();
        Self::with_bits(AtomicBits::Heap(v), inserted, params)
    }

    /// The backing file when mmap-backed, `None` on the heap.
    pub fn backing_path(&self) -> Option<&Path> {
        match &self.bits {
            AtomicBits::Heap(_) => None,
            AtomicBits::Shm(s) => Some(s.path()),
        }
    }

    /// Flush an mmap-backed filter's dirty pages to its file; no-op on
    /// the heap (checkpointing a heap filter copies it instead).
    pub fn sync(&self) -> Result<()> {
        match &self.bits {
            AtomicBits::Heap(_) => Ok(()),
            AtomicBits::Shm(s) => s.sync(),
        }
    }

    /// The atomic word array (persistence/checksum internals).
    pub(crate) fn words(&self) -> &[AtomicU64] {
        self.bits.words()
    }

    /// Word count of the backing array.
    pub(crate) fn word_count(&self) -> usize {
        self.bits.words().len()
    }

    /// OR a run of plain words into the array starting at word `offset`
    /// (the from-file half of [`Self::union_from`]; same monotone
    /// `fetch_or`, all-zero source words skipped).
    pub(crate) fn or_words_at(&self, offset: usize, src: &[u64]) {
        let words = self.bits.words();
        for (dst, &bits) in words[offset..offset + src.len()].iter().zip(src) {
            if bits != 0 {
                // Release: publish the restored bits to acquire probes.
                dst.fetch_or(bits, Ordering::Release);
            }
        }
    }

    /// Fold an externally merged element count into `inserted`.
    pub(crate) fn add_inserted(&self, n: u64) {
        self.inserted.fetch_add(n, Ordering::Relaxed);
    }

    /// Insert a key (lock-free, callable from any number of threads).
    /// Returns `true` if every probed bit was already set — i.e. the key
    /// was (possibly) already present. See the module docs for what this
    /// verdict means under concurrency.
    #[inline]
    pub fn insert(&self, key: u64) -> bool {
        let (h1, h2) = probe_pair(key);
        let m = self.m;
        let words = self.bits.words();
        let mut all_set = true;
        let mut h = h1;
        for _ in 0..self.k {
            let bit = h % m;
            let (w, mask) = (bit / 64, 1u64 << (bit % 64));
            // AcqRel: `prev` feeds the duplicate verdict (acquire side)
            // and the stored bit must publish this insert (release side).
            let prev = words[w as usize].fetch_or(mask, Ordering::AcqRel);
            all_set &= prev & mask != 0;
            h = h.wrapping_add(h2);
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
        all_set
    }

    /// Insert a key without computing the presence verdict — the cheap
    /// path once a caller has already decided a document's fate (see
    /// [`super::concurrent_index::ConcurrentLshBloomIndex::insert_if_new_shared`]).
    ///
    /// Sets exactly the same bits [`Self::insert`] would (state parity is
    /// what keeps cross-batch verdicts identical to the sequential
    /// filter), but uses test-and-test-and-set: each probed word is first
    /// read with a relaxed load and the contended `fetch_or` RMW is
    /// issued only when some probe bit is actually missing. For duplicate
    /// documents — whose bits are overwhelmingly already present — this
    /// turns the whole insert into plain loads.
    #[inline]
    pub fn set(&self, key: u64) {
        let (h1, h2) = probe_pair(key);
        let m = self.m;
        let words = self.bits.words();
        let mut h = h1;
        for _ in 0..self.k {
            let bit = h % m;
            let (w, mask) = (bit / 64, 1u64 << (bit % 64));
            let word = &words[w as usize];
            if word.load(Ordering::Acquire) & mask == 0 {
                // Release: publish the bit to acquire probes.
                word.fetch_or(mask, Ordering::Release);
            }
            h = h.wrapping_add(h2);
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
    }

    /// Bit-OR merge: fold every set bit of `other` into `self`, lock-free
    /// (`fetch_or` per word; all-zero source words are skipped). Panics
    /// if the two filters were built with different geometry — a union
    /// across mismatched `m`/`k` would silently corrupt the membership
    /// contract.
    ///
    /// The Bloom union property: after the call, `self` answers `true`
    /// for every key either filter answered `true` for (and for no key
    /// both answered `false` for beyond the design FP rate of the merged
    /// fill). Concurrent inserts into `self` during the merge are safe
    /// (both sides are monotone `fetch_or`s). Inserts racing into
    /// `other`, however, may be *missed* — the merge's relaxed loads can
    /// run before an in-flight `fetch_or` lands — so the caller must
    /// establish a happens-before edge with every `other` inserter
    /// (thread join, as `pipeline::shard` does) before merging, or those
    /// keys become false negatives in the union.
    pub fn union_from(&self, other: &Self) {
        assert_eq!(
            self.params, other.params,
            "AtomicBloomFilter::union_from: geometry mismatch ({:?} vs {:?})",
            self.params, other.params
        );
        debug_assert_eq!(self.word_count(), other.word_count());
        for (dst, src) in self.bits.words().iter().zip(other.bits.words()) {
            let bits = src.load(Ordering::Acquire);
            if bits != 0 {
                // Release: publish the merged bits to acquire probes.
                dst.fetch_or(bits, Ordering::Release);
            }
        }
        // Element counter, not a verdict (see module docs).
        self.inserted
            // lint: allow(ordering-discipline)
            .fetch_add(other.inserted.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Query a key: `true` means "possibly present" (no false negatives
    /// for inserts that happened-before this call).
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        let (h1, h2) = probe_pair(key);
        let m = self.m;
        let words = self.bits.words();
        let mut h = h1;
        for _ in 0..self.k {
            let bit = h % m;
            if words[(bit / 64) as usize].load(Ordering::Acquire) & (1u64 << (bit % 64)) == 0 {
                return false;
            }
            h = h.wrapping_add(h2);
        }
        true
    }

    /// Number of bits set (popcount) — fill diagnostics.
    pub fn ones(&self) -> u64 {
        self.bits
            .words()
            .iter()
            .map(|w| w.load(Ordering::Acquire).count_ones() as u64)
            .sum()
    }

    /// Fraction of bits set.
    pub fn fill_ratio(&self) -> f64 {
        self.ones() as f64 / self.m as f64
    }

    /// Fill ratio estimated from a strided popcount over at most
    /// `max_words` words — the cheap variant the observability gauges
    /// use so a refresh never walks a multi-GiB filter. Exact (falls
    /// back to [`Self::fill_ratio`]) whenever the filter fits inside
    /// the sample budget; otherwise an evenly strided sample, whose
    /// error shrinks as `1/sqrt(64 · max_words)` for the
    /// uniformly-spread bit patterns Bloom probes produce.
    pub fn fill_ratio_sampled(&self, max_words: usize) -> f64 {
        let words = self.bits.words();
        let n = words.len();
        if n == 0 || self.m == 0 {
            return 0.0;
        }
        if n <= max_words.max(1) {
            return self.fill_ratio();
        }
        let stride = n.div_ceil(max_words.max(1));
        let mut set_bits = 0u64;
        let mut sampled = 0u64;
        let mut i = 0;
        while i < n {
            set_bits += words[i].load(Ordering::Acquire).count_ones() as u64;
            sampled += 1;
            i += stride;
        }
        set_bits as f64 / (sampled * 64) as f64
    }

    /// Elements inserted so far (across all threads).
    pub fn inserted(&self) -> u64 {
        // Element counter, not a verdict (see module docs).
        self.inserted.load(Ordering::Relaxed) // lint: allow(ordering-discipline)
    }

    /// Geometry.
    pub fn params(&self) -> BloomParams {
        self.params
    }

    /// Bytes of backing storage.
    pub fn size_bytes(&self) -> u64 {
        (self.bits.words().len() * 8) as u64
    }

    /// Convert into a sequential heap-backed [`BloomFilter`] (for
    /// persistence via `BloomFilter::save`). Requires exclusive ownership,
    /// which is itself the synchronization point: the snapshot contains
    /// every insert that happened before the caller obtained `self`.
    pub fn into_filter(self) -> BloomFilter {
        // Exclusive ownership of `self` is the synchronization point, so
        // these snapshot loads need no ordering of their own.
        let inserted = self.inserted.load(Ordering::Relaxed); // lint: allow(ordering-discipline)
        let words: Vec<u64> = match self.bits {
            AtomicBits::Heap(v) => v.into_iter().map(|w| w.into_inner()).collect(),
            // lint: allow(ordering-discipline)
            AtomicBits::Shm(s) => s.words().iter().map(|w| w.load(Ordering::Relaxed)).collect(),
        };
        BloomFilter::from_raw_parts(words, self.k, inserted, self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn no_false_negatives_single_thread() {
        let f = AtomicBloomFilter::with_capacity(10_000, 1e-4);
        let mut rng = Xoshiro256pp::seeded(1);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        for &k in &keys {
            assert!(f.contains(k), "false negative for {k}");
        }
    }

    #[test]
    fn bit_identical_to_sequential_filter() {
        // Same keys, same geometry -> exactly the same bit pattern.
        let params = BloomParams::for_capacity(5_000, 1e-5);
        let atomic = AtomicBloomFilter::new(params);
        let mut classic = crate::bloom::BloomFilter::new(params);
        let mut rng = Xoshiro256pp::seeded(7);
        for _ in 0..5_000 {
            let k = rng.next_u64();
            atomic.insert(k);
            classic.insert(k);
        }
        assert_eq!(atomic.ones(), classic.ones());
        // Probe agreement on fresh keys (both FP or both clean).
        for _ in 0..50_000 {
            let k = rng.next_u64();
            assert_eq!(atomic.contains(k), classic.contains(k));
        }
    }

    #[test]
    fn insert_reports_prior_presence() {
        let f = AtomicBloomFilter::with_capacity(1000, 1e-6);
        assert!(!f.insert(42), "first insert must report absent");
        assert!(f.insert(42), "second insert must report present");
    }

    #[test]
    fn fp_rate_within_design_bound() {
        let p = 1e-3;
        let n = 50_000u64;
        let f = AtomicBloomFilter::with_capacity(n, p);
        let mut rng = Xoshiro256pp::seeded(2);
        for _ in 0..n {
            f.insert(rng.next_u64());
        }
        let trials = 200_000;
        let mut fps = 0u64;
        for _ in 0..trials {
            if f.contains(rng.next_u64()) {
                fps += 1;
            }
        }
        let observed = fps as f64 / trials as f64;
        assert!(observed < p * 3.0, "observed FP {observed} vs design {p}");
    }

    #[test]
    fn concurrent_inserts_no_false_negatives() {
        // 8 threads hammer overlapping key ranges; after join, every key
        // must be present (the Bloom no-false-negative invariant must
        // survive contention on the same words).
        let f = AtomicBloomFilter::with_capacity(20_000, 1e-6);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let f = &f;
                s.spawn(move || {
                    let mut rng = Xoshiro256pp::seeded(100 + t % 4); // pairs share keys
                    for _ in 0..5_000 {
                        f.insert(rng.next_u64());
                    }
                });
            }
        });
        for t in 0..4u64 {
            let mut rng = Xoshiro256pp::seeded(100 + t);
            for _ in 0..5_000 {
                let k = rng.next_u64();
                assert!(f.contains(k), "lost key {k} under contention");
            }
        }
    }

    #[test]
    fn set_is_bit_identical_to_insert() {
        let params = BloomParams::for_capacity(2_000, 1e-5);
        let via_insert = AtomicBloomFilter::new(params);
        let via_set = AtomicBloomFilter::new(params);
        let mut rng = Xoshiro256pp::seeded(21);
        for _ in 0..2_000 {
            let k = rng.next_u64();
            via_insert.insert(k);
            via_set.set(k);
        }
        assert_eq!(via_insert.ones(), via_set.ones());
        assert_eq!(via_insert.inserted(), via_set.inserted());
        for _ in 0..20_000 {
            let k = rng.next_u64();
            assert_eq!(via_insert.contains(k), via_set.contains(k));
        }
    }

    #[test]
    fn union_from_is_bit_identical_to_combined_inserts() {
        let params = BloomParams::for_capacity(4_000, 1e-5);
        let a = AtomicBloomFilter::new(params);
        let b = AtomicBloomFilter::new(params);
        let combined = AtomicBloomFilter::new(params);
        let mut rng = Xoshiro256pp::seeded(31);
        let keys_a: Vec<u64> = (0..2_000).map(|_| rng.next_u64()).collect();
        let keys_b: Vec<u64> = (0..2_000).map(|_| rng.next_u64()).collect();
        for &k in &keys_a {
            a.insert(k);
            combined.insert(k);
        }
        for &k in &keys_b {
            b.insert(k);
            combined.insert(k);
        }
        a.union_from(&b);
        assert_eq!(a.ones(), combined.ones(), "union must equal combined bit pattern");
        assert_eq!(a.inserted(), combined.inserted(), "union accumulates insert counts");
        for &k in keys_a.iter().chain(&keys_b) {
            assert!(a.contains(k), "key {k} lost in union");
        }
        // Probe agreement on fresh keys too (both FP or both clean).
        for _ in 0..20_000 {
            let k = rng.next_u64();
            assert_eq!(a.contains(k), combined.contains(k));
        }
    }

    #[test]
    fn union_from_empty_is_noop() {
        let params = BloomParams::for_capacity(1_000, 1e-4);
        let a = AtomicBloomFilter::new(params);
        let empty = AtomicBloomFilter::new(params);
        for i in 0..1_000u64 {
            a.insert(i * 17);
        }
        let before = a.ones();
        a.union_from(&empty);
        assert_eq!(a.ones(), before);
    }

    #[test]
    #[should_panic(expected = "geometry mismatch")]
    fn union_from_rejects_mismatched_geometry() {
        let a = AtomicBloomFilter::with_capacity(1_000, 1e-4);
        let b = AtomicBloomFilter::with_capacity(2_000, 1e-4);
        a.union_from(&b);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn shm_backed_filter_is_bit_identical_to_heap() {
        let dir = std::env::temp_dir().join(format!("lshbloom-ab-shm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.bits");
        let params = BloomParams::for_capacity(3_000, 1e-5);
        let heap = AtomicBloomFilter::new(params);
        let shm = AtomicBloomFilter::new_shm(params, &path).unwrap();
        assert_eq!(shm.backing_path(), Some(path.as_path()));
        assert_eq!(heap.backing_path(), None);
        let mut rng = Xoshiro256pp::seeded(91);
        for _ in 0..3_000 {
            let k = rng.next_u64();
            assert_eq!(heap.insert(k), shm.insert(k), "verdict diverged for {k}");
        }
        assert_eq!(heap.ones(), shm.ones());
        shm.sync().unwrap();
        let (ones, inserted) = (shm.ones(), shm.inserted());
        drop(shm);
        // Re-attach: same bits, same answers — the warm-start contract.
        let reopened = AtomicBloomFilter::open_shm(params, &path, inserted).unwrap();
        assert_eq!(reopened.ones(), ones);
        assert_eq!(reopened.inserted(), inserted);
        for _ in 0..20_000 {
            let k = rng.next_u64();
            assert_eq!(heap.contains(k), reopened.contains(k));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn into_filter_preserves_bits() {
        let f = AtomicBloomFilter::with_capacity(1000, 1e-4);
        for i in 0..1000u64 {
            f.insert(i * 31);
        }
        let (ones, inserted) = (f.ones(), f.inserted());
        let classic = f.into_filter();
        assert_eq!(classic.ones(), ones);
        assert_eq!(classic.inserted(), inserted);
        for i in 0..1000u64 {
            assert!(classic.contains(i * 31));
        }
    }
}
