//! Band-partitioned view of the LSHBloom index: the serving-tier
//! scale-out seam (ROADMAP "Sharded / multi-node serving").
//!
//! The concurrent index is `b` independent atomic Bloom filters, one per
//! LSH band, and the duplicate rule is a pure OR across bands (`query`:
//! a document is a duplicate iff *any* band collides, §4.2). That makes
//! the band axis trivially partitionable: give each of `N` owners a
//! contiguous slice of the `b` filters, probe every slice with the same
//! full band vector, and OR-reduce the per-slice verdicts — bit-for-bit
//! the single-index answer, because no filter moved or resized and no
//! band is probed by more than one owner.
//!
//! Two layers build on that observation:
//!
//! * [`BandSliceIndex`] — one owner's slice: the filters for bands
//!   `[start, start+len)`, built with the *full-index* per-filter
//!   geometry (`p = 1-(1-p_eff)^(1/b)` with the full `b`, §4.3), so a
//!   slice is interchangeable with the same bands of a
//!   [`super::concurrent_index::ConcurrentLshBloomIndex`]. This is what
//!   a router backend serves
//!   ([`crate::service`]'s `check_bands` op) and what restores from a
//!   slice of an existing checkpoint manifest
//!   ([`crate::persist::restore_band_slice`]).
//! * [`BandShardedEngine`] — the in-process composition (`serve
//!   --serve-shards N`): all `N` slices in one process behind one
//!   preparer. A request MinHashes once, the batch path probes every
//!   slice in parallel, and verdicts OR-reduce; the per-batch reconcile
//!   rule is shared with [`super::batch::ConcurrentEngine::submit`]
//!   via [`reconcile_in_batch`], so `--serve-shards N` is
//!   verdict-identical to the single concurrent engine for any `N`.
//!
//! The same OR-reduce runs across *hosts* in
//! [`crate::service::DedupRouter`]: each remote backend is a
//! [`BandSliceIndex`] reached over TCP, and [`reconcile_in_batch`] runs
//! at the router so batched semantics stay identical there too.

use super::atomic_bloom::AtomicBloomFilter;
use super::batch::{for_chunks_collect, Decision};
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::index::lshbloom::LshBloomConfig;
use crate::methods::lshbloom::BandPreparer;
use crate::methods::{Prepared, Preparer};
use std::collections::HashSet;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The contiguous band range owned by `slice` of `count` when `b` bands
/// are partitioned as evenly as possible (the first `b % count` slices
/// get one extra band). Every caller that partitions bands — the
/// in-process sharded engine, slice servers, the router's layout check —
/// must use this one derivation so slices always tile `[0, b)`.
pub fn slice_range(num_bands: usize, slice: usize, count: usize) -> Range<usize> {
    assert!(count >= 1, "slice_range: count must be >= 1");
    assert!(slice < count, "slice_range: slice {slice} out of range for count {count}");
    let base = num_bands / count;
    let extra = num_bands % count;
    let start = slice * base + slice.min(extra);
    let len = base + usize::from(slice < extra);
    start..start + len
}

/// The intra-batch reconcile rule shared by every batched serving path:
/// a document is a duplicate iff its pre-batch probe said so *or* an
/// earlier document in the same batch shares a band hash with it.
///
/// This is exactly phase 2 of [`super::batch::ConcurrentEngine::submit`]
/// — the rule that restores deterministic verdicts after all documents
/// probed the pre-batch filter state. It depends only on the full band
/// vectors and the OR-reduced pre-batch verdicts, never on filter
/// internals, which is why the router can apply it over *remote* slices
/// and still match the single-engine batch verdicts bit for bit.
pub fn reconcile_in_batch(bands_batch: &[Vec<u64>], pre_dup: &[bool]) -> Vec<bool> {
    debug_assert_eq!(bands_batch.len(), pre_dup.len());
    let per_doc = bands_batch.first().map(|b| b.len()).unwrap_or(0);
    let mut seen: HashSet<(u32, u64)> = HashSet::with_capacity(bands_batch.len() * per_doc);
    let mut out = Vec::with_capacity(bands_batch.len());
    for (bands, &pre) in bands_batch.iter().zip(pre_dup) {
        let dup = pre
            || bands
                .iter()
                .enumerate()
                .any(|(band, &h)| seen.contains(&(band as u32, h)));
        // Duplicates' bands enter the in-batch set too, matching the
        // sequential decider (which inserts flagged documents as well).
        for (band, &h) in bands.iter().enumerate() {
            seen.insert((band as u32, h));
        }
        out.push(dup);
    }
    out
}

/// Element-wise OR of per-slice verdict vectors (each of length `n`).
fn or_reduce(per_slice: &[Vec<bool>], n: usize) -> Vec<bool> {
    let mut out = vec![false; n];
    for verdicts in per_slice {
        debug_assert_eq!(verdicts.len(), n);
        for (o, &v) in out.iter_mut().zip(verdicts) {
            *o |= v;
        }
    }
    out
}

/// One owner's contiguous slice of the per-band atomic filters.
///
/// Every operation takes the *full* `b`-length band vector and touches
/// only the owned range, so N slices driven with the same vector set
/// exactly the bits one [`ConcurrentLshBloomIndex`] would — and the OR
/// of their verdicts is the single-index verdict.
///
/// A slice restored from a *rotated* checkpoint carries the same
/// generation list as the index that wrote it: frozen generations are
/// probe-only, every insert lands in the newest (open) generation, and
/// the verdict ORs across generations exactly like
/// [`ConcurrentLshBloomIndex::query`]. Unlike the ingest-tier index a
/// slice never rotates on its own — the serving tier adopts whatever
/// generation layout the checkpoint (or an anti-entropy peer) presents,
/// so every replica of a slice agrees on the layout by construction.
///
/// [`ConcurrentLshBloomIndex`]: super::concurrent_index::ConcurrentLshBloomIndex
/// [`ConcurrentLshBloomIndex::query`]: super::concurrent_index::ConcurrentLshBloomIndex::query
pub struct BandSliceIndex {
    /// Per-generation owned filters, oldest first; the last entry is
    /// the open generation all inserts target. Never empty.
    generations: Vec<Vec<AtomicBloomFilter>>,
    range: Range<usize>,
    config: LshBloomConfig,
    inserted: AtomicU64,
}

impl BandSliceIndex {
    /// Fresh heap-backed slice `slice` of `count` for `config`. The
    /// per-filter geometry derives from the full band count, never the
    /// slice length — that is the invariant that keeps a slice
    /// bit-compatible with the unsharded index.
    pub fn new(config: LshBloomConfig, slice: usize, count: usize) -> Self {
        let range = slice_range(config.lsh.num_bands, slice, count);
        let params = crate::index::LshBloomIndex::filter_params(&config);
        let filters = range.clone().map(|_| AtomicBloomFilter::new(params)).collect();
        Self::from_parts(vec![filters], range, config, 0)
    }

    /// Slice adopting pre-built per-generation filters (checkpoint
    /// restore — see [`crate::persist::restore_band_slice`]). Oldest
    /// generation first; the last is open for inserts.
    pub(crate) fn from_parts(
        generations: Vec<Vec<AtomicBloomFilter>>,
        range: Range<usize>,
        config: LshBloomConfig,
        inserted: u64,
    ) -> Self {
        debug_assert!(!generations.is_empty());
        for filters in &generations {
            debug_assert_eq!(filters.len(), range.len());
        }
        Self { generations, range, config, inserted: AtomicU64::new(inserted) }
    }

    /// Restore this slice's bands from a *full-index* checkpoint in
    /// `dir` (heap copy; the files are left untouched). The manifest's
    /// geometry must match `config` exactly, same strictness as a full
    /// restore — a mismatched slice would answer `false` for keys it
    /// never probed (Bloom false negatives).
    pub fn restore(
        config: LshBloomConfig,
        dir: &std::path::Path,
        slice: usize,
        count: usize,
    ) -> crate::error::Result<Self> {
        let range = slice_range(config.lsh.num_bands, slice, count);
        let (generations, manifest) =
            crate::persist::restore_band_slice(dir, &config, range.clone())?;
        Ok(Self::from_parts(generations, range, config, manifest.inserted))
    }

    /// Open — or create — this slice's bands as *live mmap-backed*
    /// filters under `dir` (see
    /// [`crate::persist::open_durable_slice`]): the replicated-serving
    /// backend mode, where every insert lands in the backing file
    /// before it is acknowledged, so a SIGKILL'd slice server restarts
    /// with zero lost inserts. A fresh directory is initialized with
    /// zeroed filters and a live-mode manifest; an existing one must
    /// match `config`'s geometry exactly (full-restore strictness) and
    /// a torn band file is a named error. Call [`Self::checkpoint`] at
    /// orderly shutdown (or after an anti-entropy merge) to refresh the
    /// manifest's counters.
    pub fn open_durable(
        config: LshBloomConfig,
        dir: &std::path::Path,
        slice: usize,
        count: usize,
    ) -> crate::error::Result<Self> {
        let range = slice_range(config.lsh.num_bands, slice, count);
        let (generations, inserted) =
            crate::persist::open_durable_slice(&config, range.clone(), dir)?;
        Ok(Self::from_parts(generations, range, config, inserted))
    }

    /// Publish this slice's manifest entries into the checkpoint
    /// directory `dir` ([`crate::persist::write_slice_checkpoint`]):
    /// live mmap-backed filters are msync'd in place, heap filters are
    /// cold-copied out. `docs`/`duplicates` are the serving counters to
    /// record alongside the index's insert count.
    pub fn checkpoint(
        &self,
        dir: &std::path::Path,
        docs: u64,
        duplicates: u64,
    ) -> crate::error::Result<()> {
        crate::persist::write_slice_checkpoint(
            &self.generations,
            &self.config,
            self.range.clone(),
            self.len(),
            docs,
            duplicates,
            dir,
        )?;
        Ok(())
    }

    /// Snapshot the words of owned band `band` (global numbering) in
    /// generation `gen` — the payload of the `pull_bands` anti-entropy
    /// wire op. `None` when this slice does not own `band` or holds no
    /// generation `gen`. Acquire loads, so the snapshot contains at
    /// least every insert that happened-before the call.
    pub fn band_words(&self, gen: usize, band: usize) -> Option<Vec<u64>> {
        let filters = self.generations.get(gen)?;
        let filter = filters.get(band.checked_sub(self.range.start)?)?;
        Some(filter.words().iter().map(|w| w.load(Ordering::Acquire)).collect())
    }

    /// Keys inserted into owned band `band` (global numbering) of
    /// generation `gen`; `None` when not owned / not held.
    pub fn band_inserted(&self, gen: usize, band: usize) -> Option<u64> {
        let filters = self.generations.get(gen)?;
        let filter = filters.get(band.checked_sub(self.range.start)?)?;
        Some(filter.inserted())
    }

    /// Bit-OR a peer replica's snapshot of band `band` (global
    /// numbering), generation `gen`, into the matching owned filter —
    /// the anti-entropy delta merge. Bloom bit-sets are monotone, so
    /// the merge is idempotent and commutative: replaying it after a
    /// mid-merge crash, or merging from several peers in any order,
    /// converges to the same bits. The filter's insert counter
    /// converges to the max of its own and `peer_inserted` (replicas of
    /// one slice see overlapping streams, so summing would
    /// double-count). Errors on a band this slice does not own, a
    /// generation it does not hold (grow first via
    /// [`Self::ensure_generations`]), or a word-count mismatch
    /// (geometry drift), without touching any bits.
    pub fn merge_band_words(
        &self,
        gen: usize,
        band: usize,
        words: &[u64],
        peer_inserted: u64,
    ) -> crate::error::Result<()> {
        let filters = self.generations.get(gen).ok_or_else(|| {
            crate::error::Error::Format(format!(
                "merge_band_words: generation {gen} exceeds this slice's {} generation(s); \
                 grow the slice (ensure_generations) before merging",
                self.generations.len()
            ))
        })?;
        let filter = band
            .checked_sub(self.range.start)
            .and_then(|local| filters.get(local))
            .ok_or_else(|| {
                crate::error::Error::Format(format!(
                    "merge_band_words: band {band} is outside this slice's range {:?}",
                    self.range
                ))
            })?;
        if words.len() != filter.word_count() {
            return Err(crate::error::Error::Format(format!(
                "merge_band_words: band {band} peer sent {} words but this filter has {}; \
                 refusing a geometry-mismatched merge",
                words.len(),
                filter.word_count()
            )));
        }
        filter.or_words_at(0, words);
        let own = filter.inserted();
        if peer_inserted > own {
            filter.add_inserted(peer_inserted - own);
        }
        Ok(())
    }

    /// Grow the generation list to at least `n` heap-backed generations
    /// so a peer's rotated layout can be merged in
    /// ([`Self::merge_band_words`] with `gen > 0`). All generations
    /// share the full-index geometry, so the new filters are
    /// bit-compatible by construction. Heap-backed even on a durable
    /// slice: the post-merge [`Self::checkpoint`] cold-copies them into
    /// the state directory, from where the next
    /// [`Self::open_durable`] re-attaches them as live mmaps.
    pub fn ensure_generations(&mut self, n: usize) {
        let params = crate::index::LshBloomIndex::filter_params(&self.config);
        while self.generations.len() < n {
            self.generations
                .push(self.range.clone().map(|_| AtomicBloomFilter::new(params)).collect());
        }
    }

    /// Number of generations this slice holds (at least 1).
    pub fn num_generations(&self) -> usize {
        self.generations.len()
    }

    /// Converge the slice-level insert counter to `max(own, n)` — the
    /// counter half of an anti-entropy merge (bits converge via
    /// [`Self::merge_band_words`]).
    pub fn adopt_inserted(&self, n: u64) {
        self.inserted.fetch_max(n, Ordering::Relaxed);
    }

    /// [`Self::restore`] against an already-loaded manifest — lets
    /// [`BandShardedEngine::restore`] parse `manifest.json` once for all
    /// N slices.
    pub(crate) fn restore_from(
        config: LshBloomConfig,
        manifest: &crate::persist::CheckpointManifest,
        dir: &std::path::Path,
        slice: usize,
        count: usize,
    ) -> crate::error::Result<Self> {
        let range = slice_range(config.lsh.num_bands, slice, count);
        let generations =
            crate::persist::restore_band_slice_from(manifest, dir, &config, range.clone())?;
        Ok(Self::from_parts(generations, range, config, manifest.inserted))
    }

    /// The band range this slice owns.
    pub fn band_range(&self) -> Range<usize> {
        self.range.clone()
    }

    /// Full band count of the index this slice partitions.
    pub fn full_bands(&self) -> usize {
        self.config.lsh.num_bands
    }

    /// The configuration the full index was built with.
    pub fn config(&self) -> LshBloomConfig {
        self.config
    }

    /// Documents inserted through this slice.
    pub fn len(&self) -> u64 {
        // Element counter, not a verdict.
        self.inserted.load(Ordering::Relaxed) // lint: allow(ordering-discipline)
    }

    /// True when nothing has been inserted.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of backing storage for the owned filters, all generations.
    pub fn disk_bytes(&self) -> u64 {
        self.generations.iter().flatten().map(|f| f.size_bytes()).sum()
    }

    /// The owned filters per generation, oldest first, each in band
    /// order (persistence internals).
    pub(crate) fn generation_filters(&self) -> &[Vec<AtomicBloomFilter>] {
        &self.generations
    }

    /// The open (newest) generation's filters, band order.
    fn open_generation(&self) -> &[AtomicBloomFilter] {
        // from_parts asserts the list is never empty.
        &self.generations[self.generations.len() - 1]
    }

    /// Publish fill-ratio / estimated-FP gauges for the owned bands
    /// (global band numbering; the open generation unlabeled, frozen
    /// generations under a `gen` label), returning `Π(1 − fp)` over
    /// every owned filter so [`BandShardedEngine`] can combine slices.
    pub(crate) fn fill_gauge_miss(&self) -> f64 {
        let open = self.generations.len() - 1;
        let mut miss = super::publish_band_fill_gauges(self.open_generation(), self.range.start);
        for (g, filters) in self.generations[..open].iter().enumerate() {
            miss *= super::publish_band_fill_gauges_gen(filters, self.range.start, g);
        }
        miss
    }

    /// Publish fill-ratio / estimated-FP gauges for the owned bands
    /// plus `engine.fp_estimate` over this slice's bands — a slice
    /// server's contribution to the fleet-wide any-band FP estimate.
    pub fn refresh_fill_gauges(&self) {
        let miss = self.fill_gauge_miss();
        let reg = crate::obs::global();
        reg.gauge("engine.fp_estimate").set(1.0 - miss);
        reg.gauge("engine.generation.count").set(self.generations.len() as f64);
    }

    fn owned<'a>(&self, band_hashes: &'a [u64]) -> &'a [u64] {
        assert_eq!(
            band_hashes.len(),
            self.config.lsh.num_bands,
            "BandSliceIndex: got {} band hashes, the index has {} bands",
            band_hashes.len(),
            self.config.lsh.num_bands
        );
        &band_hashes[self.range.clone()]
    }

    /// `true` when any owned band of `filters` contains its hash.
    fn collides(filters: &[AtomicBloomFilter], owned: &[u64]) -> bool {
        filters.iter().zip(owned).any(|(f, &h)| f.contains(h))
    }

    /// Query the owned bands without inserting (lock-free). `true` =
    /// some owned band collides in *any* generation; OR this across
    /// slices for the full-index verdict.
    pub fn query(&self, band_hashes: &[u64]) -> bool {
        let owned = self.owned(band_hashes);
        self.generations.iter().rev().any(|g| Self::collides(g, owned))
    }

    /// Query + insert the owned bands in one lock-free pass; same
    /// frozen-probe / open-insert split and the same
    /// short-circuit-to-`set` discipline (and therefore the same bits
    /// and the same verdict contribution) as
    /// [`super::concurrent_index::ConcurrentLshBloomIndex::insert_if_new_shared`].
    pub fn insert_if_new(&self, band_hashes: &[u64]) -> bool {
        let owned = self.owned(band_hashes);
        let open = self.generations.len() - 1;
        let mut dup = self.generations[..open].iter().any(|g| Self::collides(g, owned));
        for (f, &h) in self.open_generation().iter().zip(owned) {
            if dup {
                f.set(h);
            } else {
                dup = f.insert(h);
            }
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
        dup
    }

    /// Insert the owned bands into the open generation without
    /// computing a verdict (the batched phase-3 path;
    /// test-and-test-and-set, bit-identical state).
    pub fn set(&self, band_hashes: &[u64]) {
        let owned = self.owned(band_hashes);
        for (f, &h) in self.open_generation().iter().zip(owned) {
            f.set(h);
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
    }

    /// Probe a whole batch read-only against the pre-batch state, then
    /// insert every document's owned bands — the slice half of the
    /// batched serving protocol (`check_bands_batch`). Returns the
    /// *pre-batch* verdicts; the caller (in-process engine or router)
    /// OR-reduces them across slices and applies
    /// [`reconcile_in_batch`] for final verdicts.
    pub fn probe_insert_batch(&self, bands_batch: &[Vec<u64>]) -> Vec<bool> {
        let pre: Vec<bool> = bands_batch.iter().map(|b| self.query(b)).collect();
        for bands in bands_batch {
            self.set(bands);
        }
        pre
    }
}

/// N band slices behind one preparer: the in-process band-partitioned
/// serving engine (`serve --serve-shards N`).
///
/// Verdict-identical to [`super::batch::ConcurrentEngine`] for any
/// slice count: single documents OR-reduce per-slice
/// [`BandSliceIndex::insert_if_new`] verdicts, batches run the same
/// three phases as `submit` (parallel pre-batch probe — fanned across
/// slices — sequential [`reconcile_in_batch`], parallel insert).
pub struct BandShardedEngine {
    preparer: Arc<dyn Preparer>,
    slices: Vec<BandSliceIndex>,
    config: LshBloomConfig,
    workers: usize,
    docs: AtomicU64,
    duplicates: AtomicU64,
}

impl BandShardedEngine {
    /// Fresh engine with `count` heap-backed band slices.
    pub fn from_config(cfg: &PipelineConfig, count: usize) -> Self {
        let preparer = BandPreparer::from_config(cfg);
        let config = LshBloomConfig::new(preparer.lsh, cfg.p_effective, cfg.expected_docs);
        let slices = (0..count).map(|s| BandSliceIndex::new(config, s, count)).collect();
        Self::with_parts(Arc::new(preparer), slices, config, cfg.effective_workers(), 0, 0)
    }

    /// Rebuild a sharded engine from a *full-index* checkpoint in `dir`
    /// (written by [`super::batch::ConcurrentEngine::checkpoint`] or a
    /// `dedup --distributed` aggregation): each slice heap-restores its
    /// own band files, and the docs/duplicates counters resume from the
    /// manifest. The files are left untouched — use
    /// [`Self::checkpoint`] to persist again.
    pub fn restore(
        cfg: &PipelineConfig,
        dir: &std::path::Path,
        count: usize,
    ) -> crate::error::Result<Self> {
        let preparer = BandPreparer::from_config(cfg);
        let config = LshBloomConfig::new(preparer.lsh, cfg.p_effective, cfg.expected_docs);
        let manifest = crate::persist::CheckpointManifest::load(dir)?;
        manifest.verify_geometry(&config)?;
        let mut slices = Vec::with_capacity(count);
        for s in 0..count {
            slices.push(BandSliceIndex::restore_from(config, &manifest, dir, s, count)?);
        }
        Ok(Self::with_parts(
            Arc::new(preparer),
            slices,
            config,
            cfg.effective_workers(),
            manifest.docs,
            manifest.duplicates,
        ))
    }

    fn with_parts(
        preparer: Arc<dyn Preparer>,
        slices: Vec<BandSliceIndex>,
        config: LshBloomConfig,
        workers: usize,
        docs: u64,
        duplicates: u64,
    ) -> Self {
        Self {
            preparer,
            slices,
            config,
            workers: workers.max(1),
            docs: AtomicU64::new(docs),
            duplicates: AtomicU64::new(duplicates),
        }
    }

    /// Persist the full index (all slices, band order) into `dir` as a
    /// checksummed cold snapshot — the same wire format
    /// [`super::batch::ConcurrentEngine::checkpoint`] writes, so a
    /// sharded server's state restores into a single engine and back.
    pub fn checkpoint(&self, dir: &std::path::Path) -> crate::error::Result<()> {
        // Slices restored from one manifest (or built fresh) agree on
        // the generation count; reassemble each generation in full band
        // order across slices.
        let gen_filters: Vec<Vec<&AtomicBloomFilter>> = (0..self.num_generations())
            .map(|g| {
                self.slices
                    .iter()
                    .flat_map(|s| s.generation_filters()[g].iter())
                    .collect()
            })
            .collect();
        let (docs, duplicates) = self.stats();
        // Every processed document inserts into the index (duplicates
        // too), so the engine's docs counter is the inserted count.
        crate::persist::write_checkpoint_generations(
            &gen_filters,
            &self.config,
            docs,
            docs,
            duplicates,
            dir,
        )?;
        // The checkpoint walked every filter — refresh fill gauges too.
        self.refresh_fill_gauges();
        Ok(())
    }

    /// Number of band slices.
    pub fn num_slices(&self) -> usize {
        self.slices.len()
    }

    /// Full band count (all slices together).
    pub fn num_bands(&self) -> usize {
        self.config.lsh.num_bands
    }

    /// Rows hashed per band (geometry handshake).
    pub fn rows_per_band(&self) -> usize {
        self.config.lsh.rows_per_band
    }

    /// Generations held (all slices agree — they restore from one
    /// manifest or start fresh at 1).
    pub fn num_generations(&self) -> usize {
        self.slices.first().map(|s| s.num_generations()).unwrap_or(1)
    }

    /// (documents processed, duplicates flagged) across all operations.
    pub fn stats(&self) -> (u64, u64) {
        // Statistics counters, not verdicts.
        // lint: allow(ordering-discipline)
        (self.docs.load(Ordering::Relaxed), self.duplicates.load(Ordering::Relaxed))
    }

    /// Index footprint in bytes (static: sized by capacity at build).
    pub fn disk_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.disk_bytes()).sum()
    }

    /// Publish fill-ratio / estimated-FP gauges for every band across
    /// all slices, plus the whole-index any-band FP estimate
    /// (`engine.fp_estimate`).
    pub fn refresh_fill_gauges(&self) {
        let mut miss_all = 1.0f64;
        for slice in &self.slices {
            miss_all *= slice.fill_gauge_miss();
        }
        let reg = crate::obs::global();
        reg.gauge("engine.fp_estimate").set(1.0 - miss_all);
        reg.gauge("engine.generation.count").set(self.num_generations() as f64);
    }

    fn prepare_one(&self, doc: &Doc) -> Vec<u64> {
        let mut prepared = self.preparer.prepare_batch(std::slice::from_ref(doc));
        let Prepared::Bands(bands) = prepared.remove(0) else {
            panic!("BandShardedEngine requires a band-producing preparer");
        };
        bands
    }

    /// Run `f` once per slice, each on its own scoped thread, and
    /// collect the per-slice results in slice order — the one fan-out
    /// every batched phase (probe, insert, probe+insert) goes through.
    fn for_slices<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&BandSliceIndex) -> T + Sync,
    {
        std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = self
                .slices
                .iter()
                .map(|slice| scope.spawn(move || f(slice)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// Single-document query + insert: MinHash once, fold the bands into
    /// every slice, OR-reduce the per-slice verdicts. The per-slice
    /// probes run on the caller's thread — each is a handful of filter
    /// probes, far below thread-spawn cost; the batched [`Self::submit`]
    /// path is where slices fan out in parallel.
    pub fn insert_one(&self, doc: &Doc) -> bool {
        let bands = self.prepare_one(doc);
        self.insert_bands(&bands)
    }

    /// Single-document query only (no state change, no stats mutation).
    pub fn query_one(&self, doc: &Doc) -> bool {
        let bands = self.prepare_one(doc);
        self.query_bands(&bands)
    }

    /// Band-level query + insert (the `check_bands` op: bands computed
    /// elsewhere, e.g. by a router). OR-reduce of per-slice
    /// [`BandSliceIndex::insert_if_new`].
    pub fn insert_bands(&self, band_hashes: &[u64]) -> bool {
        let mut dup = false;
        for slice in &self.slices {
            // No short-circuit: every slice must ingest its bands.
            dup |= slice.insert_if_new(band_hashes);
        }
        self.docs.fetch_add(1, Ordering::Relaxed);
        self.duplicates.fetch_add(dup as u64, Ordering::Relaxed);
        dup
    }

    /// Band-level query only.
    pub fn query_bands(&self, band_hashes: &[u64]) -> bool {
        self.slices.iter().any(|s| s.query(band_hashes))
    }

    /// Band-level batch (`check_bands_batch`): every slice probes the
    /// whole batch against its pre-batch state and then folds the batch
    /// in, *in parallel across slices* — the same fan-out as
    /// [`Self::submit`]'s probe/insert phases. Safe because slices own
    /// disjoint bands: slice `i`'s probes read only filters that slice
    /// `i`'s inserts write, so parallel slices cannot leak mid-batch
    /// state into each other's pre-batch verdicts. Returns the
    /// OR-reduced *pre-batch* verdicts; counters advance exactly like
    /// [`super::batch::ConcurrentEngine::probe_insert_bands`].
    pub fn probe_insert_bands(&self, batch: &[Vec<u64>]) -> Vec<bool> {
        let per_slice = self.for_slices(|slice| slice.probe_insert_batch(batch));
        let pre = or_reduce(&per_slice, batch.len());
        self.docs.fetch_add(batch.len() as u64, Ordering::Relaxed);
        let dups = pre.iter().filter(|&&d| d).count() as u64;
        self.duplicates.fetch_add(dups, Ordering::Relaxed);
        pre
    }

    /// Deduplicate one batch; verdicts in submission order, identical to
    /// [`super::batch::ConcurrentEngine::submit`] on the same stream.
    ///
    /// Phases: (1) parallel MinHash across a worker pool, once per
    /// document; (2) every slice probes the whole batch *in parallel*
    /// against pre-batch state and the per-slice verdicts OR-reduce;
    /// (3) sequential [`reconcile_in_batch`]; (4) every slice folds the
    /// batch in, again in parallel across slices.
    pub fn submit(&self, docs: Vec<Doc>) -> Vec<Decision> {
        let n = docs.len();
        if n == 0 {
            return Vec::new();
        }
        // Phase 1: parallel prepare (band hashes only), gathered back
        // into submission order — the `ConcurrentEngine` idiom.
        let bands_batch: Vec<Vec<u64>> = for_chunks_collect(self.workers, n, |range| {
            self.preparer
                .prepare_batch(&docs[range])
                .into_iter()
                .map(|prep| {
                    let Prepared::Bands(bands) = prep else {
                        panic!("BandShardedEngine requires a band-producing preparer");
                    };
                    bands
                })
                .collect()
        });

        // Phase 2: probe every slice in parallel (read-only, pre-batch
        // state), then OR-reduce into one pre-verdict per document.
        let per_slice = self.for_slices(|slice| {
            bands_batch.iter().map(|b| slice.query(b)).collect::<Vec<bool>>()
        });
        let pre = or_reduce(&per_slice, n);

        // Phase 3: sequential intra-batch reconcile (the shared rule).
        let verdicts = reconcile_in_batch(&bands_batch, &pre);

        // Phase 4: parallel insert, one thread per slice (verdict-free
        // `set` path — same bits, no contended RMWs for present bits).
        self.for_slices(|slice| {
            for bands in &bands_batch {
                slice.set(bands);
            }
        });

        let dups = verdicts.iter().filter(|&&d| d).count() as u64;
        self.docs.fetch_add(n as u64, Ordering::Relaxed);
        self.duplicates.fetch_add(dups, Ordering::Relaxed);
        docs.iter()
            .zip(&verdicts)
            .map(|(doc, &duplicate)| Decision { id: doc.id, duplicate })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ConcurrentEngine, ConcurrentLshBloomIndex};
    use crate::minhash::LshParams;
    use crate::rng::Xoshiro256pp;

    fn cfg() -> PipelineConfig {
        PipelineConfig {
            num_perms: 128,
            threshold: 0.5,
            expected_docs: 10_000,
            workers: 4,
            ..Default::default()
        }
    }

    fn index_cfg(bands: usize, rows: usize, n: u64) -> LshBloomConfig {
        LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: rows },
            p_effective: 1e-8,
            expected_docs: n,
            blocked: false,
        }
    }

    #[test]
    fn slice_range_tiles_the_band_space() {
        for bands in [1usize, 2, 7, 9, 16] {
            for count in 1..=bands {
                let mut covered = Vec::new();
                for s in 0..count {
                    covered.extend(slice_range(bands, s, count));
                }
                assert_eq!(covered, (0..bands).collect::<Vec<_>>(), "bands={bands} count={count}");
            }
        }
        assert_eq!(slice_range(9, 0, 4), 0..3);
        assert_eq!(slice_range(9, 3, 4), 7..9);
    }

    #[test]
    fn sliced_inserts_match_the_unsharded_index() {
        let config = index_cfg(9, 13, 10_000);
        for count in [2usize, 3, 4] {
            let slices: Vec<BandSliceIndex> =
                (0..count).map(|s| BandSliceIndex::new(config, s, count)).collect();
            let whole = ConcurrentLshBloomIndex::new(config);
            let mut rng = Xoshiro256pp::seeded(17);
            for _ in 0..4_000 {
                let bands: Vec<u64> = (0..9).map(|_| rng.next_u64() % 500).collect();
                let mut dup = false;
                for s in &slices {
                    dup |= s.insert_if_new(&bands);
                }
                assert_eq!(dup, whole.insert_if_new_shared(&bands), "count={count}");
            }
            for _ in 0..10_000 {
                let bands: Vec<u64> = (0..9).map(|_| rng.next_u64() % 800).collect();
                let sliced = slices.iter().any(|s| s.query(&bands));
                assert_eq!(sliced, whole.query(&bands), "count={count}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "band hashes")]
    fn slice_rejects_wrong_band_count() {
        let s = BandSliceIndex::new(index_cfg(6, 4, 1_000), 0, 2);
        s.query(&[1, 2, 3]);
    }

    #[test]
    fn reconcile_matches_submit_rule() {
        // Twin inside the batch (same bands) must flag the later copy;
        // pre-batch dups stay flagged; fresh docs stay fresh.
        let a = vec![1u64, 2, 3];
        let b = vec![9u64, 9, 9];
        let batch = vec![a.clone(), b.clone(), a.clone(), vec![1, 7, 8]];
        let out = reconcile_in_batch(&batch, &[false, true, false, false]);
        // Doc 3 shares band 0's hash (1) with doc 0 — a band collision.
        assert_eq!(out, vec![false, true, true, true]);
        assert!(reconcile_in_batch(&[], &[]).is_empty());
    }

    #[test]
    fn sharded_engine_matches_concurrent_engine_verdicts() {
        let config = cfg();
        let docs: Vec<Doc> = (0..400)
            .map(|i| Doc { id: i, text: format!("band sharded parity doc {}", i % 140) })
            .collect();
        let reference = ConcurrentEngine::from_config(&config);
        let mut expected = Vec::new();
        for chunk in docs.chunks(37) {
            expected.extend(reference.submit(chunk.to_vec()).into_iter().map(|d| d.duplicate));
        }
        for count in [1usize, 2, 4] {
            let engine = BandShardedEngine::from_config(&config, count);
            let mut got = Vec::new();
            for chunk in docs.chunks(37) {
                got.extend(engine.submit(chunk.to_vec()).into_iter().map(|d| d.duplicate));
            }
            assert_eq!(got, expected, "count={count}");
            assert_eq!(engine.stats(), reference.stats(), "count={count}");
        }
    }

    #[test]
    fn sharded_single_doc_path_matches_engine() {
        let config = cfg();
        let reference = ConcurrentEngine::from_config(&config);
        let engine = BandShardedEngine::from_config(&config, 3);
        for i in 0..200u64 {
            let doc = Doc { id: i, text: format!("single path parity {}", i % 61) };
            assert_eq!(engine.query_one(&doc), reference.query_one(&doc), "query {i}");
            assert_eq!(engine.insert_one(&doc), reference.insert_one(&doc), "insert {i}");
        }
        assert_eq!(engine.stats(), reference.stats());
        assert_eq!(engine.disk_bytes(), reference.disk_bytes());
    }

    #[test]
    fn checkpoint_restore_roundtrips_through_slices() {
        let dir = std::env::temp_dir().join(format!("lshbloom-bands-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let config = cfg();
        let engine = ConcurrentEngine::from_config(&config);
        let docs: Vec<Doc> = (0..60)
            .map(|i| Doc { id: i, text: format!("slice restore doc {}", i % 23) })
            .collect();
        engine.submit(docs.clone());
        let stats = engine.stats();
        engine.checkpoint(&dir).unwrap();

        // Slice restore: every checkpointed document is recognized.
        let sharded = BandShardedEngine::restore(&config, &dir, 4).unwrap();
        assert_eq!(sharded.stats(), stats, "counters resume from the manifest");
        for doc in &docs {
            assert!(sharded.query_one(doc), "restored slices lost doc {}", doc.id);
        }

        // Sharded checkpoint writes the same full-index wire format back.
        let dir2 = dir.join("resaved");
        sharded.checkpoint(&dir2).unwrap();
        let whole = ConcurrentEngine::restore(&config, &dir2, false).unwrap();
        for doc in &docs {
            assert!(whole.query_one(doc), "resaved checkpoint lost doc {}", doc.id);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Property test: over random geometries and random insert / probe /
    /// verdict-free-set interleavings, an mmap-backed durable slice is
    /// bit-for-bit identical to a heap slice fed the same stream — every
    /// verdict and, at the end, every filter word.
    #[test]
    fn durable_slice_is_bit_identical_to_heap() {
        let root = std::env::temp_dir()
            .join(format!("lshbloom-durable-prop-{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let mut rng = Xoshiro256pp::seeded(0xD17B_0007);
        for case in 0..6u64 {
            let bands = [3usize, 5, 8, 9, 12, 16][(rng.next_u64() % 6) as usize];
            let rows = 4 + (rng.next_u64() % 12) as usize;
            let count = 1 + (rng.next_u64() % (bands as u64).min(4)) as usize;
            let config = index_cfg(bands, rows, 5_000);
            let heap: Vec<BandSliceIndex> =
                (0..count).map(|s| BandSliceIndex::new(config, s, count)).collect();
            let durable: Vec<BandSliceIndex> = (0..count)
                .map(|s| {
                    BandSliceIndex::open_durable(
                        config,
                        &root.join(format!("case{case}-slice{s}")),
                        s,
                        count,
                    )
                    .unwrap()
                })
                .collect();
            for step in 0..1_200u64 {
                let hashes: Vec<u64> =
                    (0..bands).map(|_| rng.next_u64() % 700).collect();
                match rng.next_u64() % 3 {
                    0 => {
                        for (h, d) in heap.iter().zip(&durable) {
                            assert_eq!(
                                h.insert_if_new(&hashes),
                                d.insert_if_new(&hashes),
                                "case {case} step {step}: insert verdict diverged"
                            );
                        }
                    }
                    1 => {
                        for (h, d) in heap.iter().zip(&durable) {
                            h.set(&hashes);
                            d.set(&hashes);
                        }
                    }
                    _ => {
                        for (h, d) in heap.iter().zip(&durable) {
                            assert_eq!(
                                h.query(&hashes),
                                d.query(&hashes),
                                "case {case} step {step}: probe verdict diverged"
                            );
                        }
                    }
                }
            }
            for (h, d) in heap.iter().zip(&durable) {
                assert_eq!(h.len(), d.len(), "case {case}: insert counters diverged");
                for g in h.band_range() {
                    assert_eq!(
                        h.band_words(0, g),
                        d.band_words(0, g),
                        "case {case} band {g}: mmap words differ from heap"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&root).ok();
    }

    /// Property test for the anti-entropy invariant: with every insert
    /// delivered to a random subset of replicas such that replicas 0
    /// and 1 *jointly* see everything, OR-merging both into the stale
    /// replica 2 reproduces the reference slice (which saw every
    /// insert) bit for bit — and replaying the merge changes nothing
    /// (idempotence, the property that makes mid-merge crash retry
    /// safe).
    #[test]
    fn replica_subset_union_recovers_the_full_slice() {
        let config = index_cfg(8, 6, 4_000);
        let reference = BandSliceIndex::new(config, 1, 3);
        let replicas: Vec<BandSliceIndex> =
            (0..3).map(|_| BandSliceIndex::new(config, 1, 3)).collect();
        let mut rng = Xoshiro256pp::seeded(0xA117_E27);
        for _ in 0..2_000 {
            let hashes: Vec<u64> = (0..8).map(|_| rng.next_u64() % 900).collect();
            reference.set(&hashes);
            // Replicas 0 and 1 jointly cover every insert; replica 2
            // sees only a strict-ish subset (the stale restartee).
            match rng.next_u64() % 3 {
                0 => replicas[0].set(&hashes),
                1 => replicas[1].set(&hashes),
                _ => {
                    replicas[0].set(&hashes);
                    replicas[1].set(&hashes);
                }
            }
            if rng.next_u64() % 4 == 0 {
                replicas[2].set(&hashes);
            }
        }
        let merge_all_into = |target: &BandSliceIndex| {
            for g in reference.band_range() {
                for peer in &replicas[..2] {
                    target
                        .merge_band_words(
                            0,
                            g,
                            &peer.band_words(0, g).unwrap(),
                            peer.band_inserted(0, g).unwrap(),
                        )
                        .unwrap();
                }
            }
        };
        merge_all_into(&replicas[2]);
        let converged: Vec<Option<Vec<u64>>> =
            reference.band_range().map(|g| replicas[2].band_words(0, g)).collect();
        for (g, words) in reference.band_range().zip(&converged) {
            assert_eq!(
                words.as_ref(),
                reference.band_words(0, g).as_ref(),
                "band {g}: replica union missed bits the full index has"
            );
        }
        // Idempotence: a second full replay of the merge is a no-op.
        merge_all_into(&replicas[2]);
        for (g, words) in reference.band_range().zip(&converged) {
            assert_eq!(
                replicas[2].band_words(0, g).as_ref(),
                words.as_ref(),
                "band {g}: replaying the merge changed bits"
            );
        }
        // Out-of-range band, missing generation, and wrong word count
        // are named errors that leave no bits behind.
        assert!(replicas[2].merge_band_words(0, 0, &[], 0).is_err(), "band 0 is unowned");
        let g = reference.band_range().start;
        let err = replicas[2].merge_band_words(1, g, &[], 0).unwrap_err();
        assert!(err.to_string().contains("generation"), "{err}");
        let err = replicas[2].merge_band_words(0, g, &[0u64; 1], 0).unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
    }

    /// A slice that adopted a rotated layout (frozen generations +
    /// one open) answers exactly like the generational index: frozen
    /// membership survives, inserts land only in the open generation,
    /// and merging a rotated peer into a single-generation replica
    /// converges after `ensure_generations`.
    #[test]
    fn generational_slice_matches_generational_index() {
        let config = index_cfg(6, 4, 256);
        let mut whole = ConcurrentLshBloomIndex::new(config);
        whole.enable_rotation(0.5);
        let mut rng = Xoshiro256pp::seeded(0x6E2A_51CE);
        let docs: Vec<Vec<u64>> = (0..2_048)
            .map(|_| (0..6).map(|_| rng.next_u64()).collect())
            .collect();
        for bands in &docs {
            whole.insert_if_new_shared(bands);
        }
        assert!(whole.num_generations() > 1, "rotation must have fired");

        // Rebuild the same layout slice-by-slice from a checkpoint.
        let dir = std::env::temp_dir()
            .join(format!("lshbloom-genslice-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        crate::persist::write_checkpoint(&whole, docs.len() as u64, 0, &dir).unwrap();
        let count = 3usize;
        let slices: Vec<BandSliceIndex> = (0..count)
            .map(|s| BandSliceIndex::restore(config, &dir, s, count).unwrap())
            .collect();
        for s in &slices {
            assert_eq!(s.num_generations(), whole.num_generations());
        }
        for bands in &docs {
            assert!(
                slices.iter().any(|s| s.query(bands)),
                "restored generational slices lost a frozen-generation doc"
            );
        }

        // Anti-entropy: a fresh single-generation replica of slice 1
        // grows to the peer's layout and converges bit-for-bit.
        let mut stale = BandSliceIndex::new(config, 1, count);
        let peer = &slices[1];
        stale.ensure_generations(peer.num_generations());
        for gen in 0..peer.num_generations() {
            for band in peer.band_range() {
                stale
                    .merge_band_words(
                        gen,
                        band,
                        &peer.band_words(gen, band).unwrap(),
                        peer.band_inserted(gen, band).unwrap(),
                    )
                    .unwrap();
            }
        }
        for gen in 0..peer.num_generations() {
            for band in peer.band_range() {
                assert_eq!(
                    stale.band_words(gen, band),
                    peer.band_words(gen, band),
                    "gen {gen} band {band}: merged replica diverged"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
