//! Lock-free LSHBloom index: one [`AtomicBloomFilter`] per band, grown
//! in *generations* for unbounded streaming ingest.
//!
//! The structural twin of [`crate::index::LshBloomIndex`] — same band
//! geometry, same per-filter rate derivation (`p = 1-(1-p_eff)^(1/b)`,
//! §4.3), same single-pass insert-if-new semantics — but every operation
//! takes `&self`, so any number of threads insert and query without a
//! lock.
//!
//! ## Generations
//!
//! A Bloom filter sized for `n` documents degrades past `n`: fill climbs
//! past the ~50% design point and the false-positive rate grows without
//! bound. Instead of capping ingest at the plan, the index holds a list
//! of *generations* — filter sets sharing one geometry (the live
//! [`crate::capacity::Plan`]). The newest generation is *open*: all
//! inserts land there. Older generations are *frozen*: probed read-only.
//! A document is a duplicate when any band collides in any generation,
//! so freezing never loses a positive; rotation only resets the fill
//! (and FP) clock for new arrivals.
//!
//! Rotation is driven by sampled fill: when the open generation's
//! fullest band crosses the configured watermark
//! (`capacity.rotate_watermark`, default 0.5 ≈ "at planned capacity"),
//! the current filter set is frozen and a fresh one opens, sized from
//! the same plan. [`ConcurrentLshBloomIndex::new`] starts with rotation
//! disabled; the engine wiring opts in via
//! [`ConcurrentLshBloomIndex::enable_rotation`].
//!
//! ## Linearizability caveat
//!
//! `insert_if_new` is *not* linearizable across threads: two concurrent
//! inserts of near-identical documents can both return `false` ("new")
//! because each observes the filter before the other's bits land. Within
//! one [`super::batch::ConcurrentEngine::submit`] call this is repaired
//! by the intra-batch reconcile pass; callers driving this index directly
//! from unsynchronized threads (e.g. the service's per-connection path)
//! accept the race: the duplicate pair survives, which only costs a tiny
//! amount of recall for twins that arrive in the same microsecond —
//! never a false positive, and never a false negative once the inserting
//! thread synchronizes with the querier.

use super::atomic_bloom::AtomicBloomFilter;
use crate::index::lshbloom::LshBloomConfig;
use crate::index::BandIndex;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// One generation's band filters, shared so probes and checkpoints can
/// hold a generation alive across a concurrent rotation.
pub(crate) type GenerationFilters = Arc<Vec<AtomicBloomFilter>>;

/// Words sampled per filter when deciding whether to rotate. Small
/// enough to amortize over the check interval, exact for small filters.
const ROTATE_SAMPLE_WORDS: usize = 1 << 12;

/// Lock-free per-band Bloom index with generational growth.
pub struct ConcurrentLshBloomIndex {
    /// Generations, oldest first; the last entry is the open one. The
    /// lock is write-held only during a rotation (and the rare
    /// `ensure_generations` during restores/unions) — the hot path takes
    /// the uncontended read side.
    generations: RwLock<Vec<GenerationFilters>>,
    config: LshBloomConfig,
    inserted: AtomicU64,
    /// Sampled-fill watermark that triggers a rotation; `0.0` disables.
    watermark: f64,
    /// Inserts since the last fill sample (rotation checks are strided).
    since_check: AtomicU64,
    /// Watermark-driven rotations performed.
    rotations: AtomicU64,
    /// Backing directory when mmap-backed: rotated generations open
    /// their files under `<dir>/gen{g:03}/`.
    shm_dir: Option<PathBuf>,
}

impl ConcurrentLshBloomIndex {
    /// Build from the same config the sequential index uses. The
    /// `blocked` flag is ignored (atomic filters are always the classic
    /// layout; blocking is a cache optimization for the sequential path).
    /// Rotation starts disabled — see [`Self::enable_rotation`].
    pub fn new(config: LshBloomConfig) -> Self {
        // Same geometry derivation as the sequential index — required for
        // `into_sequential` snapshots and cross-index `union_from`.
        let params = crate::index::LshBloomIndex::filter_params(&config);
        let filters = (0..config.lsh.num_bands)
            .map(|_| AtomicBloomFilter::new(params))
            .collect();
        Self::from_generations(vec![filters], config, 0)
    }

    /// Index with every band filter mmap-backed under `dir`
    /// (`band{i:03}.bits`, freshly zeroed) — the durable variant: same
    /// lock-free semantics, but every `fetch_or` lands in a file, and
    /// `persist::write_checkpoint` on this index is an msync instead of
    /// a copy. Rotated generations land in `gen{g:03}/` subdirectories.
    /// Point `dir` at `/dev/shm/...` for the paper's DRAM-resident setup
    /// (§4.4.2) or any path for plain persistence.
    pub fn new_shm(config: LshBloomConfig, dir: &std::path::Path) -> crate::error::Result<Self> {
        std::fs::create_dir_all(dir)
            .map_err(|e| crate::error::Error::io(dir.display().to_string(), e))?;
        // A fresh index invalidates any checkpoint already in `dir`, so
        // the stale manifest must go *before* the filter files are
        // zeroed: if it survived and this process crashed before its
        // first checkpoint, a later restore would trust the old
        // manifest over the new empty filters (live mode skips
        // checksums) and skip documents whose bits are gone — silent
        // Bloom false negatives. Removal failure (other than the file
        // not existing) is therefore a hard error.
        for stale in [
            crate::persist::manifest::MANIFEST_FILE.to_string(),
            format!("{}.tmp", crate::persist::manifest::MANIFEST_FILE),
        ] {
            crate::persist::remove_file_if_exists(&dir.join(stale))?;
        }
        // Stale generation directories from a previous incarnation go
        // with the manifest — restores are manifest-driven so they are
        // unreachable, but leaving them would let a later rotation adopt
        // a directory it doesn't own.
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                if crate::persist::manifest::parse_generation_dir_name(&name.to_string_lossy())
                    .is_some()
                    && entry.path().is_dir()
                {
                    std::fs::remove_dir_all(entry.path())
                        .map_err(|e| crate::error::Error::io(entry.path().display().to_string(), e))?;
                }
            }
        }
        let params = crate::index::LshBloomIndex::filter_params(&config);
        let mut filters = Vec::with_capacity(config.lsh.num_bands);
        for band in 0..config.lsh.num_bands {
            let path = dir.join(crate::persist::manifest::band_file_name(band));
            filters.push(AtomicBloomFilter::new_shm(params, &path)?);
        }
        Ok(Self::from_generations(vec![filters], config, 0))
    }

    /// Index adopting pre-built band filters (checkpoint restore of a
    /// single-generation index).
    pub(crate) fn from_parts(
        filters: Vec<AtomicBloomFilter>,
        config: LshBloomConfig,
        inserted: u64,
    ) -> Self {
        Self::from_generations(vec![filters], config, inserted)
    }

    /// Index adopting pre-built generations, oldest first (checkpoint
    /// restore). The backing directory for future rotations is inferred
    /// from generation 0's filter files when they are mmap-backed.
    pub(crate) fn from_generations(
        generations: Vec<Vec<AtomicBloomFilter>>,
        config: LshBloomConfig,
        inserted: u64,
    ) -> Self {
        debug_assert!(!generations.is_empty());
        for g in &generations {
            debug_assert_eq!(g.len(), config.lsh.num_bands);
        }
        let shm_dir = generations
            .first()
            .and_then(|g| g.first())
            .and_then(|f| f.backing_path())
            .and_then(|p| p.parent())
            .map(|p| p.to_path_buf());
        Self {
            generations: RwLock::new(generations.into_iter().map(Arc::new).collect()),
            config,
            inserted: AtomicU64::new(inserted),
            watermark: 0.0,
            since_check: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
            shm_dir,
        }
    }

    /// Opt in to watermark-driven rotation: once the open generation's
    /// sampled fill reaches `watermark`, it freezes and a fresh
    /// generation opens. `0.0` keeps the index fixed-size (legacy
    /// behavior — the filter saturates past its plan instead of
    /// growing).
    pub fn enable_rotation(&mut self, watermark: f64) {
        self.watermark = watermark.clamp(0.0, 1.0);
    }

    /// The configured rotation watermark (`0.0` = disabled).
    pub fn rotate_watermark(&self) -> f64 {
        self.watermark
    }

    fn gens(&self) -> RwLockReadGuard<'_, Vec<GenerationFilters>> {
        self.generations.read().unwrap_or_else(PoisonError::into_inner)
    }

    fn gens_mut(&self) -> RwLockWriteGuard<'_, Vec<GenerationFilters>> {
        self.generations.write().unwrap_or_else(PoisonError::into_inner)
    }

    /// Snapshot of every generation, oldest first (persistence
    /// internals). The Arcs keep each filter set alive even if a
    /// rotation lands mid-checkpoint.
    pub(crate) fn generation_snapshot(&self) -> Vec<GenerationFilters> {
        self.gens().clone()
    }

    /// Grow to at least `n` generations by opening fresh (empty) ones —
    /// the restore/union half of rotation, where the source layout
    /// dictates the count.
    pub(crate) fn ensure_generations(&self, n: usize) -> crate::error::Result<()> {
        let mut gens = self.gens_mut();
        while gens.len() < n {
            let fresh = self.fresh_generation(gens.len())?;
            gens.push(Arc::new(fresh));
        }
        Ok(())
    }

    /// Number of generations (1 until the first rotation).
    pub fn num_generations(&self) -> usize {
        self.gens().len()
    }

    /// Watermark-driven rotations performed over this index's lifetime
    /// (excludes generations adopted from a restore or union).
    pub fn rotations(&self) -> u64 {
        // Statistics counter, not a verdict.
        self.rotations.load(Ordering::Relaxed) // lint: allow(ordering-discipline)
    }

    /// Fold an externally merged document count into the index counter
    /// (the from-file half of [`Self::union_from`]'s accounting).
    pub(crate) fn add_inserted(&self, n: u64) {
        self.inserted.fetch_add(n, Ordering::Relaxed);
    }

    /// Flush every mmap-backed band filter of every generation to its
    /// file (no-op for heap filters). See [`AtomicBloomFilter::sync`].
    pub fn sync(&self) -> crate::error::Result<()> {
        for g in self.generation_snapshot() {
            for f in g.iter() {
                f.sync()?;
            }
        }
        Ok(())
    }

    /// The configuration this index was built with.
    pub fn config(&self) -> LshBloomConfig {
        self.config
    }

    fn collides(filters: &[AtomicBloomFilter], band_hashes: &[u64]) -> bool {
        filters.iter().zip(band_hashes).any(|(f, &h)| f.contains(h))
    }

    /// Query without inserting (lock-free). `true` = any band collides
    /// in any generation. Probes newest-first: recent keys are the
    /// likeliest matches in a dedup stream.
    pub fn query(&self, band_hashes: &[u64]) -> bool {
        debug_assert_eq!(band_hashes.len(), self.config.lsh.num_bands);
        self.gens().iter().rev().any(|g| Self::collides(g, band_hashes))
    }

    /// Query + insert in one lock-free pass; `&self`, callable from any
    /// thread. Returns `true` if every probed bit of some band was
    /// already set (duplicate) in any generation. Frozen generations are
    /// probed read-only; the insert lands in the open generation only.
    /// Subject to the module-level linearizability caveat for concurrent
    /// twins.
    ///
    /// Once some band (or a frozen generation) reports a collision the
    /// verdict is final, so the remaining bands switch from the
    /// verdict-tracking [`AtomicBloomFilter::insert`] to the cheaper
    /// [`AtomicBloomFilter::set`]: the same bits are still set (state
    /// parity with the sequential single-pass insert is what keeps later
    /// verdicts exact), but already-present bits are detected with a
    /// plain load instead of a contended `fetch_or` — for exact
    /// duplicates, whose bits are all present, the tail of the pass
    /// issues no RMWs at all.
    pub fn insert_if_new_shared(&self, band_hashes: &[u64]) -> bool {
        debug_assert_eq!(band_hashes.len(), self.config.lsh.num_bands);
        let dup = {
            let gens = self.gens();
            let (open, frozen) = gens.split_last().expect("generation list never empty");
            let mut dup = frozen.iter().any(|g| Self::collides(g, band_hashes));
            for (f, &h) in open.iter().zip(band_hashes) {
                if dup {
                    f.set(h);
                } else {
                    dup = f.insert(h);
                }
            }
            dup
        };
        self.inserted.fetch_add(1, Ordering::Relaxed);
        self.maybe_rotate();
        dup
    }

    /// Insert a document's bands without computing a verdict — the bulk
    /// path for callers that already decided the document's fate (the
    /// engine's phase-3 insert after its reconcile pass). Sets exactly
    /// the bits [`Self::insert_if_new_shared`] would — in the open
    /// generation — via the test-and-test-and-set
    /// [`AtomicBloomFilter::set`], so filter state — and every later
    /// verdict — is unchanged while already-present bits cost a plain
    /// load instead of a contended `fetch_or`.
    pub fn set_shared(&self, band_hashes: &[u64]) {
        debug_assert_eq!(band_hashes.len(), self.config.lsh.num_bands);
        {
            let gens = self.gens();
            let open = gens.last().expect("generation list never empty");
            for (f, &h) in open.iter().zip(band_hashes) {
                f.set(h);
            }
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
        self.maybe_rotate();
    }

    /// How many inserts to absorb between fill samples: fine enough to
    /// catch the watermark within ~6% of the plan, coarse enough that
    /// the strided popcount amortizes to noise.
    fn check_interval(&self) -> u64 {
        (self.config.expected_docs / 16).clamp(32, 1 << 16)
    }

    fn max_fill(filters: &[AtomicBloomFilter]) -> f64 {
        filters
            .iter()
            .map(|f| f.fill_ratio_sampled(ROTATE_SAMPLE_WORDS))
            .fold(0.0, f64::max)
    }

    /// Strided rotation check: sample the open generation's fill every
    /// `check_interval()` inserts and rotate when it crosses the
    /// watermark.
    fn maybe_rotate(&self) {
        if self.watermark <= 0.0 {
            return;
        }
        if self.since_check.fetch_add(1, Ordering::Relaxed) + 1 < self.check_interval() {
            return;
        }
        // Benign race: concurrent resets only stretch the next interval.
        self.since_check.store(0, Ordering::Relaxed);
        let crossed = {
            let gens = self.gens();
            let open = gens.last().expect("generation list never empty");
            Self::max_fill(open) >= self.watermark
        };
        if crossed {
            self.rotate();
        }
    }

    /// Freeze the open generation and open a fresh one. On shm failure
    /// the index keeps ingesting into the (over-full) open generation —
    /// correctness is unaffected, only the FP budget degrades — and the
    /// next check retries.
    fn rotate(&self) {
        let mut gens = self.gens_mut();
        // Re-sample under the write lock: a racing thread may have
        // rotated between our sample and the lock acquisition, in which
        // case the (fresh) open generation is nowhere near the
        // watermark.
        let open = gens.last().expect("generation list never empty");
        if Self::max_fill(open) < self.watermark {
            return;
        }
        match self.fresh_generation(gens.len()) {
            Ok(fresh) => {
                gens.push(Arc::new(fresh));
                self.rotations.fetch_add(1, Ordering::Relaxed);
                let reg = crate::obs::global();
                reg.counter("engine.generation.rotations.total").inc();
                reg.gauge("engine.generation.count").set(gens.len() as f64);
                crate::log_info!(
                    "generation rotation: open-generation fill crossed {:.2}, generation {} now open ({} total)",
                    self.watermark,
                    gens.len() - 1,
                    gens.len()
                );
            }
            Err(e) => {
                crate::log_warn!(
                    "generation rotation failed ({e}); continuing in generation {}",
                    gens.len() - 1
                );
            }
        }
    }

    /// Build generation `gen`'s filter set from the live plan — heap, or
    /// mmap-backed under `gen{g:03}/` when the index is durable.
    fn fresh_generation(&self, gen: usize) -> crate::error::Result<Vec<AtomicBloomFilter>> {
        let params = crate::index::LshBloomIndex::filter_params(&self.config);
        let bands = self.config.lsh.num_bands;
        match &self.shm_dir {
            Some(dir) => {
                let gdir = dir.join(crate::persist::manifest::generation_dir_name(gen));
                std::fs::create_dir_all(&gdir)
                    .map_err(|e| crate::error::Error::io(gdir.display().to_string(), e))?;
                let mut filters = Vec::with_capacity(bands);
                for band in 0..bands {
                    let path = gdir.join(crate::persist::manifest::band_file_name(band));
                    filters.push(AtomicBloomFilter::new_shm(params, &path)?);
                }
                Ok(filters)
            }
            None => Ok((0..bands).map(|_| AtomicBloomFilter::new(params)).collect()),
        }
    }

    /// Bit-OR merge: fold every band filter of every generation of
    /// `other` into the matching generation of `self` (lock-free,
    /// geometry-checked — see [`AtomicBloomFilter::union_from`]).
    /// Generations align by position — sound because both indexes derive
    /// every generation from the same plan — and `self` opens fresh
    /// generations as needed to absorb a source that rotated further.
    /// Panics when the two indexes disagree on band count or per-filter
    /// geometry.
    ///
    /// This is the sharded-aggregation primitive (paper §6): after the
    /// union, `self` reports a collision for every band vector either
    /// index would have reported one for, so cross-shard deduplication
    /// reduces to querying survivors against the running union — no
    /// re-insertion, no re-MinHashing. Concurrent inserts into `self`
    /// are safe during the merge; inserts racing into `other` may be
    /// missed, so synchronize with (e.g. join) every `other` writer
    /// first — see [`AtomicBloomFilter::union_from`] for the full
    /// memory-ordering contract.
    pub fn union_from(&self, other: &Self) {
        assert_eq!(
            self.config.lsh.num_bands,
            other.config.lsh.num_bands,
            "ConcurrentLshBloomIndex::union_from: band count mismatch ({} vs {})",
            self.config.lsh.num_bands,
            other.config.lsh.num_bands
        );
        let src_gens = other.generation_snapshot();
        self.ensure_generations(src_gens.len())
            .expect("ConcurrentLshBloomIndex::union_from: cannot open destination generation");
        let dst_gens = self.generation_snapshot();
        for (dst, src) in dst_gens.iter().zip(&src_gens) {
            for (d, s) in dst.iter().zip(src.iter()) {
                d.union_from(s);
            }
        }
        self.inserted
            // lint: allow(ordering-discipline) — element counter, not a verdict
            .fetch_add(other.inserted.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Fill ratio of each band filter of the *open* generation
    /// (diagnostics; frozen generations sit pinned at the watermark).
    pub fn fill_ratios(&self) -> Vec<f64> {
        let gens = self.gens();
        let open = gens.last().expect("generation list never empty");
        open.iter().map(|f| f.fill_ratio()).collect()
    }

    /// Publish per-band fill-ratio / estimated-FP gauges plus the
    /// any-band FP estimate (`engine.fp_estimate`) and generation count
    /// into the global observability registry. The open generation keeps
    /// the legacy `{band="B"}` labels; frozen generations carry an extra
    /// `gen` label so dashboards see the live fill, not silently
    /// generation 0's. Popcounts are strided
    /// ([`AtomicBloomFilter::fill_ratio_sampled`]), so this is cheap
    /// enough to run on every checkpoint and every metrics scrape.
    pub fn refresh_fill_gauges(&self) {
        let gens = self.generation_snapshot();
        let open = gens.len() - 1;
        let mut miss_all = 1.0;
        for (g, filters) in gens.iter().enumerate() {
            miss_all *= if g == open {
                super::publish_band_fill_gauges(filters, 0)
            } else {
                super::publish_band_fill_gauges_gen(filters, 0, g)
            };
        }
        let reg = crate::obs::global();
        reg.gauge("engine.fp_estimate").set(1.0 - miss_all);
        reg.gauge("engine.generation.count").set(gens.len() as f64);
    }

    /// Number of bands.
    pub fn num_bands(&self) -> usize {
        self.config.lsh.num_bands
    }

    /// Documents inserted so far (across all generations).
    pub fn len(&self) -> u64 {
        // Element counter, not a verdict.
        self.inserted.load(Ordering::Relaxed) // lint: allow(ordering-discipline)
    }

    /// True when nothing has been inserted.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of backing storage across all generations (static per
    /// generation: fixed by capacity, not docs).
    pub fn disk_bytes(&self) -> u64 {
        self.gens()
            .iter()
            .map(|g| g.iter().map(|f| f.size_bytes()).sum::<u64>())
            .sum()
    }

    /// OR every generation's band filters into one fresh filter set —
    /// sound because all generations share one geometry; the cost is
    /// merging the generations' independent FP budgets into one
    /// (over-full) filter.
    fn collapse(gens: &[GenerationFilters], config: &LshBloomConfig) -> Vec<AtomicBloomFilter> {
        let params = crate::index::LshBloomIndex::filter_params(config);
        (0..config.lsh.num_bands)
            .map(|band| {
                let acc = AtomicBloomFilter::new(params);
                for g in gens {
                    acc.union_from(&g[band]);
                }
                acc
            })
            .collect()
    }

    /// Freeze into a persistable sequential [`crate::index::LshBloomIndex`]
    /// snapshot. Consumes the index; exclusive ownership is the
    /// synchronization point, so the snapshot holds every insert that
    /// happened before the caller obtained `self`. A multi-generation
    /// index is collapsed by OR (see [`Self::collapse`]); single
    /// generations move without copying.
    pub fn into_sequential(self) -> crate::index::LshBloomIndex {
        // lint: allow(ordering-discipline) — exclusive ownership is the sync point
        let inserted = self.inserted.load(Ordering::Relaxed);
        let config = self.config;
        let mut gens = self
            .generations
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner);
        let filters = if gens.len() == 1 {
            let only = gens.pop().expect("generation list never empty");
            match Arc::try_unwrap(only) {
                Ok(owned) => owned,
                Err(shared) => Self::collapse(&[shared], &config),
            }
        } else {
            Self::collapse(&gens, &config)
        };
        let filters = filters.into_iter().map(|f| f.into_filter()).collect::<Vec<_>>();
        crate::index::LshBloomIndex::from_filters(filters, config, inserted)
    }
}

// The trait's `insert_if_new` takes `&mut self`; routing it through the
// shared-path method lets the concurrent index drop into any code written
// against `BandIndex` (tests, the shard pipeline) at zero cost.
impl BandIndex for ConcurrentLshBloomIndex {
    fn query(&self, band_hashes: &[u64]) -> bool {
        ConcurrentLshBloomIndex::query(self, band_hashes)
    }

    fn insert_if_new(&mut self, band_hashes: &[u64]) -> bool {
        self.insert_if_new_shared(band_hashes)
    }

    fn num_bands(&self) -> usize {
        ConcurrentLshBloomIndex::num_bands(self)
    }

    fn len(&self) -> u64 {
        ConcurrentLshBloomIndex::len(self)
    }

    fn disk_bytes(&self) -> u64 {
        ConcurrentLshBloomIndex::disk_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minhash::LshParams;
    use crate::rng::Xoshiro256pp;

    fn cfg(bands: usize, rows: usize, n: u64) -> LshBloomConfig {
        LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: rows },
            p_effective: 1e-8,
            expected_docs: n,
            blocked: false,
        }
    }

    fn random_bands(rng: &mut Xoshiro256pp, b: usize) -> Vec<u64> {
        (0..b).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn matches_sequential_index_verdicts() {
        let config = cfg(9, 13, 10_000);
        let concurrent = ConcurrentLshBloomIndex::new(config);
        let mut sequential = crate::index::LshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(11);
        for _ in 0..5_000 {
            let bands = random_bands(&mut rng, 9);
            assert_eq!(
                concurrent.insert_if_new_shared(&bands),
                sequential.insert_if_new(&bands),
            );
        }
        for _ in 0..20_000 {
            let bands = random_bands(&mut rng, 9);
            assert_eq!(concurrent.query(&bands), sequential.query(&bands));
        }
        assert_eq!(concurrent.disk_bytes(), sequential.disk_bytes());
        assert_eq!(concurrent.len(), sequential.len());
    }

    #[test]
    fn single_band_match_is_duplicate() {
        let idx = ConcurrentLshBloomIndex::new(cfg(4, 2, 1000));
        idx.insert_if_new_shared(&[1, 2, 3, 4]);
        assert!(idx.query(&[9, 9, 3, 9]));
        assert!(!idx.query(&[9, 9, 9, 9]));
    }

    #[test]
    fn short_circuited_insert_keeps_exact_state_parity() {
        // Low-entropy band values force the duplicate verdict early in
        // the band pass, exercising the `set` tail on nearly every
        // insert. State must stay bit-for-bit equal to the sequential
        // index: identical verdicts during ingest AND identical answers
        // on every later query (a dropped tail-band insert would show up
        // here as a sequential-true / concurrent-false divergence).
        let config = cfg(7, 5, 5_000);
        let concurrent = ConcurrentLshBloomIndex::new(config);
        let mut sequential = crate::index::LshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(77);
        let docs: Vec<Vec<u64>> =
            (0..3_000).map(|_| (0..7).map(|_| rng.next_u64() % 40).collect()).collect();
        for d in &docs {
            assert_eq!(
                concurrent.insert_if_new_shared(d),
                sequential.insert_if_new(d),
                "verdict diverged on {d:?}"
            );
        }
        for _ in 0..20_000 {
            let probe: Vec<u64> = (0..7).map(|_| rng.next_u64() % 60).collect();
            assert_eq!(
                concurrent.query(&probe),
                sequential.query(&probe),
                "post-ingest state diverged on {probe:?}"
            );
        }
        assert_eq!(concurrent.len(), sequential.len());
    }

    #[test]
    fn union_from_merges_membership_of_both_indexes() {
        let config = cfg(6, 4, 10_000);
        let a = ConcurrentLshBloomIndex::new(config);
        let b = ConcurrentLshBloomIndex::new(config);
        let combined = ConcurrentLshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(41);
        let docs_a: Vec<Vec<u64>> = (0..1_500).map(|_| random_bands(&mut rng, 6)).collect();
        let docs_b: Vec<Vec<u64>> = (0..1_500).map(|_| random_bands(&mut rng, 6)).collect();
        for d in &docs_a {
            a.insert_if_new_shared(d);
            combined.insert_if_new_shared(d);
        }
        for d in &docs_b {
            b.insert_if_new_shared(d);
            combined.insert_if_new_shared(d);
        }
        a.union_from(&b);
        for d in docs_a.iter().chain(&docs_b) {
            assert!(a.query(d), "doc lost in union");
        }
        assert_eq!(a.len(), 3_000, "union accumulates document counts");
        // Exact bit parity with single-index ingest of the same stream.
        assert_eq!(a.fill_ratios(), combined.fill_ratios());
    }

    #[test]
    #[should_panic(expected = "band count mismatch")]
    fn union_from_rejects_band_count_mismatch() {
        let a = ConcurrentLshBloomIndex::new(cfg(6, 4, 1_000));
        let b = ConcurrentLshBloomIndex::new(cfg(5, 4, 1_000));
        a.union_from(&b);
    }

    #[test]
    #[should_panic(expected = "geometry mismatch")]
    fn union_from_rejects_filter_geometry_mismatch() {
        // Same band count, different capacity -> different per-filter m.
        let a = ConcurrentLshBloomIndex::new(cfg(6, 4, 1_000));
        let b = ConcurrentLshBloomIndex::new(cfg(6, 4, 50_000));
        a.union_from(&b);
    }

    #[test]
    fn concurrent_inserts_never_lose_documents() {
        let idx = ConcurrentLshBloomIndex::new(cfg(6, 8, 50_000));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let idx = &idx;
                s.spawn(move || {
                    let mut rng = Xoshiro256pp::seeded(500 + t);
                    for _ in 0..2_000 {
                        idx.insert_if_new_shared(&random_bands(&mut rng, 6));
                    }
                });
            }
        });
        assert_eq!(idx.len(), 16_000);
        for t in 0..8u64 {
            let mut rng = Xoshiro256pp::seeded(500 + t);
            for _ in 0..2_000 {
                assert!(idx.query(&random_bands(&mut rng, 6)), "doc lost under contention");
            }
        }
    }

    #[test]
    fn into_sequential_preserves_contents() {
        let idx = ConcurrentLshBloomIndex::new(cfg(5, 3, 5000));
        let mut rng = Xoshiro256pp::seeded(3);
        let docs: Vec<Vec<u64>> = (0..500).map(|_| random_bands(&mut rng, 5)).collect();
        for d in &docs {
            idx.insert_if_new_shared(d);
        }
        let (len, disk) = (idx.len(), idx.disk_bytes());
        let frozen = idx.into_sequential();
        assert_eq!(frozen.len(), len);
        assert_eq!(frozen.disk_bytes(), disk);
        for d in &docs {
            assert!(frozen.query(d));
        }
    }

    #[test]
    fn rotation_stays_disabled_by_default() {
        // 8x overfill without `enable_rotation` must not grow the index —
        // legacy fixed-size behavior.
        let idx = ConcurrentLshBloomIndex::new(cfg(6, 4, 256));
        let mut rng = Xoshiro256pp::seeded(7);
        for _ in 0..2_048 {
            idx.insert_if_new_shared(&random_bands(&mut rng, 6));
        }
        assert_eq!(idx.num_generations(), 1);
        assert_eq!(idx.rotations(), 0);
    }

    #[test]
    fn rotation_opens_new_generations_and_keeps_all_verdicts() {
        let mut idx = ConcurrentLshBloomIndex::new(cfg(6, 4, 256));
        idx.enable_rotation(0.5);
        let mut rng = Xoshiro256pp::seeded(9);
        let docs: Vec<Vec<u64>> = (0..2_048).map(|_| random_bands(&mut rng, 6)).collect();
        for d in &docs {
            idx.insert_if_new_shared(d);
        }
        assert!(idx.num_generations() > 1, "8x overfill must cross the watermark");
        assert_eq!(idx.rotations() as usize, idx.num_generations() - 1);
        for d in &docs {
            assert!(idx.query(d), "doc lost across a rotation");
        }
        assert_eq!(idx.len(), 2_048);
    }

    #[test]
    fn union_from_absorbs_multi_generation_sources() {
        let config = cfg(6, 4, 256);
        let mut a = ConcurrentLshBloomIndex::new(config);
        a.enable_rotation(0.5);
        let mut rng = Xoshiro256pp::seeded(17);
        let docs: Vec<Vec<u64>> = (0..1_024).map(|_| random_bands(&mut rng, 6)).collect();
        for d in &docs {
            a.insert_if_new_shared(d);
        }
        assert!(a.num_generations() > 1);
        let b = ConcurrentLshBloomIndex::new(config);
        b.union_from(&a);
        assert_eq!(b.num_generations(), a.num_generations());
        for d in &docs {
            assert!(b.query(d), "doc lost in generational union");
        }
        assert_eq!(b.len(), a.len());
    }

    #[test]
    fn into_sequential_collapses_generations() {
        let mut idx = ConcurrentLshBloomIndex::new(cfg(5, 3, 200));
        idx.enable_rotation(0.5);
        let mut rng = Xoshiro256pp::seeded(23);
        let docs: Vec<Vec<u64>> = (0..800).map(|_| random_bands(&mut rng, 5)).collect();
        for d in &docs {
            idx.insert_if_new_shared(d);
        }
        assert!(idx.num_generations() > 1);
        let inserted = idx.len();
        let frozen = idx.into_sequential();
        assert_eq!(frozen.len(), inserted);
        for d in &docs {
            assert!(frozen.query(d), "doc lost collapsing generations");
        }
    }
}
