//! Lock-free LSHBloom index: one [`AtomicBloomFilter`] per band.
//!
//! The structural twin of [`crate::index::LshBloomIndex`] — same band
//! geometry, same per-filter rate derivation (`p = 1-(1-p_eff)^(1/b)`,
//! §4.3), same single-pass insert-if-new semantics — but every operation
//! takes `&self`, so any number of threads insert and query without a
//! lock.
//!
//! ## Linearizability caveat
//!
//! `insert_if_new` is *not* linearizable across threads: two concurrent
//! inserts of near-identical documents can both return `false` ("new")
//! because each observes the filter before the other's bits land. Within
//! one [`super::batch::ConcurrentEngine::submit`] call this is repaired
//! by the intra-batch reconcile pass; callers driving this index directly
//! from unsynchronized threads (e.g. the service's per-connection path)
//! accept the race: the duplicate pair survives, which only costs a tiny
//! amount of recall for twins that arrive in the same microsecond —
//! never a false positive, and never a false negative once the inserting
//! thread synchronizes with the querier.

use super::atomic_bloom::AtomicBloomFilter;
use crate::index::lshbloom::LshBloomConfig;
use crate::index::BandIndex;
use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free per-band Bloom index.
pub struct ConcurrentLshBloomIndex {
    filters: Vec<AtomicBloomFilter>,
    config: LshBloomConfig,
    inserted: AtomicU64,
}

impl ConcurrentLshBloomIndex {
    /// Build from the same config the sequential index uses. The
    /// `blocked` flag is ignored (atomic filters are always the classic
    /// layout; blocking is a cache optimization for the sequential path).
    pub fn new(config: LshBloomConfig) -> Self {
        // Same geometry derivation as the sequential index — required for
        // `into_sequential` snapshots and cross-index `union_from`.
        let params = crate::index::LshBloomIndex::filter_params(&config);
        let filters = (0..config.lsh.num_bands)
            .map(|_| AtomicBloomFilter::new(params))
            .collect();
        Self { filters, config, inserted: AtomicU64::new(0) }
    }

    /// Index with every band filter mmap-backed under `dir`
    /// (`band{i:03}.bits`, freshly zeroed) — the durable variant: same
    /// lock-free semantics, but every `fetch_or` lands in a file, and
    /// `persist::write_checkpoint` on this index is an msync instead of
    /// a copy. Point `dir` at `/dev/shm/...` for the paper's
    /// DRAM-resident setup (§4.4.2) or any path for plain persistence.
    pub fn new_shm(config: LshBloomConfig, dir: &std::path::Path) -> crate::error::Result<Self> {
        std::fs::create_dir_all(dir)
            .map_err(|e| crate::error::Error::io(dir.display().to_string(), e))?;
        // A fresh index invalidates any checkpoint already in `dir`, so
        // the stale manifest must go *before* the filter files are
        // zeroed: if it survived and this process crashed before its
        // first checkpoint, a later restore would trust the old
        // manifest over the new empty filters (live mode skips
        // checksums) and skip documents whose bits are gone — silent
        // Bloom false negatives. Removal failure (other than the file
        // not existing) is therefore a hard error.
        for stale in [
            crate::persist::manifest::MANIFEST_FILE.to_string(),
            format!("{}.tmp", crate::persist::manifest::MANIFEST_FILE),
        ] {
            crate::persist::remove_file_if_exists(&dir.join(stale))?;
        }
        let params = crate::index::LshBloomIndex::filter_params(&config);
        let mut filters = Vec::with_capacity(config.lsh.num_bands);
        for band in 0..config.lsh.num_bands {
            let path = dir.join(crate::persist::manifest::band_file_name(band));
            filters.push(AtomicBloomFilter::new_shm(params, &path)?);
        }
        Ok(Self { filters, config, inserted: AtomicU64::new(0) })
    }

    /// Index adopting pre-built band filters (checkpoint restore).
    pub(crate) fn from_parts(
        filters: Vec<AtomicBloomFilter>,
        config: LshBloomConfig,
        inserted: u64,
    ) -> Self {
        debug_assert_eq!(filters.len(), config.lsh.num_bands);
        Self { filters, config, inserted: AtomicU64::new(inserted) }
    }

    /// The per-band filters (persistence internals).
    pub(crate) fn filters(&self) -> &[AtomicBloomFilter] {
        &self.filters
    }

    /// Fold an externally merged document count into the index counter
    /// (the from-file half of [`Self::union_from`]'s accounting).
    pub(crate) fn add_inserted(&self, n: u64) {
        self.inserted.fetch_add(n, Ordering::Relaxed);
    }

    /// Flush every mmap-backed band filter to its file (no-op for heap
    /// filters). See [`AtomicBloomFilter::sync`].
    pub fn sync(&self) -> crate::error::Result<()> {
        for f in &self.filters {
            f.sync()?;
        }
        Ok(())
    }

    /// The configuration this index was built with.
    pub fn config(&self) -> LshBloomConfig {
        self.config
    }

    /// Query without inserting (lock-free). `true` = any band collides.
    pub fn query(&self, band_hashes: &[u64]) -> bool {
        debug_assert_eq!(band_hashes.len(), self.filters.len());
        self.filters.iter().zip(band_hashes).any(|(f, &h)| f.contains(h))
    }

    /// Query + insert in one lock-free pass; `&self`, callable from any
    /// thread. Returns `true` if every probed bit of some band was
    /// already set (duplicate). Subject to the module-level
    /// linearizability caveat for concurrent twins.
    ///
    /// Once some band reports a collision the verdict is final, so the
    /// remaining bands switch from the verdict-tracking
    /// [`AtomicBloomFilter::insert`] to the cheaper
    /// [`AtomicBloomFilter::set`]: the same bits are still set (state
    /// parity with the sequential single-pass insert is what keeps later
    /// verdicts exact), but already-present bits are detected with a
    /// plain load instead of a contended `fetch_or` — for exact
    /// duplicates, whose bits are all present, the tail of the pass
    /// issues no RMWs at all.
    pub fn insert_if_new_shared(&self, band_hashes: &[u64]) -> bool {
        debug_assert_eq!(band_hashes.len(), self.filters.len());
        let mut dup = false;
        for (f, &h) in self.filters.iter().zip(band_hashes) {
            if dup {
                f.set(h);
            } else {
                dup = f.insert(h);
            }
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
        dup
    }

    /// Insert a document's bands without computing a verdict — the bulk
    /// path for callers that already decided the document's fate (the
    /// engine's phase-3 insert after its reconcile pass). Sets exactly
    /// the bits [`Self::insert_if_new_shared`] would, via the
    /// test-and-test-and-set [`AtomicBloomFilter::set`], so filter state
    /// — and every later verdict — is unchanged while already-present
    /// bits cost a plain load instead of a contended `fetch_or`.
    pub fn set_shared(&self, band_hashes: &[u64]) {
        debug_assert_eq!(band_hashes.len(), self.filters.len());
        for (f, &h) in self.filters.iter().zip(band_hashes) {
            f.set(h);
        }
        self.inserted.fetch_add(1, Ordering::Relaxed);
    }

    /// Bit-OR merge: fold every band filter of `other` into `self`
    /// (lock-free, geometry-checked — see
    /// [`AtomicBloomFilter::union_from`]). Panics when the two indexes
    /// disagree on band count or per-filter geometry.
    ///
    /// This is the sharded-aggregation primitive (paper §6): after the
    /// union, `self` reports a collision for every band vector either
    /// index would have reported one for, so cross-shard deduplication
    /// reduces to querying survivors against the running union — no
    /// re-insertion, no re-MinHashing. Concurrent inserts into `self`
    /// are safe during the merge; inserts racing into `other` may be
    /// missed, so synchronize with (e.g. join) every `other` writer
    /// first — see [`AtomicBloomFilter::union_from`] for the full
    /// memory-ordering contract.
    pub fn union_from(&self, other: &Self) {
        assert_eq!(
            self.filters.len(),
            other.filters.len(),
            "ConcurrentLshBloomIndex::union_from: band count mismatch ({} vs {})",
            self.filters.len(),
            other.filters.len()
        );
        for (dst, src) in self.filters.iter().zip(&other.filters) {
            dst.union_from(src);
        }
        self.inserted
            // lint: allow(ordering-discipline) — element counter, not a verdict
            .fetch_add(other.inserted.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Fill ratio of each filter (diagnostics).
    pub fn fill_ratios(&self) -> Vec<f64> {
        self.filters.iter().map(|f| f.fill_ratio()).collect()
    }

    /// Publish per-band fill-ratio / estimated-FP gauges plus the
    /// any-band FP estimate (`engine.fp_estimate`) into the global
    /// observability registry. Popcounts are strided
    /// ([`AtomicBloomFilter::fill_ratio_sampled`]), so this is cheap
    /// enough to run on every checkpoint and every metrics scrape.
    pub fn refresh_fill_gauges(&self) {
        let miss = super::publish_band_fill_gauges(&self.filters, 0);
        crate::obs::global().gauge("engine.fp_estimate").set(1.0 - miss);
    }

    /// Number of bands.
    pub fn num_bands(&self) -> usize {
        self.filters.len()
    }

    /// Documents inserted so far.
    pub fn len(&self) -> u64 {
        // Element counter, not a verdict.
        self.inserted.load(Ordering::Relaxed) // lint: allow(ordering-discipline)
    }

    /// True when nothing has been inserted.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of backing storage (static: fixed by capacity, not docs).
    pub fn disk_bytes(&self) -> u64 {
        self.filters.iter().map(|f| f.size_bytes()).sum()
    }

    /// Freeze into a persistable sequential [`crate::index::LshBloomIndex`]
    /// snapshot. Consumes the index; exclusive ownership is the
    /// synchronization point, so the snapshot holds every insert that
    /// happened before the caller obtained `self`.
    pub fn into_sequential(self) -> crate::index::LshBloomIndex {
        // lint: allow(ordering-discipline) — exclusive ownership is the sync point
        let inserted = self.inserted.load(Ordering::Relaxed);
        let filters = self
            .filters
            .into_iter()
            .map(|f| f.into_filter())
            .collect::<Vec<_>>();
        crate::index::LshBloomIndex::from_filters(filters, self.config, inserted)
    }
}

// The trait's `insert_if_new` takes `&mut self`; routing it through the
// shared-path method lets the concurrent index drop into any code written
// against `BandIndex` (tests, the shard pipeline) at zero cost.
impl BandIndex for ConcurrentLshBloomIndex {
    fn query(&self, band_hashes: &[u64]) -> bool {
        ConcurrentLshBloomIndex::query(self, band_hashes)
    }

    fn insert_if_new(&mut self, band_hashes: &[u64]) -> bool {
        self.insert_if_new_shared(band_hashes)
    }

    fn num_bands(&self) -> usize {
        ConcurrentLshBloomIndex::num_bands(self)
    }

    fn len(&self) -> u64 {
        ConcurrentLshBloomIndex::len(self)
    }

    fn disk_bytes(&self) -> u64 {
        ConcurrentLshBloomIndex::disk_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minhash::LshParams;
    use crate::rng::Xoshiro256pp;

    fn cfg(bands: usize, rows: usize, n: u64) -> LshBloomConfig {
        LshBloomConfig {
            lsh: LshParams { num_bands: bands, rows_per_band: rows },
            p_effective: 1e-8,
            expected_docs: n,
            blocked: false,
        }
    }

    fn random_bands(rng: &mut Xoshiro256pp, b: usize) -> Vec<u64> {
        (0..b).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn matches_sequential_index_verdicts() {
        let config = cfg(9, 13, 10_000);
        let concurrent = ConcurrentLshBloomIndex::new(config);
        let mut sequential = crate::index::LshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(11);
        for _ in 0..5_000 {
            let bands = random_bands(&mut rng, 9);
            assert_eq!(
                concurrent.insert_if_new_shared(&bands),
                sequential.insert_if_new(&bands),
            );
        }
        for _ in 0..20_000 {
            let bands = random_bands(&mut rng, 9);
            assert_eq!(concurrent.query(&bands), sequential.query(&bands));
        }
        assert_eq!(concurrent.disk_bytes(), sequential.disk_bytes());
        assert_eq!(concurrent.len(), sequential.len());
    }

    #[test]
    fn single_band_match_is_duplicate() {
        let idx = ConcurrentLshBloomIndex::new(cfg(4, 2, 1000));
        idx.insert_if_new_shared(&[1, 2, 3, 4]);
        assert!(idx.query(&[9, 9, 3, 9]));
        assert!(!idx.query(&[9, 9, 9, 9]));
    }

    #[test]
    fn short_circuited_insert_keeps_exact_state_parity() {
        // Low-entropy band values force the duplicate verdict early in
        // the band pass, exercising the `set` tail on nearly every
        // insert. State must stay bit-for-bit equal to the sequential
        // index: identical verdicts during ingest AND identical answers
        // on every later query (a dropped tail-band insert would show up
        // here as a sequential-true / concurrent-false divergence).
        let config = cfg(7, 5, 5_000);
        let concurrent = ConcurrentLshBloomIndex::new(config);
        let mut sequential = crate::index::LshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(77);
        let docs: Vec<Vec<u64>> =
            (0..3_000).map(|_| (0..7).map(|_| rng.next_u64() % 40).collect()).collect();
        for d in &docs {
            assert_eq!(
                concurrent.insert_if_new_shared(d),
                sequential.insert_if_new(d),
                "verdict diverged on {d:?}"
            );
        }
        for _ in 0..20_000 {
            let probe: Vec<u64> = (0..7).map(|_| rng.next_u64() % 60).collect();
            assert_eq!(
                concurrent.query(&probe),
                sequential.query(&probe),
                "post-ingest state diverged on {probe:?}"
            );
        }
        assert_eq!(concurrent.len(), sequential.len());
    }

    #[test]
    fn union_from_merges_membership_of_both_indexes() {
        let config = cfg(6, 4, 10_000);
        let a = ConcurrentLshBloomIndex::new(config);
        let b = ConcurrentLshBloomIndex::new(config);
        let combined = ConcurrentLshBloomIndex::new(config);
        let mut rng = Xoshiro256pp::seeded(41);
        let docs_a: Vec<Vec<u64>> = (0..1_500).map(|_| random_bands(&mut rng, 6)).collect();
        let docs_b: Vec<Vec<u64>> = (0..1_500).map(|_| random_bands(&mut rng, 6)).collect();
        for d in &docs_a {
            a.insert_if_new_shared(d);
            combined.insert_if_new_shared(d);
        }
        for d in &docs_b {
            b.insert_if_new_shared(d);
            combined.insert_if_new_shared(d);
        }
        a.union_from(&b);
        for d in docs_a.iter().chain(&docs_b) {
            assert!(a.query(d), "doc lost in union");
        }
        assert_eq!(a.len(), 3_000, "union accumulates document counts");
        // Exact bit parity with single-index ingest of the same stream.
        assert_eq!(a.fill_ratios(), combined.fill_ratios());
    }

    #[test]
    #[should_panic(expected = "band count mismatch")]
    fn union_from_rejects_band_count_mismatch() {
        let a = ConcurrentLshBloomIndex::new(cfg(6, 4, 1_000));
        let b = ConcurrentLshBloomIndex::new(cfg(5, 4, 1_000));
        a.union_from(&b);
    }

    #[test]
    #[should_panic(expected = "geometry mismatch")]
    fn union_from_rejects_filter_geometry_mismatch() {
        // Same band count, different capacity -> different per-filter m.
        let a = ConcurrentLshBloomIndex::new(cfg(6, 4, 1_000));
        let b = ConcurrentLshBloomIndex::new(cfg(6, 4, 50_000));
        a.union_from(&b);
    }

    #[test]
    fn concurrent_inserts_never_lose_documents() {
        let idx = ConcurrentLshBloomIndex::new(cfg(6, 8, 50_000));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let idx = &idx;
                s.spawn(move || {
                    let mut rng = Xoshiro256pp::seeded(500 + t);
                    for _ in 0..2_000 {
                        idx.insert_if_new_shared(&random_bands(&mut rng, 6));
                    }
                });
            }
        });
        assert_eq!(idx.len(), 16_000);
        for t in 0..8u64 {
            let mut rng = Xoshiro256pp::seeded(500 + t);
            for _ in 0..2_000 {
                assert!(idx.query(&random_bands(&mut rng, 6)), "doc lost under contention");
            }
        }
    }

    #[test]
    fn into_sequential_preserves_contents() {
        let idx = ConcurrentLshBloomIndex::new(cfg(5, 3, 5000));
        let mut rng = Xoshiro256pp::seeded(3);
        let docs: Vec<Vec<u64>> = (0..500).map(|_| random_bands(&mut rng, 5)).collect();
        for d in &docs {
            idx.insert_if_new_shared(d);
        }
        let (len, disk) = (idx.len(), idx.disk_bytes());
        let frozen = idx.into_sequential();
        assert_eq!(frozen.len(), len);
        assert_eq!(frozen.disk_bytes(), disk);
        for d in &docs {
            assert!(frozen.query(d));
        }
    }
}
