//! Batched multi-threaded ingest: `submit(Vec<Doc>) -> Vec<Decision>`.
//!
//! A [`ConcurrentEngine`] owns a band preparer and a
//! [`ConcurrentLshBloomIndex`] and processes document batches with **no
//! global lock**:
//!
//! 1. **Parallel prepare + probe** — a scoped worker pool (the
//!    `std::thread::scope` idiom from `pipeline::orchestrator`) MinHashes
//!    each document and probes the lock-free index *read-only*, yielding
//!    a pre-batch duplicate verdict per document.
//! 2. **Intra-batch reconcile (sequential, cheap)** — concurrent twins
//!    inside one batch cannot see each other through the filter probes of
//!    step 1 (they all ran against the pre-batch snapshot), so a single
//!    O(docs × bands) hash-set pass replays the batch in submission
//!    order: a document is a duplicate iff the pre-batch probe said so
//!    *or* an earlier document in the batch shares a band hash. This is
//!    exactly the sequential decider's in-batch collision rule (an exact
//!    band-hash match always sets identical filter bits), minus the
//!    ~`p_effective`-probability incremental false positives a partially
//!    filled filter could add — the engine is never *less* accurate.
//! 3. **Parallel insert** — every document's band hashes are folded into
//!    the atomic filters via `fetch_or` across the worker pool.
//!
//! Because step 2 runs in submission order, a batch's survivor set is
//! deterministic and matches the sequential [`crate::methods::Decider`]
//! (enforced by `rust/tests/engine_equivalence.rs`).
//!
//! ## When to prefer which path
//!
//! * **Classic (`Mutex<LshBloomDecider>` / `pipeline::run_stream`)** —
//!   exact stream-order semantics, supports every method (not just
//!   LSHBloom), and the blocked-filter layout. Right for evaluation runs
//!   where verdict order must match the paper's sequential definition
//!   bit-for-bit, including in-batch filter false positives.
//! * **Concurrent engine** — wins whenever multiple threads contend for
//!   the index: the batched `submit` path scales prepare *and* decide
//!   with cores, and the per-document [`ConcurrentEngine::insert_one`]
//!   path lets service connections ingest with zero queueing (accepting
//!   the same-microsecond-twin caveat documented in
//!   [`super::concurrent_index`]).

use super::concurrent_index::ConcurrentLshBloomIndex;
use crate::config::PipelineConfig;
use crate::corpus::Doc;
use crate::index::lshbloom::LshBloomConfig;
use crate::methods::lshbloom::BandPreparer;
use crate::methods::{Prepared, Preparer};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Verdict for one submitted document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    /// The document's `Doc::id`.
    pub id: u64,
    /// `true` = duplicate of earlier content (this batch or any before).
    pub duplicate: bool,
}

/// Documents per work unit handed to a pool worker. Small enough to
/// balance skewed document lengths, large enough to amortize the cursor
/// fetch_add and the per-chunk result push.
const CHUNK: usize = 32;

/// Run `work` over [`CHUNK`]-sized index ranges of `0..n` on up to
/// `workers` scoped threads; ranges are claimed off an atomic cursor, so
/// skewed per-range costs self-balance. Shared with the band-sliced
/// engine ([`super::band_slice`]), whose prepare phase is the same
/// pooled MinHash.
pub(crate) fn for_chunks<F: Fn(std::ops::Range<usize>) + Sync>(workers: usize, n: usize, work: F) {
    if n == 0 {
        return;
    }
    let threads = workers.min(n.div_ceil(CHUNK)).max(1);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let start = cursor.fetch_add(CHUNK, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                work(start..n.min(start + CHUNK));
            });
        }
    });
}

/// [`for_chunks`] with per-item results gathered back into submission
/// order: `f` maps an index range to that range's results, chunks land
/// under a mutex tagged by start index, and the final Vec is the
/// re-ordered concatenation. The one home of the ordered-collect idiom
/// every batched probe/prepare pass uses.
pub(crate) fn for_chunks_collect<T, F>(workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> Vec<T> + Sync,
{
    let slots: Mutex<Vec<(usize, Vec<T>)>> = Mutex::new(Vec::with_capacity(n.div_ceil(CHUNK)));
    for_chunks(workers, n, |range| {
        let start = range.start;
        let chunk = f(range);
        slots.lock().unwrap().push((start, chunk));
    });
    let mut chunks = slots.into_inner().unwrap();
    chunks.sort_unstable_by_key(|(start, _)| *start);
    chunks.into_iter().flat_map(|(_, c)| c).collect()
}

/// Lock-free deduplication engine: band preparer + atomic Bloom index.
pub struct ConcurrentEngine {
    preparer: Arc<dyn Preparer>,
    index: ConcurrentLshBloomIndex,
    workers: usize,
    docs: AtomicU64,
    duplicates: AtomicU64,
}

impl ConcurrentEngine {
    /// Build from the pipeline config (native Mix64 backend, same band
    /// geometry derivation as `methods::lshbloom`). When
    /// `cfg.rotate_watermark` is nonzero the index rotates into a fresh
    /// generation whenever sampled fill crosses the watermark
    /// ([`ConcurrentLshBloomIndex::enable_rotation`]), so a stream that
    /// outgrows `expected_docs` keeps its false-positive budget instead
    /// of saturating.
    pub fn from_config(cfg: &PipelineConfig) -> Self {
        let preparer = BandPreparer::from_config(cfg);
        let index_cfg = LshBloomConfig::new(preparer.lsh, cfg.p_effective, cfg.expected_docs);
        let mut index = ConcurrentLshBloomIndex::new(index_cfg);
        index.enable_rotation(cfg.rotate_watermark);
        Self::with_index(Arc::new(preparer), index, cfg.effective_workers(), 0, 0)
    }

    /// Build from an explicit band-producing preparer (e.g. the XLA
    /// artifact preparer) and index config.
    pub fn with_preparer(
        preparer: Arc<dyn Preparer>,
        index_cfg: LshBloomConfig,
        workers: usize,
    ) -> Self {
        Self::with_index(preparer, ConcurrentLshBloomIndex::new(index_cfg), workers, 0, 0)
    }

    fn with_index(
        preparer: Arc<dyn Preparer>,
        index: ConcurrentLshBloomIndex,
        workers: usize,
        docs: u64,
        duplicates: u64,
    ) -> Self {
        Self {
            preparer,
            index,
            workers: workers.max(1),
            docs: AtomicU64::new(docs),
            duplicates: AtomicU64::new(duplicates),
        }
    }

    /// Engine whose filters are mmap-backed under `dir` (fresh, zeroed):
    /// same verdicts as [`Self::from_config`], but every insert lands in
    /// a file and [`Self::checkpoint`] into the same `dir` is an msync +
    /// manifest rewrite instead of a full copy.
    pub fn new_persistent(
        cfg: &PipelineConfig,
        dir: &std::path::Path,
    ) -> crate::error::Result<Self> {
        let preparer = BandPreparer::from_config(cfg);
        let index_cfg = LshBloomConfig::new(preparer.lsh, cfg.p_effective, cfg.expected_docs);
        let mut index = ConcurrentLshBloomIndex::new_shm(index_cfg, dir)?;
        index.enable_rotation(cfg.rotate_watermark);
        Ok(Self::with_index(Arc::new(preparer), index, cfg.effective_workers(), 0, 0))
    }

    /// Rebuild an engine from the checkpoint in `dir` (written by
    /// [`Self::checkpoint`]), restoring filter bits and the
    /// docs/duplicates counters recorded in the manifest.
    ///
    /// Geometry derived from `cfg` must match the manifest exactly or
    /// restore refuses (a mismatched filter would answer `false` for
    /// keys it never probed — Bloom false negatives). With `mmap` the
    /// checkpoint files become the live backing store (warm start /
    /// resume-in-place); without it the bits are copied to the heap and
    /// `dir` is left untouched.
    pub fn restore(
        cfg: &PipelineConfig,
        dir: &std::path::Path,
        mmap: bool,
    ) -> crate::error::Result<Self> {
        let preparer = BandPreparer::from_config(cfg);
        let index_cfg = LshBloomConfig::new(preparer.lsh, cfg.p_effective, cfg.expected_docs);
        let (mut index, manifest) = crate::persist::restore_index(dir, &index_cfg, mmap)?;
        index.enable_rotation(cfg.rotate_watermark);
        Ok(Self::with_index(
            Arc::new(preparer),
            index,
            cfg.effective_workers(),
            manifest.docs,
            manifest.duplicates,
        ))
    }

    /// Persist the engine's full state into `dir` (filter bits + a
    /// versioned manifest with geometry, counters, and checksums — see
    /// [`crate::persist`]). Callable between batches on a live engine;
    /// filters already mmap-backed in `dir` are msync'd in place, any
    /// others are copied out as a cold snapshot.
    pub fn checkpoint(&self, dir: &std::path::Path) -> crate::error::Result<()> {
        let (docs, duplicates) = self.stats();
        crate::persist::write_checkpoint(&self.index, docs, duplicates, dir)?;
        // A checkpoint walks every filter anyway — refresh the fill /
        // estimated-FP gauges while the state is quiescent.
        self.index.refresh_fill_gauges();
        Ok(())
    }

    /// The underlying lock-free index.
    pub fn index(&self) -> &ConcurrentLshBloomIndex {
        &self.index
    }

    /// Worker threads used per `submit` call.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// (documents processed, duplicates flagged) across all operations.
    pub fn stats(&self) -> (u64, u64) {
        // Statistics counters, not verdicts.
        // lint: allow(ordering-discipline)
        (self.docs.load(Ordering::Relaxed), self.duplicates.load(Ordering::Relaxed))
    }

    /// Index footprint in bytes (static: sized by capacity at build).
    pub fn disk_bytes(&self) -> u64 {
        self.index.disk_bytes()
    }

    /// Deduplicate one batch. Verdicts come back in submission order and
    /// are deterministic for a deterministic preparer (see module docs).
    ///
    /// # Examples
    ///
    /// ```
    /// use lshbloom::config::PipelineConfig;
    /// use lshbloom::corpus::Doc;
    /// use lshbloom::engine::ConcurrentEngine;
    ///
    /// let cfg = PipelineConfig {
    ///     num_perms: 128,
    ///     threshold: 0.5,
    ///     expected_docs: 10_000,
    ///     workers: 4,
    ///     ..Default::default()
    /// };
    /// let engine = ConcurrentEngine::from_config(&cfg);
    /// let batch = vec![
    ///     Doc { id: 0, text: "the quick brown fox jumps over the lazy dog".into() },
    ///     Doc { id: 1, text: "the quick brown fox jumps over the lazy dog".into() },
    ///     Doc { id: 2, text: "completely unrelated content with other words".into() },
    /// ];
    /// let verdicts: Vec<bool> = engine.submit(batch).iter().map(|d| d.duplicate).collect();
    /// // The exact twin is reconciled within the batch; the distinct
    /// // document survives.
    /// assert_eq!(verdicts, [false, true, false]);
    /// assert_eq!(engine.stats(), (3, 1));
    /// ```
    pub fn submit(&self, docs: Vec<Doc>) -> Vec<Decision> {
        self.submit_with_bands(&docs).0
    }

    /// [`Self::submit`], additionally returning each document's band
    /// hashes (submission order, duplicates included).
    ///
    /// This is the sharded-aggregation hook (`pipeline::shard`): phase 1
    /// already MinHashes every document once, and the returned bands let
    /// phase 2 recheck shard survivors against the merged cross-shard
    /// filter as a pure `query` — zero re-MinHashing anywhere.
    pub fn submit_with_bands(&self, docs: &[Doc]) -> (Vec<Decision>, Vec<Vec<u64>>) {
        let n = docs.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }

        // Phase 1: parallel prepare + read-only probe of the pre-batch
        // filter state, gathered back into submission order.
        let phase1 = crate::obs::span("engine.submit.prepare_probe");
        let prepared: Vec<(Vec<u64>, bool)> = for_chunks_collect(self.workers, n, |range| {
            self.preparer
                .prepare_batch(&docs[range])
                .into_iter()
                .map(|prep| {
                    let Prepared::Bands(bands) = prep else {
                        panic!("ConcurrentEngine requires a band-producing preparer");
                    };
                    let pre_dup = self.index.query(&bands);
                    (bands, pre_dup)
                })
                .collect()
        });
        drop(phase1);
        debug_assert_eq!(prepared.len(), n);

        // Phase 2: sequential intra-batch reconcile. Catches twins the
        // parallel probes could not see (both probed pre-batch state).
        // One shared rule ([`super::band_slice::reconcile_in_batch`]) —
        // the band-sliced engine and the router apply the identical
        // function, so batched verdicts cannot drift between serving
        // paths.
        let (bands_batch, pre): (Vec<Vec<u64>>, Vec<bool>) = prepared.into_iter().unzip();
        let phase2 = crate::obs::span("engine.submit.reconcile");
        let verdicts = super::band_slice::reconcile_in_batch(&bands_batch, &pre);
        drop(phase2);
        let decisions: Vec<Decision> = docs
            .iter()
            .zip(&verdicts)
            .map(|(doc, &duplicate)| Decision { id: doc.id, duplicate })
            .collect();
        let duplicates = verdicts.iter().filter(|&&d| d).count() as u64;

        // Phase 3: parallel lock-free insert of every document's bands.
        // Verdicts were fixed by the reconcile pass, so the verdict-free
        // `set_shared` path applies: same bits, but bands whose bits are
        // already present cost plain loads, not contended fetch_ors.
        let phase3 = crate::obs::span("engine.submit.insert");
        for_chunks(self.workers, n, |range| {
            for bands in &bands_batch[range] {
                self.index.set_shared(bands);
            }
        });
        drop(phase3);

        self.docs.fetch_add(n as u64, Ordering::Relaxed);
        self.duplicates.fetch_add(duplicates, Ordering::Relaxed);
        (decisions, bands_batch)
    }

    /// Single-document query+insert on the caller's thread, fully
    /// lock-free — the service fast path. Subject to the concurrent-twin
    /// caveat ([`super::concurrent_index`]); use [`Self::submit`] when
    /// batch-internal exactness matters.
    pub fn insert_one(&self, doc: &Doc) -> bool {
        let prepared = self.preparer.prepare_batch(std::slice::from_ref(doc));
        let Prepared::Bands(ref bands) = prepared[0] else {
            panic!("ConcurrentEngine requires a band-producing preparer");
        };
        let dup = self.index.insert_if_new_shared(bands);
        self.docs.fetch_add(1, Ordering::Relaxed);
        self.duplicates.fetch_add(dup as u64, Ordering::Relaxed);
        dup
    }

    /// Band-level query + insert: the document was already MinHashed
    /// elsewhere (a router fanning `check_bands` over backends) and
    /// arrives as its `b` band hashes. Same verdict and same bits as
    /// [`Self::insert_one`] on the originating document.
    pub fn insert_bands(&self, band_hashes: &[u64]) -> bool {
        let dup = self.index.insert_if_new_shared(band_hashes);
        self.docs.fetch_add(1, Ordering::Relaxed);
        self.duplicates.fetch_add(dup as u64, Ordering::Relaxed);
        dup
    }

    /// Band-level query only (no insert, no stats mutation).
    pub fn query_bands(&self, band_hashes: &[u64]) -> bool {
        self.index.query(band_hashes)
    }

    /// Band-level batch: probe every band vector read-only against the
    /// pre-batch state, then fold all of them in (verdict-free `set`
    /// path). Returns the *pre-batch* verdicts — the caller applies the
    /// intra-batch reconcile ([`super::band_slice::reconcile_in_batch`])
    /// to get final verdicts identical to [`Self::submit`]. The docs
    /// counter advances by the batch size; the duplicates counter
    /// advances by the pre-batch count (the caller's reconcile may add
    /// in-batch twins it alone can see).
    ///
    /// This is the per-backend hot path of the routed serving tier
    /// (`check_bands_batch`), so both passes run on the worker pool —
    /// the same [`for_chunks`] fan-out `submit` uses — with the probe
    /// pass fully joined before any insert begins (the pre-batch
    /// contract).
    pub fn probe_insert_bands(&self, bands_batch: &[Vec<u64>]) -> Vec<bool> {
        let n = bands_batch.len();
        if n == 0 {
            return Vec::new();
        }
        let probe = crate::obs::span("engine.bands.probe");
        let pre: Vec<bool> = for_chunks_collect(self.workers, n, |range| {
            bands_batch[range].iter().map(|b| self.index.query(b)).collect()
        });
        drop(probe);
        let insert = crate::obs::span("engine.bands.insert");
        for_chunks(self.workers, n, |range| {
            for bands in &bands_batch[range] {
                self.index.set_shared(bands);
            }
        });
        drop(insert);
        self.docs.fetch_add(n as u64, Ordering::Relaxed);
        let dups = pre.iter().filter(|&&d| d).count() as u64;
        self.duplicates.fetch_add(dups, Ordering::Relaxed);
        pre
    }

    /// Single-document query (no insert, no stats mutation).
    pub fn query_one(&self, doc: &Doc) -> bool {
        let prepared = self.preparer.prepare_batch(std::slice::from_ref(doc));
        let Prepared::Bands(ref bands) = prepared[0] else {
            panic!("ConcurrentEngine requires a band-producing preparer");
        };
        self.index.query(bands)
    }

    /// Freeze into a persistable sequential index snapshot.
    pub fn into_index(self) -> crate::index::LshBloomIndex {
        self.index.into_sequential()
    }

    /// Take the live lock-free index out of the engine (dropping the
    /// preparer). The sharded pipeline uses this after phase 1 to merge
    /// per-shard filters via [`ConcurrentLshBloomIndex::union_from`]
    /// without freezing them first; exclusive ownership of the engine is
    /// the synchronization point, so the index holds every insert from
    /// every prior `submit`.
    pub fn into_concurrent_index(self) -> ConcurrentLshBloomIndex {
        self.index
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{DatasetSpec, LabeledCorpus};
    use crate::minhash::{optimal_param, MinHasher, PermFamily};

    fn cfg() -> PipelineConfig {
        PipelineConfig {
            num_perms: 128,
            threshold: 0.5,
            expected_docs: 10_000,
            workers: 4,
            ..Default::default()
        }
    }

    #[test]
    fn exact_duplicates_within_one_batch_are_reconciled() {
        let engine = ConcurrentEngine::from_config(&cfg());
        let a = Doc { id: 0, text: "the quick brown fox jumps over the lazy dog".into() };
        let b = a.clone();
        let c = Doc { id: 2, text: "completely unrelated content with other words".into() };
        let decisions = engine.submit(vec![a, b, c]);
        assert_eq!(
            decisions.iter().map(|d| d.duplicate).collect::<Vec<_>>(),
            vec![false, true, false],
            "twin in the same batch must be caught by the reconcile pass"
        );
        let (docs, dups) = engine.stats();
        assert_eq!((docs, dups), (3, 1));
    }

    #[test]
    fn duplicates_across_batches_are_caught_by_the_filter() {
        let engine = ConcurrentEngine::from_config(&cfg());
        let doc = Doc { id: 0, text: "cross batch duplicate detection test".into() };
        let first = engine.submit(vec![doc.clone()]);
        assert!(!first[0].duplicate);
        let second = engine.submit(vec![Doc { id: 1, ..doc }]);
        assert!(second[0].duplicate);
    }

    #[test]
    fn empty_batch() {
        let engine = ConcurrentEngine::from_config(&cfg());
        assert!(engine.submit(Vec::new()).is_empty());
        assert_eq!(engine.stats(), (0, 0));
    }

    #[test]
    fn insert_one_matches_submit_semantics() {
        let engine = ConcurrentEngine::from_config(&cfg());
        let doc = Doc { id: 7, text: "single document fast path".into() };
        assert!(!engine.query_one(&doc));
        assert!(!engine.insert_one(&doc));
        assert!(engine.query_one(&doc));
        assert!(engine.insert_one(&doc));
    }

    #[test]
    fn submit_with_bands_returns_band_hashes_in_submission_order() {
        let config = cfg();
        let engine = ConcurrentEngine::from_config(&config);
        let docs: Vec<Doc> = (0..20)
            .map(|i| Doc { id: i, text: format!("band return check document {}", i % 7) })
            .collect();
        let (decisions, bands) = engine.submit_with_bands(&docs);
        assert_eq!(decisions.len(), docs.len());
        assert_eq!(bands.len(), docs.len());
        // Bands match an independent preparer with identical geometry
        // (duplicates included — they are what phase 2 reuses).
        let lsh = optimal_param(config.threshold, config.num_perms);
        let preparer = BandPreparer {
            hasher: MinHasher::new(PermFamily::Mix64, lsh.rows_used(), config.ngram),
            lsh,
        };
        for (doc, got) in docs.iter().zip(&bands) {
            let prep = preparer.prepare_batch(std::slice::from_ref(doc));
            let Prepared::Bands(ref expected) = prep[0] else { unreachable!() };
            assert_eq!(got, expected, "bands diverged for doc {}", doc.id);
            // Every returned band vector must already be in the filter.
            assert!(engine.index().query(got));
        }
        // And the two entry points agree verdict-for-verdict.
        let engine2 = ConcurrentEngine::from_config(&config);
        let via_submit = engine2.submit(docs.clone());
        assert_eq!(decisions, via_submit);
    }

    #[test]
    fn batched_verdicts_match_sequential_method() {
        let corpus = LabeledCorpus::build(DatasetSpec::testing(13, 300, 0.5));
        let mut seq =
            crate::methods::lshbloom::lshbloom_method(&cfg(), PermFamily::Mix64);
        let expected = seq.process_all(&corpus.docs);
        for batch_size in [1usize, 7, 64, 300] {
            let engine = ConcurrentEngine::from_config(&cfg());
            let mut verdicts = Vec::new();
            for chunk in corpus.docs.chunks(batch_size) {
                let batch: Vec<Doc> = chunk.iter().map(|ld| ld.doc.clone()).collect();
                verdicts.extend(engine.submit(batch).into_iter().map(|d| d.duplicate));
            }
            assert_eq!(verdicts, expected, "batch_size={batch_size}");
        }
    }

    #[test]
    fn checkpoint_restore_roundtrip_preserves_state() {
        let dir = std::env::temp_dir().join(format!("lshbloom-eng-ckpt-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let config = cfg();
        let engine = ConcurrentEngine::from_config(&config);
        let docs: Vec<Doc> = (0..40)
            .map(|i| Doc { id: i, text: format!("checkpoint doc {}", i % 13) })
            .collect();
        engine.submit(docs.clone());
        let before = engine.stats();
        engine.checkpoint(&dir).unwrap();
        // Heap restore: bits copied out, dir untouched afterwards.
        let restored = ConcurrentEngine::restore(&config, &dir, false).unwrap();
        assert_eq!(restored.stats(), before, "counters must survive the manifest");
        for doc in &docs {
            assert!(restored.query_one(doc), "restored engine lost doc {}", doc.id);
        }
        // Mmap restore re-attaches the files in place.
        let warm = ConcurrentEngine::restore(&config, &dir, true).unwrap();
        assert_eq!(warm.stats(), before);
        for doc in &docs {
            assert!(warm.query_one(doc));
        }
        drop(warm);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_mismatched_geometry() {
        let dir = std::env::temp_dir().join(format!("lshbloom-eng-geo-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let config = cfg();
        let engine = ConcurrentEngine::from_config(&config);
        engine.submit(vec![Doc { id: 0, text: "geometry guard document".into() }]);
        engine.checkpoint(&dir).unwrap();
        let mut other = config.clone();
        other.expected_docs *= 2; // different filter sizing
        let err = ConcurrentEngine::restore(&other, &dir, false).unwrap_err();
        assert!(err.to_string().contains("geometry mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn into_index_snapshot_queries_like_live_engine() {
        let engine = ConcurrentEngine::from_config(&cfg());
        let docs: Vec<Doc> = (0..50)
            .map(|i| Doc { id: i, text: format!("snapshot document number {i} content") })
            .collect();
        engine.submit(docs.clone());
        let frozen = engine.into_index();
        assert_eq!(frozen.len(), 50);
        use crate::index::BandIndex as _;
        assert!(frozen.disk_bytes() > 0);
    }
}
