//! Bloom filters (paper §2.4, §4.5) — the space-efficient probabilistic
//! membership substrate LSHBloom's index is built from.
//!
//! * [`BloomFilter`] — contiguous bit array + k hash probes (double
//!   hashing). Contiguity is the §4.5 "cache-aware data layout" point:
//!   a query touches k cache lines with no pointer chasing.
//! * [`params`] — optimal sizing: `m = -n·ln p / (ln 2)²`,
//!   `k = (m/n)·ln 2` (§4.5, after Bender et al.).
//! * [`shm`] — a `/dev/shm`-backed (or any mmap-able path) bit array so
//!   the index lives in DRAM with file persistence (§4.4.2 codesign).

pub mod blocked;
pub mod filter;
pub mod params;
pub mod scalable;
pub mod shm;

pub use blocked::BlockedBloomFilter;
pub use filter::{probe_pair, BloomFilter};
pub use params::{optimal_bits, optimal_hashes, BloomParams};
pub use scalable::ScalableBloomFilter;
pub use shm::ShmBitArray;
