//! mmap-backed bit arrays for `/dev/shm`-resident Bloom filters (§4.4.2).
//!
//! The paper hosts its filters in node-local shared-memory segments so the
//! index lives in DRAM with file semantics (persistence across pipeline
//! stages, observable by other processes, swap-backed by local SSD).
//! This module implements that with `mmap(MAP_SHARED)` over a regular
//! file — point it at `/dev/shm/...` to get the paper's exact setup, or
//! at any filesystem path for plain persistence.

use crate::error::{Error, Result};
use std::fs::OpenOptions;
use std::os::fd::AsRawFd;
use std::path::{Path, PathBuf};

/// A u64-word bit array backed by a shared file mapping.
pub struct ShmBitArray {
    ptr: *mut u64,
    words: usize,
    path: PathBuf,
}

// The mapping is owned exclusively by this struct; concurrent mutation is
// prevented by &mut discipline, matching Vec<u64> semantics.
unsafe impl Send for ShmBitArray {}

impl ShmBitArray {
    /// Create (or truncate) a file of `words * 8` bytes and map it shared.
    pub fn create(path: &Path, words: usize) -> Result<Self> {
        Self::open_impl(path, words, true)
    }

    /// Map an existing array created by [`ShmBitArray::create`].
    pub fn open(path: &Path, words: usize) -> Result<Self> {
        Self::open_impl(path, words, false)
    }

    fn open_impl(path: &Path, words: usize, truncate: bool) -> Result<Self> {
        assert!(words > 0);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(truncate)
            .open(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let bytes = words * 8;
        file.set_len(bytes as u64)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(Error::io(
                path.display().to_string(),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(Self { ptr: ptr as *mut u64, words, path: path.to_path_buf() })
    }

    /// The words as an immutable slice.
    #[inline(always)]
    pub fn words(&self) -> &[u64] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.words) }
    }

    /// The words as a mutable slice.
    #[inline(always)]
    pub fn words_mut(&mut self) -> &mut [u64] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.words) }
    }

    /// Flush dirty pages to the backing file (msync).
    pub fn sync(&self) -> Result<()> {
        let rc = unsafe { libc::msync(self.ptr as *mut _, self.words * 8, libc::MS_SYNC) };
        if rc != 0 {
            return Err(Error::io(
                self.path.display().to_string(),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(())
    }

    /// Backing path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for ShmBitArray {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.ptr as *mut _, self.words * 8);
        }
    }
}

/// Pick the default shared-memory directory: `/dev/shm` when present
/// (Linux), falling back to the system temp dir.
pub fn default_shm_dir() -> PathBuf {
    let shm = PathBuf::from("/dev/shm");
    if shm.is_dir() {
        shm
    } else {
        std::env::temp_dir()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lshbloom-shm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn create_write_reopen() {
        let path = tmp("a.bits");
        {
            let mut arr = ShmBitArray::create(&path, 16).unwrap();
            arr.words_mut()[0] = 0xDEAD_BEEF;
            arr.words_mut()[15] = u64::MAX;
            arr.sync().unwrap();
        }
        {
            let arr = ShmBitArray::open(&path, 16).unwrap();
            assert_eq!(arr.words()[0], 0xDEAD_BEEF);
            assert_eq!(arr.words()[15], u64::MAX);
            assert_eq!(arr.words()[7], 0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn create_truncates_existing() {
        let path = tmp("b.bits");
        {
            let mut arr = ShmBitArray::create(&path, 4).unwrap();
            arr.words_mut().fill(u64::MAX);
            arr.sync().unwrap();
        }
        {
            let arr = ShmBitArray::create(&path, 4).unwrap();
            assert!(arr.words().iter().all(|&w| w == 0), "create must zero");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_path_is_io_error() {
        let r = ShmBitArray::create(Path::new("/nonexistent-dir-xyz/f.bits"), 4);
        assert!(r.is_err());
    }

    #[test]
    fn shm_dir_exists() {
        assert!(default_shm_dir().is_dir());
    }
}
