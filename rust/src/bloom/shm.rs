//! mmap-backed bit arrays for `/dev/shm`-resident Bloom filters (§4.4.2).
//!
//! The paper hosts its filters in node-local shared-memory segments so the
//! index lives in DRAM with file semantics (persistence across pipeline
//! stages, observable by other processes, swap-backed by local SSD).
//! This module implements that with `mmap(MAP_SHARED)` over a regular
//! file — point it at `/dev/shm/...` to get the paper's exact setup, or
//! at any filesystem path for plain persistence.

use crate::error::{Error, Result};
use std::fs::{File, OpenOptions};
use std::os::fd::AsRawFd;
use std::path::{Path, PathBuf};

/// Minimal FFI surface over the platform C library. The crate is
/// dependency-free (no `libc` crate in Cargo.toml); these symbols are
/// provided by the C runtime every Rust binary on this target already
/// links, and the constants are the stable Linux ABI values
/// (`asm-generic/mman-common.h`). Shared with
/// [`crate::persist::ShmAtomicBitArray`], the `&[AtomicU64]`-viewed
/// sibling of [`ShmBitArray`].
pub(crate) mod libc {
    // The constants below are the 64-bit Linux ABI; on other targets they
    // would compile fine and misbehave at runtime (e.g. Darwin's MS_SYNC
    // is 0x0010, and 32-bit glibc's mmap takes a 32-bit off_t, so the
    // `offset: i64` declaration below would scramble the call ABI), so
    // fail the build loudly instead.
    #[cfg(any(not(target_os = "linux"), target_pointer_width = "16", target_pointer_width = "32"))]
    compile_error!(
        "bloom::shm's inline libc shim encodes the 64-bit Linux mman ABI; \
         port PROT_*/MAP_*/MS_* and the off_t width before building on this target"
    );

    pub use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const PROT_WRITE: c_int = 0x2;
    pub const MAP_SHARED: c_int = 0x01;
    pub const MS_SYNC: c_int = 4;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void; // (void *)-1

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn msync(addr: *mut c_void, len: usize, flags: c_int) -> c_int;
    }
}

/// A u64-word bit array backed by a shared file mapping.
pub struct ShmBitArray {
    ptr: *mut u64,
    words: usize,
    path: PathBuf,
}

// SAFETY: the mapping is owned exclusively by this struct (the pointer
// never escapes except through `words`/`words_mut`, which borrow self),
// so moving it to another thread moves sole access with it; concurrent
// mutation is prevented by &mut discipline, matching Vec<u64> semantics.
unsafe impl Send for ShmBitArray {}

impl ShmBitArray {
    /// Create (or truncate) a file of `words * 8` bytes and map it shared.
    pub fn create(path: &Path, words: usize) -> Result<Self> {
        assert!(words > 0);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        file.set_len((words * 8) as u64)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::map(file, path, words)
    }

    /// Map an existing array created by [`ShmBitArray::create`].
    ///
    /// The file must already exist and be exactly `words * 8` bytes:
    /// opening a missing path is an I/O error (silently fabricating a
    /// zeroed array would report every key absent — Bloom false
    /// negatives), and a size mismatch is a [`Error::Format`] (remapping
    /// with a smaller `words` would `set_len`-truncate, i.e. corrupt, a
    /// live filter; a larger one would read bits the filter never
    /// wrote). Use [`ShmBitArray::create`] to (re)initialize.
    pub fn open(path: &Path, words: usize) -> Result<Self> {
        assert!(words > 0);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let actual = file
            .metadata()
            .map_err(|e| Error::io(path.display().to_string(), e))?
            .len();
        let expected = (words * 8) as u64;
        if actual != expected {
            return Err(Error::Format(format!(
                "shm bit array {}: file is {actual} bytes but {words} words need {expected}; \
                 refusing to remap a mismatched filter",
                path.display()
            )));
        }
        Self::map(file, path, words)
    }

    fn map(file: File, path: &Path, words: usize) -> Result<Self> {
        let bytes = words * 8;
        // SAFETY: plain FFI call with no pointer-validity precondition —
        // addr is null (kernel chooses), `fd` is a live descriptor
        // borrowed from `file` for the duration of the call, and the
        // kernel validates len/prot/flags, returning MAP_FAILED (checked
        // below) rather than faulting. The mapping outliving `file` is
        // fine: MAP_SHARED mappings keep the inode alive after close.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(Error::io(
                path.display().to_string(),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(Self { ptr: ptr as *mut u64, words, path: path.to_path_buf() })
    }

    /// The words as an immutable slice.
    #[inline(always)]
    pub fn words(&self) -> &[u64] {
        // SAFETY: `ptr` is a live MAP_SHARED mapping of exactly
        // `words * 8` bytes (validated against the file length in
        // `open`, set by `create`), page-aligned so u64-aligned, and
        // unmapped only in Drop; the returned borrow of self keeps the
        // mapping alive and excludes `words_mut`'s aliasing &mut.
        unsafe { std::slice::from_raw_parts(self.ptr, self.words) }
    }

    /// The words as a mutable slice.
    #[inline(always)]
    pub fn words_mut(&mut self) -> &mut [u64] {
        // SAFETY: same mapping validity as `words`; &mut self makes
        // this the only live view, so the &mut slice cannot alias.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.words) }
    }

    /// Flush dirty pages to the backing file (msync).
    pub fn sync(&self) -> Result<()> {
        // SAFETY: `ptr`/len describe the live mapping (see `words`);
        // msync only schedules writeback and reports errors via rc.
        let rc = unsafe { libc::msync(self.ptr as *mut _, self.words * 8, libc::MS_SYNC) };
        if rc != 0 {
            return Err(Error::io(
                self.path.display().to_string(),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(())
    }

    /// Backing path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for ShmBitArray {
    fn drop(&mut self) {
        // Flush before unmapping: munmap alone only schedules writeback,
        // and a process exiting right after a clean drop could otherwise
        // lose the unsynced tail of the filter. Errors are unreportable
        // from drop; callers that must observe sync failures call
        // [`ShmBitArray::sync`] explicitly first.
        // SAFETY: `ptr`/len describe the mapping created in `map` and
        // never handed out beyond self-borrowed slices; Drop runs after
        // all borrows end, so no view outlives the munmap.
        unsafe {
            let _ = libc::msync(self.ptr as *mut _, self.words * 8, libc::MS_SYNC);
            libc::munmap(self.ptr as *mut _, self.words * 8);
        }
    }
}

/// Pick the default shared-memory directory: `/dev/shm` when present
/// (Linux), falling back to the system temp dir.
pub fn default_shm_dir() -> PathBuf {
    let shm = PathBuf::from("/dev/shm");
    if shm.is_dir() {
        shm
    } else {
        std::env::temp_dir()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lshbloom-shm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn create_write_reopen() {
        let path = tmp("a.bits");
        {
            let mut arr = ShmBitArray::create(&path, 16).unwrap();
            arr.words_mut()[0] = 0xDEAD_BEEF;
            arr.words_mut()[15] = u64::MAX;
            arr.sync().unwrap();
        }
        {
            let arr = ShmBitArray::open(&path, 16).unwrap();
            assert_eq!(arr.words()[0], 0xDEAD_BEEF);
            assert_eq!(arr.words()[15], u64::MAX);
            assert_eq!(arr.words()[7], 0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn create_truncates_existing() {
        let path = tmp("b.bits");
        {
            let mut arr = ShmBitArray::create(&path, 4).unwrap();
            arr.words_mut().fill(u64::MAX);
            arr.sync().unwrap();
        }
        {
            let arr = ShmBitArray::create(&path, 4).unwrap();
            assert!(arr.words().iter().all(|&w| w == 0), "create must zero");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_path_is_io_error() {
        let r = ShmBitArray::create(Path::new("/nonexistent-dir-xyz/f.bits"), 4);
        assert!(r.is_err());
    }

    #[test]
    fn open_missing_file_errors_instead_of_fabricating() {
        let path = tmp("missing.bits");
        std::fs::remove_file(&path).ok();
        let r = ShmBitArray::open(&path, 8);
        assert!(r.is_err(), "open must not create a zeroed array");
        assert!(!path.exists(), "open must not leave a file behind");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI is unsupported under Miri
    fn open_size_mismatch_errors_instead_of_truncating() {
        let path = tmp("sized.bits");
        {
            let mut arr = ShmBitArray::create(&path, 16).unwrap();
            arr.words_mut().fill(u64::MAX);
            arr.sync().unwrap();
        }
        // Smaller view would truncate, larger would read unwritten bits;
        // both must be refused.
        for words in [8usize, 32] {
            let err = ShmBitArray::open(&path, words).unwrap_err();
            assert!(
                err.to_string().contains("refusing to remap"),
                "unexpected error for words={words}: {err}"
            );
        }
        // The existing contents survived both refused attempts.
        let arr = ShmBitArray::open(&path, 16).unwrap();
        assert!(arr.words().iter().all(|&w| w == u64::MAX));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shm_dir_exists() {
        assert!(default_shm_dir().is_dir());
    }
}
