//! The Bloom filter proper: contiguous bit array + double hashing.
//!
//! Probe positions follow Kirsch–Mitzenmacher double hashing:
//! `pos_j = (h1 + j·h2) mod m` with `h1`,`h2` derived from the u64 key by
//! independent mixes. Keys are already well-mixed u64s (band sum-hashes or
//! `fast_str_hash` outputs), so two cheap finalizers suffice.
//!
//! The backing storage is pluggable ([`Bits`]): an in-heap `Vec<u64>` or a
//! [`super::shm::ShmBitArray`] mapping (§4.4.2 /dev/shm codesign).

use super::params::BloomParams;
use super::shm::ShmBitArray;
use crate::error::{Error, Result};
use crate::rng::mix64;
use std::io::{Read, Write};
use std::path::Path;

/// Kirsch–Mitzenmacher probe pair for a key: `(h1, h2)` with `h2` forced
/// odd so every probe stride visits distinct positions. Shared by
/// [`BloomFilter`] and [`crate::engine::AtomicBloomFilter`] so both probe
/// the exact same bit positions for a given key and geometry — the
/// design-bound FP math (§4.3/§4.5) holds identically for either.
#[inline(always)]
pub fn probe_pair(key: u64) -> (u64, u64) {
    let h1 = mix64(key);
    let h2 = mix64(key ^ 0x9E37_79B9_7F4A_7C15) | 1;
    (h1, h2)
}

/// Backing bit storage.
pub enum Bits {
    Heap(Vec<u64>),
    Shm(ShmBitArray),
}

impl Bits {
    #[inline(always)]
    fn words(&self) -> &[u64] {
        match self {
            Bits::Heap(v) => v,
            Bits::Shm(s) => s.words(),
        }
    }

    #[inline(always)]
    fn words_mut(&mut self) -> &mut [u64] {
        match self {
            Bits::Heap(v) => v,
            Bits::Shm(s) => s.words_mut(),
        }
    }
}

/// A single Bloom filter.
pub struct BloomFilter {
    bits: Bits,
    /// Bit-array length (= params.bits rounded up to a word multiple).
    m: u64,
    k: u32,
    inserted: u64,
    params: BloomParams,
}

impl BloomFilter {
    /// Heap-backed filter with the given geometry.
    pub fn new(params: BloomParams) -> Self {
        let words = params.bits.div_ceil(64) as usize;
        Self {
            bits: Bits::Heap(vec![0u64; words]),
            m: words as u64 * 64,
            k: params.hashes,
            inserted: 0,
            params,
        }
    }

    /// Heap-backed filter for `n` planned elements at rate `p`.
    pub fn with_capacity(n: u64, p: f64) -> Self {
        Self::new(BloomParams::for_capacity(n, p))
    }

    /// Heap-backed filter from an existing word array (e.g. a snapshot of
    /// an [`crate::engine::AtomicBloomFilter`] being frozen for
    /// persistence). `words` must match the geometry in `params`.
    pub(crate) fn from_raw_parts(
        words: Vec<u64>,
        hashes: u32,
        inserted: u64,
        params: BloomParams,
    ) -> Self {
        debug_assert_eq!(words.len() as u64, params.bits.div_ceil(64));
        let m = words.len() as u64 * 64;
        Self { bits: Bits::Heap(words), m, k: hashes, inserted, params }
    }

    /// Filter backed by an mmap-ed file (e.g. under `/dev/shm`).
    pub fn new_shm(params: BloomParams, path: &Path) -> Result<Self> {
        let words = params.bits.div_ceil(64) as usize;
        let shm = ShmBitArray::create(path, words)?;
        Ok(Self { bits: Bits::Shm(shm), m: words as u64 * 64, k: params.hashes, inserted: 0, params })
    }

    #[inline(always)]
    fn probes(&self, key: u64) -> (u64, u64) {
        probe_pair(key)
    }

    /// Insert a key. Returns `true` if the key was (possibly) already
    /// present — i.e. every probed bit was already set.
    #[inline]
    pub fn insert(&mut self, key: u64) -> bool {
        let (h1, h2) = self.probes(key);
        let m = self.m;
        let words = self.bits.words_mut();
        let mut all_set = true;
        let mut h = h1;
        for _ in 0..self.k {
            let bit = h % m;
            let (w, mask) = (bit / 64, 1u64 << (bit % 64));
            let word = &mut words[w as usize];
            if *word & mask == 0 {
                all_set = false;
                *word |= mask;
            }
            h = h.wrapping_add(h2);
        }
        self.inserted += 1;
        all_set
    }

    /// Query a key: `true` means "possibly present" (no false negatives).
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        let (h1, h2) = self.probes(key);
        let m = self.m;
        let words = self.bits.words();
        let mut h = h1;
        for _ in 0..self.k {
            let bit = h % m;
            if words[(bit / 64) as usize] & (1u64 << (bit % 64)) == 0 {
                return false;
            }
            h = h.wrapping_add(h2);
        }
        true
    }

    /// Number of bits set (popcount) — fill diagnostics.
    pub fn ones(&self) -> u64 {
        self.bits.words().iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Fraction of bits set.
    pub fn fill_ratio(&self) -> f64 {
        self.ones() as f64 / self.m as f64
    }

    /// Elements inserted so far.
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Geometry.
    pub fn params(&self) -> BloomParams {
        self.params
    }

    /// Bytes of backing storage (the disk footprint of this filter).
    pub fn size_bytes(&self) -> u64 {
        (self.bits.words().len() * 8) as u64
    }

    /// Serialize: header (m, k, inserted, capacity) + raw words.
    pub fn save<W: Write>(&self, w: &mut W) -> Result<()> {
        let hdr = [
            self.m,
            self.k as u64,
            self.inserted,
            self.params.capacity,
            self.params.bits,
        ];
        let mut buf = Vec::with_capacity(40);
        for v in hdr {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf).map_err(|e| Error::io("bloom save", e))?;
        // Write words in bulk.
        let words = self.bits.words();
        let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        w.write_all(&bytes).map_err(|e| Error::io("bloom save", e))?;
        Ok(())
    }

    /// Deserialize a heap-backed filter.
    pub fn load<R: Read>(r: &mut R) -> Result<Self> {
        let mut hdr = [0u8; 40];
        r.read_exact(&mut hdr).map_err(|e| Error::io("bloom load", e))?;
        let get = |i: usize| u64::from_le_bytes(hdr[i * 8..(i + 1) * 8].try_into().unwrap());
        let (m, k, inserted, capacity, bits) = (get(0), get(1), get(2), get(3), get(4));
        if m == 0 || m % 64 != 0 || k == 0 || k > 1024 {
            return Err(Error::Format(format!("bad bloom header: m={m} k={k}")));
        }
        let words = (m / 64) as usize;
        let mut raw = vec![0u8; words * 8];
        r.read_exact(&mut raw).map_err(|e| Error::io("bloom load", e))?;
        let vec: Vec<u64> = raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self {
            bits: Bits::Heap(vec),
            m,
            k: k as u32,
            inserted,
            params: BloomParams { bits, hashes: k as u32, capacity },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn no_false_negatives() {
        let mut f = BloomFilter::with_capacity(10_000, 1e-4);
        let mut rng = Xoshiro256pp::seeded(1);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        for &k in &keys {
            assert!(f.contains(k), "false negative for {k}");
        }
    }

    #[test]
    fn fp_rate_within_design_bound() {
        let p = 1e-3;
        let n = 50_000u64;
        let mut f = BloomFilter::with_capacity(n, p);
        let mut rng = Xoshiro256pp::seeded(2);
        for _ in 0..n {
            f.insert(rng.next_u64());
        }
        // Probe fresh keys; observed FP rate should be ~p (allow 3x).
        let trials = 200_000;
        let mut fps = 0u64;
        for _ in 0..trials {
            if f.contains(rng.next_u64()) {
                fps += 1;
            }
        }
        let observed = fps as f64 / trials as f64;
        assert!(observed < p * 3.0, "observed FP {observed} vs design {p}");
    }

    #[test]
    fn insert_reports_prior_presence() {
        let mut f = BloomFilter::with_capacity(1000, 1e-6);
        assert!(!f.insert(42), "first insert must report absent");
        assert!(f.insert(42), "second insert must report present");
    }

    #[test]
    fn empty_filter_contains_nothing() {
        let f = BloomFilter::with_capacity(1000, 1e-4);
        let mut rng = Xoshiro256pp::seeded(3);
        for _ in 0..1000 {
            assert!(!f.contains(rng.next_u64()));
        }
    }

    #[test]
    fn fill_ratio_tracks_inserts() {
        let mut f = BloomFilter::with_capacity(1000, 0.01);
        assert_eq!(f.fill_ratio(), 0.0);
        for i in 0..500 {
            f.insert(i);
        }
        let half = f.fill_ratio();
        for i in 500..1000 {
            f.insert(i);
        }
        let full = f.fill_ratio();
        assert!(full > half && half > 0.0);
        // At design capacity the fill should be ~50% (optimal k property).
        assert!((0.4..0.6).contains(&full), "fill at capacity {full}");
    }

    #[test]
    fn save_load_roundtrip() {
        let mut f = BloomFilter::with_capacity(5000, 1e-4);
        let mut rng = Xoshiro256pp::seeded(4);
        let keys: Vec<u64> = (0..5000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        let mut buf = Vec::new();
        f.save(&mut buf).unwrap();
        let g = BloomFilter::load(&mut buf.as_slice()).unwrap();
        assert_eq!(g.inserted(), f.inserted());
        assert_eq!(g.size_bytes(), f.size_bytes());
        for &k in &keys {
            assert!(g.contains(k));
        }
        assert_eq!(g.ones(), f.ones());
    }

    #[test]
    fn load_rejects_garbage() {
        let buf = vec![0xFFu8; 32]; // truncated header
        assert!(BloomFilter::load(&mut buf.as_slice()).is_err());
        let mut hdr = Vec::new();
        for v in [63u64, 5, 0, 0, 63] {
            hdr.extend_from_slice(&v.to_le_bytes()); // m not word multiple
        }
        assert!(BloomFilter::load(&mut hdr.as_slice()).is_err());
    }

    #[test]
    fn shm_backed_filter_works() {
        let dir = std::env::temp_dir().join(format!("lshbloom-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.bloom.bits");
        {
            let params = BloomParams::for_capacity(1000, 1e-4);
            let mut f = BloomFilter::new_shm(params, &path).unwrap();
            for i in 0..1000u64 {
                f.insert(i * 7);
            }
            for i in 0..1000u64 {
                assert!(f.contains(i * 7));
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
