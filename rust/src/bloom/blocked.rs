//! Register-blocked Bloom filter (Putze et al.): all k probe bits of a
//! key land in a single 64-byte cache block.
//!
//! The classic filter's insert touches k random cache lines; with the
//! paper's conservative `p_effective = 1e-10` over 42 bands the
//! per-filter rate demands k ≈ 39 — ~1,600 cache misses per document
//! across the index. Blocking reduces that to one miss per band (42)
//! at the cost of a slightly worse FP rate for equal m, compensated by
//! growing the bit array (`BLOCK_OVERPROVISION`).
//!
//! The §Perf pass (EXPERIMENTS.md) measures this swap; the LSHBloom
//! index takes either filter via [`crate::index::lshbloom`]'s config.

use super::params::BloomParams;
use crate::rng::mix64;

/// 64-byte block = 8 u64 words = 512 bits.
const WORDS_PER_BLOCK: usize = 8;
const BITS_PER_BLOCK: u64 = 512;

/// Extra space vs the classic optimum to recover the blocking FP loss.
/// Putze et al. report ~15-30% for k in the 20-40 range at 512-bit
/// blocks; we provision 30% (validated empirically in tests).
pub const BLOCK_OVERPROVISION: f64 = 1.3;

/// Cache-line-blocked Bloom filter.
pub struct BlockedBloomFilter {
    words: Vec<u64>,
    num_blocks: u64,
    k: u32,
    inserted: u64,
    params: BloomParams,
}

impl BlockedBloomFilter {
    /// Build with geometry derived from the classic optimum for
    /// (`n`, `p`) scaled by [`BLOCK_OVERPROVISION`].
    pub fn with_capacity(n: u64, p: f64) -> Self {
        let params = BloomParams::for_capacity(n, p);
        let bits = (params.bits as f64 * BLOCK_OVERPROVISION) as u64;
        let num_blocks = bits.div_ceil(BITS_PER_BLOCK).max(1);
        Self {
            words: vec![0u64; (num_blocks as usize) * WORDS_PER_BLOCK],
            num_blocks,
            // k capped: >16 probes inside 512 bits saturates quickly and
            // costs time; 16 gives p_block ~ 2^-16 * fill-corrections,
            // further probes add little once bits collide inside a block.
            k: params.hashes.min(16),
            inserted: 0,
            params,
        }
    }

    /// Derive (block index, probe stream seed) from a key.
    #[inline(always)]
    fn route(&self, key: u64) -> (usize, u64) {
        let h = mix64(key);
        // High bits pick the block; the full mixed value seeds probes.
        let block = (((h >> 32) * self.num_blocks) >> 32) as usize;
        (block, mix64(h ^ 0xA24B_AED4_963E_E407))
    }

    /// Insert; returns true when every probed bit was already set.
    #[inline]
    pub fn insert(&mut self, key: u64) -> bool {
        let (block, mut probe) = self.route(key);
        let words = &mut self.words[block * WORDS_PER_BLOCK..(block + 1) * WORDS_PER_BLOCK];
        let mut all_set = true;
        for _ in 0..self.k {
            // 9 bits of probe per bit position (3 word + 6 bit).
            let bit = (probe & 511) as usize;
            probe = probe.rotate_right(9).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ probe;
            let mask = 1u64 << (bit & 63);
            let w = &mut words[bit >> 6];
            if *w & mask == 0 {
                all_set = false;
                *w |= mask;
            }
        }
        self.inserted += 1;
        all_set
    }

    /// Query; true = possibly present (never a false negative).
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        let (block, mut probe) = self.route(key);
        let words = &self.words[block * WORDS_PER_BLOCK..(block + 1) * WORDS_PER_BLOCK];
        for _ in 0..self.k {
            let bit = (probe & 511) as usize;
            probe = probe.rotate_right(9).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ probe;
            if words[bit >> 6] & (1u64 << (bit & 63)) == 0 {
                return false;
            }
        }
        true
    }

    /// Backing bytes (disk footprint).
    pub fn size_bytes(&self) -> u64 {
        (self.words.len() * 8) as u64
    }

    /// Elements inserted.
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// The classic-optimum params this filter was derived from.
    pub fn params(&self) -> BloomParams {
        self.params
    }

    /// Fraction of bits set.
    pub fn fill_ratio(&self) -> f64 {
        let ones: u64 = self.words.iter().map(|w| w.count_ones() as u64).sum();
        ones as f64 / (self.words.len() as u64 * 64) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn no_false_negatives() {
        let mut f = BlockedBloomFilter::with_capacity(20_000, 1e-6);
        let mut rng = Xoshiro256pp::seeded(1);
        let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        for &k in &keys {
            assert!(f.contains(k));
        }
    }

    #[test]
    fn fp_rate_reasonable_at_capacity() {
        // Design p=1e-4; blocked + overprovision should stay within ~4x.
        let p = 1e-4;
        let n = 100_000u64;
        let mut f = BlockedBloomFilter::with_capacity(n, p);
        let mut rng = Xoshiro256pp::seeded(2);
        for _ in 0..n {
            f.insert(rng.next_u64());
        }
        let trials = 500_000u64;
        let mut fps = 0u64;
        for _ in 0..trials {
            fps += f.contains(rng.next_u64()) as u64;
        }
        let observed = fps as f64 / trials as f64;
        assert!(observed < p * 4.0, "observed {observed} vs design {p}");
    }

    #[test]
    fn insert_reports_prior_presence() {
        let mut f = BlockedBloomFilter::with_capacity(1000, 1e-8);
        assert!(!f.insert(123456));
        assert!(f.insert(123456));
        assert!(f.contains(123456));
        assert!(!f.contains(654321));
    }

    #[test]
    fn distributes_across_blocks() {
        let mut f = BlockedBloomFilter::with_capacity(10_000, 1e-4);
        let mut rng = Xoshiro256pp::seeded(3);
        for _ in 0..5_000 {
            f.insert(rng.next_u64());
        }
        let fill = f.fill_ratio();
        assert!(fill > 0.05 && fill < 0.6, "fill {fill}");
    }
}
