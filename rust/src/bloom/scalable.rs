//! Scalable Bloom filter (Almeida et al., 2007) — LSHBloom without a
//! planned corpus cardinality.
//!
//! The paper's index must be sized for `n` expected documents up front
//! (§4.5); continuously growing corpora (its own motivation: monthly
//! CommonCrawl drops, §1) eventually exceed any plan. A scalable filter
//! chains sub-filters of geometrically increasing capacity and
//! geometrically tightening error so the *total* false-positive rate
//! stays below the configured bound no matter how many elements arrive:
//!
//! ```text
//!   p_total ≤ p0 · Σ r^i = p0 / (1 - r)      (r = TIGHTENING < 1)
//! ```
//!
//! Queries probe every sub-filter (newest first — recent keys are the
//! likeliest matches in a dedup stream); inserts go to the newest.
//! This powers [`crate::index::lshbloom`]'s unbounded mode and is the
//! concrete realization of the paper's §6 scaling future work.

use super::filter::BloomFilter;
pub use crate::capacity::STAGE_GROWTH as GROWTH;
pub use crate::capacity::STAGE_TIGHTENING as TIGHTENING;

/// A chain of Bloom filters with bounded total false-positive rate.
pub struct ScalableBloomFilter {
    /// Sub-filters, oldest first.
    stages: Vec<BloomFilter>,
    /// First-stage capacity.
    initial_capacity: u64,
    /// Total false-positive budget across all stages.
    p_total: f64,
    inserted: u64,
}

impl ScalableBloomFilter {
    /// New scalable filter: `initial_capacity` sizes stage 0; the chain
    /// keeps overall FP ≤ `p_total` forever.
    pub fn new(initial_capacity: u64, p_total: f64) -> Self {
        assert!(initial_capacity > 0);
        assert!(p_total > 0.0 && p_total < 1.0);
        let mut f = Self {
            stages: Vec::new(),
            initial_capacity,
            p_total,
            inserted: 0,
        };
        f.push_stage();
        f
    }

    fn push_stage(&mut self) {
        // All stage sizing goes through the capacity oracle — this module
        // holds no geometry math of its own.
        let i = self.stages.len();
        let params = crate::capacity::scalable_stage_params(self.initial_capacity, self.p_total, i);
        self.stages.push(BloomFilter::new(params));
    }

    /// Insert a key; returns `true` when it was (possibly) already
    /// present in *any* stage.
    pub fn insert(&mut self, key: u64) -> bool {
        if self.contains(key) {
            // Matching the plain filter's insert-reports-presence
            // semantics; still record the key in the active stage so the
            // positive is stable even if older stages are compacted away.
            self.active_insert(key);
            return true;
        }
        self.active_insert(key);
        false
    }

    fn active_insert(&mut self, key: u64) {
        let last = self.stages.len() - 1;
        let full = {
            let s = &self.stages[last];
            s.inserted() >= s.params().capacity
        };
        if full {
            self.push_stage();
        }
        let last = self.stages.len() - 1;
        self.stages[last].insert(key);
        self.inserted += 1;
    }

    /// Query newest-first.
    pub fn contains(&self, key: u64) -> bool {
        self.stages.iter().rev().any(|s| s.contains(key))
    }

    /// Elements inserted.
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Number of chained stages.
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total backing bytes.
    pub fn size_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.size_bytes()).sum()
    }

    /// The design-time total FP bound.
    pub fn p_total(&self) -> f64 {
        self.p_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn grows_past_initial_capacity_without_false_negatives() {
        let mut f = ScalableBloomFilter::new(1_000, 1e-4);
        let mut rng = Xoshiro256pp::seeded(1);
        let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(f.num_stages() > 3, "should have chained stages");
        for &k in &keys {
            assert!(f.contains(k), "lost key across stage boundary");
        }
    }

    #[test]
    fn fp_rate_stays_bounded_after_many_growths() {
        let p_total = 1e-3;
        let mut f = ScalableBloomFilter::new(500, p_total);
        let mut rng = Xoshiro256pp::seeded(2);
        for _ in 0..30_000 {
            f.insert(rng.next_u64());
        }
        let trials = 300_000u64;
        let mut fps = 0u64;
        for _ in 0..trials {
            fps += f.contains(rng.next_u64()) as u64;
        }
        let observed = fps as f64 / trials as f64;
        assert!(
            observed < p_total * 3.0,
            "observed {observed} vs total budget {p_total} after {} stages",
            f.num_stages()
        );
    }

    #[test]
    fn insert_reports_duplicates() {
        let mut f = ScalableBloomFilter::new(100, 1e-6);
        assert!(!f.insert(42));
        assert!(f.insert(42));
        // Force growth, then re-check an old key.
        for i in 0..5_000u64 {
            f.insert(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        assert!(f.insert(42), "old-stage key must still be recognized");
    }

    #[test]
    fn stage_sizes_grow_geometrically() {
        let mut f = ScalableBloomFilter::new(100, 1e-4);
        let mut rng = Xoshiro256pp::seeded(3);
        for _ in 0..2_000 {
            f.insert(rng.next_u64());
        }
        let caps: Vec<u64> = f.stages.iter().map(|s| s.params().capacity).collect();
        for w in caps.windows(2) {
            assert_eq!(w[1], w[0] * GROWTH);
        }
    }
}
