//! Optimal Bloom filter sizing (§4.5).
//!
//! For a planned capacity of `n` elements and per-filter false-positive
//! bound `p`: `m = -n·ln p / (ln 2)²` bits and `k = (m/n)·ln 2 = -log2 p`
//! hash functions. LSHBloom instantiates `b` such filters (one per LSH
//! band) with `p = 1 - (1 - p_eff)^(1/b)` so the whole index meets the
//! user's effective false-positive bound `p_eff` (§4.3).

/// Bits required for `n` elements at false-positive rate `p`.
pub fn optimal_bits(n: u64, p: f64) -> u64 {
    assert!(n > 0, "capacity must be positive");
    assert!(p > 0.0 && p < 1.0, "p must be in (0,1), got {p}");
    let ln2 = std::f64::consts::LN_2;
    let m = -(n as f64) * p.ln() / (ln2 * ln2);
    (m.ceil() as u64).max(64)
}

/// Number of hash probes for a given bits/element ratio.
pub fn optimal_hashes(m: u64, n: u64) -> u32 {
    assert!(n > 0);
    let k = (m as f64 / n as f64) * std::f64::consts::LN_2;
    (k.round() as u32).max(1)
}

/// Resolved Bloom geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BloomParams {
    /// Bit-array length.
    pub bits: u64,
    /// Number of hash probes per element.
    pub hashes: u32,
    /// Planned capacity.
    pub capacity: u64,
}

impl BloomParams {
    /// Geometry for `n` planned insertions at false-positive rate `p`.
    pub fn for_capacity(n: u64, p: f64) -> Self {
        let bits = optimal_bits(n, p);
        Self { bits, hashes: optimal_hashes(bits, n), capacity: n }
    }

    /// Per-band rate from an index-wide effective bound (§4.3):
    /// `p = 1 - (1 - p_eff)^(1/b)`.
    pub fn per_filter_rate(p_effective: f64, num_bands: usize) -> f64 {
        assert!(num_bands > 0);
        assert!(p_effective > 0.0 && p_effective < 1.0);
        // For tiny p_eff, 1-(1-p)^(1/b) loses precision; use ln1p/expm1.
        let r = -(-p_effective).ln_1p() / num_bands as f64; // -ln(1-p_eff)/b
        -(-r).exp_m1() // 1 - exp(-r)
    }

    /// Predicted false-positive rate after `inserted` elements
    /// (standard approximation `(1 - e^{-k·i/m})^k`).
    pub fn predicted_fp_rate(&self, inserted: u64) -> f64 {
        let k = self.hashes as f64;
        let fill = 1.0 - (-k * inserted as f64 / self.bits as f64).exp();
        fill.powf(k)
    }

    /// Bytes of backing storage.
    pub fn bytes(&self) -> u64 {
        self.bits.div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizing_example() {
        // §4.5: T=0.8, 128 perms -> 9 bands; p_eff = 1e-10, n = 10B docs
        // -> "only 590 GB" for all nine filters.
        let p_eff = 1e-10;
        let b = 9;
        let p = BloomParams::per_filter_rate(p_eff, b);
        let params = BloomParams::for_capacity(10_000_000_000, p);
        let total_gb = (params.bytes() * b as u64) as f64 / 1e9;
        assert!(
            (500.0..700.0).contains(&total_gb),
            "paper says ~590 GB, got {total_gb:.1} GB"
        );
    }

    #[test]
    fn bits_per_element_classic_values() {
        // p = 1% -> ~9.585 bits/element, k ~ 7.
        let params = BloomParams::for_capacity(1_000_000, 0.01);
        let bpe = params.bits as f64 / 1_000_000.0;
        assert!((9.5..9.7).contains(&bpe), "bits/elem {bpe}");
        assert_eq!(params.hashes, 7);
    }

    #[test]
    fn per_filter_rate_composes_back() {
        for b in [1usize, 9, 42] {
            for p_eff in [1e-3, 1e-5, 1e-10] {
                let p = BloomParams::per_filter_rate(p_eff, b);
                let recomposed = 1.0 - (1.0 - p).powi(b as i32);
                assert!(
                    (recomposed - p_eff).abs() / p_eff < 1e-4,
                    "b={b} p_eff={p_eff}: recomposed {recomposed}"
                );
            }
        }
    }

    #[test]
    fn predicted_fp_at_capacity_close_to_design_p() {
        let p = 1e-4;
        let params = BloomParams::for_capacity(100_000, p);
        let at_cap = params.predicted_fp_rate(100_000);
        assert!(at_cap < p * 1.6, "predicted {at_cap} vs design {p}");
        assert!(at_cap > p * 0.4);
    }

    #[test]
    fn monotonicity() {
        assert!(optimal_bits(1000, 1e-6) > optimal_bits(1000, 1e-3));
        assert!(optimal_bits(10_000, 1e-3) > optimal_bits(1000, 1e-3));
        let params = BloomParams::for_capacity(1000, 1e-3);
        assert!(params.predicted_fp_rate(2000) > params.predicted_fp_rate(500));
    }

    #[test]
    #[should_panic]
    fn rejects_bad_p() {
        optimal_bits(10, 0.0);
    }
}
