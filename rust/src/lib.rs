//! # LSHBloom
//!
//! Memory-efficient, extreme-scale document deduplication.
//!
//! Reproduction of *LSHBloom: Internet-Scale Text Deduplication*
//! (Khan et al., 2024) as a three-layer rust + JAX/Pallas system:
//!
//! * **Layer 3 (this crate)** — the streaming deduplication coordinator:
//!   document ingestion, parallel MinHashing workers, the sequential
//!   Bloom-filter LSH index, the baseline methods the paper compares
//!   against, the synthetic labeled-corpus generator, and the full
//!   evaluation/benchmark harness.
//! * **Layer 2 (python/compile/model.py)** — the batched
//!   token-hashes → MinHash-signatures → band-hashes compute graph in JAX,
//!   AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — the MinHash permutation +
//!   min-reduce hot loop as a Pallas kernel, called from Layer 2.
//!
//! Python never runs on the ingest path: `make artifacts` lowers the
//! kernels once, and [`runtime`] loads the HLO artifacts through PJRT
//! (gated behind the `xla` cargo feature; offline builds get stubs).
//!
//! Two index engines serve the hot path: the classic sequential decider
//! ([`pipeline::run_stream`], exact stream-order semantics) and the
//! lock-free concurrent engine ([`engine`], atomic Bloom filters +
//! batched multi-threaded ingest — `--engine concurrent`).
// Soundness gates: unsafe operations must sit in explicit `unsafe {}`
// blocks even inside `unsafe fn` (each block carries its own SAFETY:
// comment, enforced by `analysis`), and blocks that stop being needed
// must be removed rather than linger.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unused_unsafe)]

pub mod analysis;
pub mod bloom;
pub mod capacity;
pub mod cli;
pub mod config;
pub mod corpus;
pub mod engine;
pub mod error;
pub mod eval;
pub mod hash;
pub mod index;
pub mod json;
pub mod logging;
pub mod methods;
pub mod minhash;
pub mod obs;
pub mod perf;
pub mod persist;
pub mod pipeline;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod text;
