//! Deterministic PRNGs and sampling distributions.
//!
//! The offline build has no `rand` crate, so this module provides the
//! randomness substrate for the whole system: [`SplitMix64`] (also the
//! source of the MinHash permutation seeds — kept in bit-for-bit lockstep
//! with `python/compile/kernels/common.py::splitmix64_stream`),
//! [`Xoshiro256pp`] for bulk generation, and the samplers used by the
//! synthetic corpus generator (uniform, ranges, Zipf, geometric).

/// splitmix64: tiny, fast, passes BigCrush when used as a seeder.
///
/// `next_u64` advances the state by the golden-ratio gamma and applies the
/// Stafford mix13 finalizer — exactly the sequence the python AOT side
/// generates for permutation seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

/// The splitmix64 golden-gamma increment.
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// Stafford mix13 finalizer (the splitmix64 output function).
#[inline(always)]
pub const fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl SplitMix64 {
    /// Create a generator with the given seed.
    pub const fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        mix64(self.state)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna): the workhorse generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via splitmix64 (the canonical seeding procedure).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from explicit (unnormalized) weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf-distributed sampler over ranks `0..n` (rank 0 most frequent).
///
/// Precomputes the CDF once; sampling is a binary search. Used by the
/// synthetic corpus generator to give the vocabulary a natural-language
/// frequency profile.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n` ranks with exponent `s` (s≈1.0 for natural text).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf over empty support");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let norm = acc;
        for v in &mut cdf {
            *v /= norm;
        }
        Self { cdf }
    }

    /// Sample a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Geometric sampler: number of Bernoulli(p) failures before a success.
pub fn geometric(rng: &mut Xoshiro256pp, p: f64) -> usize {
    debug_assert!(p > 0.0 && p <= 1.0);
    if p >= 1.0 {
        return 0;
    }
    let u = rng.next_f64().max(f64::MIN_POSITIVE);
    (u.ln() / (1.0 - p).ln()).floor() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_reference_vector() {
        // Reference values for seed=0 from the canonical splitmix64.c.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn splitmix_is_deterministic_per_seed() {
        let a: Vec<u64> = { let mut r = SplitMix64::new(42); (0..8).map(|_| r.next_u64()).collect() };
        let b: Vec<u64> = { let mut r = SplitMix64::new(42); (0..8).map(|_| r.next_u64()).collect() };
        let c: Vec<u64> = { let mut r = SplitMix64::new(43); (0..8).map(|_| r.next_u64()).collect() };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn xoshiro_uniformity_rough() {
        let mut rng = Xoshiro256pp::seeded(7);
        let n = 100_000;
        let mut buckets = [0u32; 10];
        for _ in 0..n {
            buckets[(rng.next_f64() * 10.0) as usize] += 1;
        }
        for b in buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket fraction {frac}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Xoshiro256pp::seeded(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_rank_order() {
        let zipf = Zipf::new(100, 1.0);
        let mut rng = Xoshiro256pp::seeded(3);
        let mut counts = [0u32; 100];
        for _ in 0..100_000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
        // rank0/rank1 ratio should be near 2 for s=1.
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((1.6..2.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn geometric_mean_close() {
        let mut rng = Xoshiro256pp::seeded(5);
        let p = 0.25;
        let n = 50_000;
        let total: usize = (0..n).map(|_| geometric(&mut rng, p)).sum();
        let mean = total as f64 / n as f64;
        let expect = (1.0 - p) / p; // 3.0
        assert!((mean - expect).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Xoshiro256pp::seeded(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }
}
