//! Pipeline configuration.
//!
//! [`PipelineConfig`] is the single source of truth for a dedup run:
//! similarity threshold, MinHash geometry, Bloom bounds, worker counts,
//! and backend selection. It can be loaded from a small TOML-subset file
//! (`key = value`, `[section]` headers flattened to `section.key`) and
//! overridden from CLI flags — the config-system layer that a deployment
//! would drive.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which MinHash backend computes signatures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MinHashBackend {
    /// Native rust (mix64 family) — default.
    Native,
    /// AOT-compiled XLA artifact through PJRT (mix64 family, bit-identical).
    Xla,
    /// Native rust, datasketch-compatible `(a·h+b) mod p` family.
    Datasketch,
}

impl MinHashBackend {
    /// Parse from a CLI/config token.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Self::Native),
            "xla" => Ok(Self::Xla),
            "datasketch" => Ok(Self::Datasketch),
            _ => Err(Error::Config(format!(
                "unknown minhash backend '{s}' (native|xla|datasketch)"
            ))),
        }
    }
}

/// Which index engine serves insert/query traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// Sequential decider behind a mutex — exact stream-order verdicts,
    /// all methods/backends. Default.
    Classic,
    /// Lock-free atomic-Bloom engine (`crate::engine`) — scales inserts
    /// and queries with cores; LSHBloom only. See the `engine` module
    /// docs for the linearizability caveat.
    Concurrent,
}

impl EngineMode {
    /// Parse from a CLI/config token.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "classic" => Ok(Self::Classic),
            "concurrent" => Ok(Self::Concurrent),
            _ => Err(Error::Config(format!(
                "unknown engine '{s}' (classic|concurrent)"
            ))),
        }
    }
}

/// Full configuration for a deduplication run.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Jaccard similarity threshold T (Table 1 best: 0.5).
    pub threshold: f64,
    /// Number of MinHash permutations P (Table 1 best: 256).
    pub num_perms: usize,
    /// Word n-gram size for shingling (Table 1 best for LSH methods: 1).
    pub ngram: usize,
    /// Effective index-wide false-positive bound p_eff (§4.3).
    pub p_effective: f64,
    /// Planned corpus cardinality (sizes the Bloom filters).
    pub expected_docs: u64,
    /// MinHash worker threads (0 = available parallelism).
    pub workers: usize,
    /// Documents per worker batch (also the XLA artifact's B dimension).
    pub batch_size: usize,
    /// Signature backend.
    pub backend: MinHashBackend,
    /// Directory holding AOT artifacts (XLA backend).
    pub artifacts_dir: String,
    /// Host the Bloom index in /dev/shm (§4.4.2) instead of the heap.
    pub use_shm: bool,
    /// Use cache-line-blocked Bloom filters (§Perf; heap-only, faster
    /// inserts at conservative p_effective, ~30% more space).
    pub blocked_bloom: bool,
    /// Bounded-channel depth between pipeline stages (backpressure).
    pub channel_depth: usize,
    /// Index engine: classic mutex-serialized decider or the lock-free
    /// concurrent engine.
    pub engine: EngineMode,
    /// Shard count for the §6 sharded-aggregation path (1 = unsharded).
    /// Shard counts > 1 run `pipeline::dedup_sharded`: per-shard
    /// concurrent-engine ingest, cross-shard bit-OR filter aggregation.
    pub shards: usize,
    /// Run each shard as its own OS worker process under a supervising
    /// orchestrator (`pipeline::supervisor`, `dedup --distributed`).
    /// Requires `shards >= 2`. `checkpoint_dir` is the worker state root
    /// — the only channel between supervisor and workers (the `dedup`
    /// CLI falls back to a temp dir when unset); `checkpoint_every` sets
    /// each worker's crash-recovery granularity.
    pub distributed: bool,
    /// Durable state directory for the concurrent engine ("" = none):
    /// mmap-backed filters plus a checkpoint manifest (`crate::persist`).
    /// Drives `dedup --checkpoint-dir` / `serve --state-dir`; with
    /// shards > 1 it is the per-shard checkpoint root for the on-disk
    /// phase-2 union. A slice server (`serve --slice-index`) owns its
    /// band range here as live mmaps — acknowledged inserts survive a
    /// crash-restart, and sibling slices may tile the same directory.
    pub checkpoint_dir: String,
    /// Checkpoint every N documents during engine-backed streaming
    /// ingest (0 = only the final end-of-stream checkpoint). Requires
    /// `checkpoint_dir`.
    pub checkpoint_every: u64,
    /// Band-slice count for the serving tier (`serve --serve-shards`,
    /// 1 = a single engine). Counts > 1 partition the b band filters
    /// across N in-process slice engines (`crate::engine::band_slice`)
    /// that are probed in parallel and OR-reduced per request —
    /// verdict-identical to a single engine. Requires the concurrent
    /// engine; ignored by `dedup` (ingest sharding is `shards`).
    pub serve_shards: usize,
    /// `HOST:PORT` for the Prometheus metrics endpoint served by
    /// `serve`/`route` (`--metrics-addr`, "" = disabled). Port 0 binds
    /// an ephemeral port. The endpoint exposes the `crate::obs`
    /// registry as text exposition at `/metrics` and JSON at
    /// `/metrics.json`.
    pub metrics_addr: String,
    /// Probability in [0, 1] that a request records a distributed
    /// trace (`--trace-sample`, 0 = off). Errors and slow requests
    /// record regardless; the verdict is derived deterministically
    /// from the trace ID so every hop of one request agrees.
    pub trace_sample: f64,
    /// Slow-request threshold in milliseconds (`--trace-slow-ms`,
    /// 0 = off). Requests at or above it always record a trace and
    /// log one WARN line with the per-hop breakdown.
    pub trace_slow_ms: u64,
    /// Sampled-fill watermark in [0, 1) at which the concurrent engine
    /// freezes the open filter generation and opens a fresh one sized
    /// from the live capacity plan (`--rotate-watermark`, key
    /// `capacity.rotate_watermark`; 0 disables rotation). The default
    /// 0.5 is the fill the §4.5 sizing rule reaches at exactly the
    /// planned capacity, so rotation fires the moment a generation
    /// exceeds what it was sized for.
    pub rotate_watermark: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            threshold: 0.5,
            num_perms: 256,
            ngram: 1,
            p_effective: 1e-10,
            expected_docs: 1_000_000,
            workers: 0,
            batch_size: 64,
            backend: MinHashBackend::Native,
            artifacts_dir: "artifacts".into(),
            use_shm: false,
            blocked_bloom: false,
            channel_depth: 64,
            engine: EngineMode::Classic,
            shards: 1,
            distributed: false,
            checkpoint_dir: String::new(),
            checkpoint_every: 0,
            serve_shards: 1,
            metrics_addr: String::new(),
            trace_sample: 0.0,
            trace_slow_ms: 0,
            rotate_watermark: 0.5,
        }
    }
}

impl PipelineConfig {
    /// Validate parameter combinations.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.threshold) {
            return Err(Error::Config(format!("threshold {} not in [0,1]", self.threshold)));
        }
        if self.num_perms == 0 || self.num_perms > 4096 {
            return Err(Error::Config(format!("num_perms {} out of range", self.num_perms)));
        }
        if self.ngram == 0 {
            return Err(Error::Config("ngram must be >= 1".into()));
        }
        if !(self.p_effective > 0.0 && self.p_effective < 1.0) {
            return Err(Error::Config(format!("p_effective {} not in (0,1)", self.p_effective)));
        }
        if self.expected_docs == 0 {
            return Err(Error::Config("expected_docs must be positive".into()));
        }
        if self.batch_size == 0 || self.channel_depth == 0 {
            return Err(Error::Config("batch_size/channel_depth must be positive".into()));
        }
        if self.shards == 0 {
            return Err(Error::Config("shards must be >= 1".into()));
        }
        if self.serve_shards == 0 {
            return Err(Error::Config("serve_shards must be >= 1".into()));
        }
        if self.serve_shards > 1 && self.engine != EngineMode::Concurrent {
            return Err(Error::Config(
                "serve_shards > 1 requires the concurrent engine (band slices are \
                 atomic filters; add engine = concurrent / --engine concurrent)"
                    .into(),
            ));
        }
        if !self.metrics_addr.is_empty() && !self.metrics_addr.contains(':') {
            // Bind errors would surface anyway, but "metrics endpoint
            // never came up" is the kind of misconfiguration an operator
            // only notices when the first scrape fails — reject the
            // obviously port-less form up front.
            return Err(Error::Config(format!(
                "metrics_addr '{}' is not HOST:PORT",
                self.metrics_addr
            )));
        }
        if !(0.0..=1.0).contains(&self.trace_sample) {
            return Err(Error::Config(format!(
                "trace_sample {} not in [0,1]",
                self.trace_sample
            )));
        }
        if !(0.0..1.0).contains(&self.rotate_watermark) {
            return Err(Error::Config(format!(
                "rotate_watermark {} not in [0,1) (0 disables generation rotation)",
                self.rotate_watermark
            )));
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() && !self.distributed {
            // Distributed runs are exempt: each worker checkpoints into
            // its own directory under the state root, which the CLI
            // defaults to a temp dir when checkpoint_dir is unset.
            return Err(Error::Config(
                "checkpoint_every requires a checkpoint_dir".into(),
            ));
        }
        if self.distributed && self.shards < 2 {
            return Err(Error::Config(
                "distributed mode requires shards >= 2 (one worker process per \
                 shard; a single shard is just the plain concurrent engine)"
                    .into(),
            ));
        }
        if self.checkpoint_every > 0 && self.shards > 1 && !self.distributed {
            return Err(Error::Config(
                "checkpoint_every is not supported with shards > 1 (each shard \
                 checkpoints once, after its phase-1 ingest); silently ignoring it \
                 would promise periodic durability the sharded path does not provide \
                 (distributed workers do honor it — add distributed = true)"
                    .into(),
            ));
        }
        if !self.checkpoint_dir.is_empty()
            && self.shards == 1
            && self.engine != EngineMode::Concurrent
        {
            return Err(Error::Config(
                "checkpoint_dir requires the concurrent engine (the classic index \
                 persists via LshBloomIndex::save_dir)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Resolved worker count.
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }

    /// Load from a TOML-subset file and overlay onto defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let kv = parse_toml_subset(&text)?;
        let mut cfg = Self::default();
        cfg.apply(&kv)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply string key/values (from file or CLI) onto this config.
    pub fn apply(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            let bad = |what: &str| Error::Config(format!("bad {what} value '{v}'"));
            match k.as_str() {
                "threshold" | "pipeline.threshold" | "capacity.threshold" => {
                    self.threshold = v.parse().map_err(|_| bad("threshold"))?
                }
                "num_perms" | "pipeline.num_perms" => {
                    self.num_perms = v.parse().map_err(|_| bad("num_perms"))?
                }
                "ngram" | "pipeline.ngram" => self.ngram = v.parse().map_err(|_| bad("ngram"))?,
                "p_effective" | "bloom.p_effective" | "capacity.fp_budget" => {
                    self.p_effective = v.parse().map_err(|_| bad("p_effective"))?
                }
                "expected_docs" | "bloom.expected_docs" | "capacity.expect_docs" => {
                    self.expected_docs = v.parse().map_err(|_| bad("expected_docs"))?
                }
                "workers" | "pipeline.workers" => {
                    self.workers = v.parse().map_err(|_| bad("workers"))?
                }
                "batch_size" | "pipeline.batch_size" => {
                    self.batch_size = v.parse().map_err(|_| bad("batch_size"))?
                }
                "backend" | "pipeline.backend" => self.backend = MinHashBackend::parse(v)?,
                "artifacts_dir" | "pipeline.artifacts_dir" => self.artifacts_dir = v.clone(),
                "use_shm" | "bloom.use_shm" => {
                    self.use_shm = matches!(v.as_str(), "true" | "1")
                }
                "blocked_bloom" | "bloom.blocked" => {
                    self.blocked_bloom = matches!(v.as_str(), "true" | "1")
                }
                "channel_depth" | "pipeline.channel_depth" => {
                    self.channel_depth = v.parse().map_err(|_| bad("channel_depth"))?
                }
                "engine" | "pipeline.engine" => self.engine = EngineMode::parse(v)?,
                "shards" | "pipeline.shards" => {
                    self.shards = v.parse().map_err(|_| bad("shards"))?
                }
                "distributed" | "pipeline.distributed" => {
                    self.distributed = matches!(v.as_str(), "true" | "1")
                }
                "checkpoint_dir" | "persist.checkpoint_dir" => self.checkpoint_dir = v.clone(),
                "checkpoint_every" | "persist.checkpoint_every" => {
                    self.checkpoint_every = v.parse().map_err(|_| bad("checkpoint_every"))?
                }
                "serve_shards" | "service.serve_shards" => {
                    self.serve_shards = v.parse().map_err(|_| bad("serve_shards"))?
                }
                "metrics_addr" | "service.metrics_addr" => self.metrics_addr = v.clone(),
                "trace_sample" | "service.trace_sample" => {
                    self.trace_sample = v.parse().map_err(|_| bad("trace_sample"))?
                }
                "trace_slow_ms" | "service.trace_slow_ms" => {
                    self.trace_slow_ms = v.parse().map_err(|_| bad("trace_slow_ms"))?
                }
                "rotate_watermark" | "capacity.rotate_watermark" => {
                    self.rotate_watermark = v.parse().map_err(|_| bad("rotate_watermark"))?
                }
                other => return Err(Error::Config(format!("unknown config key '{other}'"))),
            }
        }
        Ok(())
    }
}

/// Parse `key = value` lines with optional `[section]` headers; values may
/// be bare, quoted, numeric, or booleans. Comments start with `#`.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(Error::parse("config", format!("line {}: no '='", lineno + 1)));
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        out.insert(key, val);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        PipelineConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_subset_parses_sections_and_comments() {
        let kv = parse_toml_subset(
            "# comment\nthreshold = 0.8\n[bloom]\np_effective = 1e-5 # inline\nuse_shm = true\n",
        )
        .unwrap();
        assert_eq!(kv["threshold"], "0.8");
        assert_eq!(kv["bloom.p_effective"], "1e-5");
        assert_eq!(kv["bloom.use_shm"], "true");
    }

    #[test]
    fn apply_overrides() {
        let mut cfg = PipelineConfig::default();
        let kv = parse_toml_subset("threshold = 0.8\nnum_perms = 128\nbackend = xla").unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.threshold, 0.8);
        assert_eq!(cfg.num_perms, 128);
        assert_eq!(cfg.backend, MinHashBackend::Xla);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse_toml_subset("bogus = 1").unwrap()).is_err());
        assert!(cfg.apply(&parse_toml_subset("threshold = x").unwrap()).is_err());
    }

    #[test]
    fn validate_catches_bad_combos() {
        let cfg = PipelineConfig { threshold: 1.5, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = PipelineConfig { p_effective: 0.0, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = PipelineConfig { ngram: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn backend_parse() {
        assert_eq!(MinHashBackend::parse("xla").unwrap(), MinHashBackend::Xla);
        assert!(MinHashBackend::parse("gpu").is_err());
    }

    #[test]
    fn shards_key_applies_and_validates() {
        let mut cfg = PipelineConfig::default();
        assert_eq!(cfg.shards, 1);
        cfg.apply(&parse_toml_subset("[pipeline]\nshards = 8").unwrap()).unwrap();
        assert_eq!(cfg.shards, 8);
        cfg.validate().unwrap();
        cfg.shards = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse_toml_subset("shards = x").unwrap()).is_err());
    }

    #[test]
    fn checkpoint_keys_apply_and_validate() {
        let mut cfg = PipelineConfig::default();
        cfg.apply(
            &parse_toml_subset(
                "[persist]\ncheckpoint_dir = \"state\"\ncheckpoint_every = 1000000",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_dir, "state");
        assert_eq!(cfg.checkpoint_every, 1_000_000);
        // checkpoint_dir needs the concurrent engine (unsharded)...
        assert!(cfg.validate().is_err());
        cfg.engine = EngineMode::Concurrent;
        cfg.validate().unwrap();
        // ...or a sharded run (per-shard checkpoint root) — but only
        // without checkpoint_every, which the sharded path cannot honor.
        cfg.engine = EngineMode::Classic;
        cfg.shards = 4;
        assert!(cfg.validate().is_err(), "periodic checkpoints + shards must be rejected");
        cfg.checkpoint_every = 0;
        cfg.validate().unwrap();
        // checkpoint_every without a dir is a hard error.
        let cfg = PipelineConfig { checkpoint_every: 10, ..Default::default() };
        assert!(cfg.validate().is_err());
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse_toml_subset("checkpoint_every = x").unwrap()).is_err());
    }

    #[test]
    fn distributed_key_applies_and_validates() {
        let mut cfg = PipelineConfig::default();
        assert!(!cfg.distributed);
        cfg.apply(&parse_toml_subset("[pipeline]\ndistributed = true").unwrap()).unwrap();
        assert!(cfg.distributed);
        // ...but distributed alone is invalid: it needs shards to split.
        assert!(cfg.validate().is_err(), "distributed without shards must be rejected");
        cfg.shards = 4;
        cfg.validate().unwrap();
        cfg.checkpoint_dir = "state".into();
        cfg.validate().unwrap();
        // Periodic worker checkpoints are a distributed-only feature for
        // sharded runs — and legal even without an explicit state root
        // (the CLI falls back to a temp dir).
        cfg.checkpoint_every = 1000;
        cfg.validate().unwrap();
        cfg.checkpoint_dir = String::new();
        cfg.validate().unwrap();
        cfg.distributed = false;
        assert!(cfg.validate().is_err(), "periodic checkpoints + in-process shards stay rejected");
    }

    #[test]
    fn serve_shards_key_applies_and_validates() {
        let mut cfg = PipelineConfig::default();
        assert_eq!(cfg.serve_shards, 1);
        cfg.apply(&parse_toml_subset("[service]\nserve_shards = 4").unwrap()).unwrap();
        assert_eq!(cfg.serve_shards, 4);
        // ...but sliced serving needs the concurrent engine...
        assert!(cfg.validate().is_err(), "serve_shards without concurrent engine rejected");
        cfg.engine = EngineMode::Concurrent;
        cfg.validate().unwrap();
        // ...and zero slices is nonsense.
        cfg.serve_shards = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse_toml_subset("serve_shards = x").unwrap()).is_err());
    }

    #[test]
    fn metrics_addr_key_applies_and_validates() {
        let mut cfg = PipelineConfig::default();
        assert!(cfg.metrics_addr.is_empty(), "metrics endpoint is off by default");
        cfg.apply(&parse_toml_subset("[service]\nmetrics_addr = \"127.0.0.1:9400\"").unwrap())
            .unwrap();
        assert_eq!(cfg.metrics_addr, "127.0.0.1:9400");
        cfg.validate().unwrap();
        cfg.metrics_addr = "no-port-here".into();
        assert!(cfg.validate().is_err(), "port-less metrics_addr rejected");
        cfg.metrics_addr.clear();
        cfg.validate().unwrap();
    }

    #[test]
    fn trace_keys_apply_and_validate() {
        let mut cfg = PipelineConfig::default();
        assert_eq!(cfg.trace_sample, 0.0, "tracing is off by default");
        assert_eq!(cfg.trace_slow_ms, 0);
        cfg.apply(
            &parse_toml_subset("[service]\ntrace_sample = 0.25\ntrace_slow_ms = 250").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.trace_sample, 0.25);
        assert_eq!(cfg.trace_slow_ms, 250);
        cfg.validate().unwrap();
        // Probabilities outside [0,1] are misconfigurations, not clamps.
        cfg.trace_sample = 1.5;
        assert!(cfg.validate().is_err(), "trace_sample > 1 rejected");
        cfg.trace_sample = -0.1;
        assert!(cfg.validate().is_err(), "negative trace_sample rejected");
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse_toml_subset("trace_sample = x").unwrap()).is_err());
        assert!(cfg.apply(&parse_toml_subset("trace_slow_ms = -3").unwrap()).is_err());
    }

    #[test]
    fn capacity_keys_apply_and_validate() {
        let mut cfg = PipelineConfig::default();
        assert_eq!(cfg.rotate_watermark, 0.5, "rotation defaults to the at-capacity fill");
        cfg.apply(
            &parse_toml_subset(
                "[capacity]\nthreshold = 0.8\nexpect_docs = 5000000\nfp_budget = 1e-8\n\
                 rotate_watermark = 0.7",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.threshold, 0.8);
        assert_eq!(cfg.expected_docs, 5_000_000);
        assert_eq!(cfg.p_effective, 1e-8);
        assert_eq!(cfg.rotate_watermark, 0.7);
        cfg.validate().unwrap();
        // 0 disables rotation; a full or negative watermark is nonsense.
        cfg.rotate_watermark = 0.0;
        cfg.validate().unwrap();
        cfg.rotate_watermark = 1.0;
        assert!(cfg.validate().is_err(), "watermark 1.0 can never fire");
        cfg.rotate_watermark = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse_toml_subset("rotate_watermark = x").unwrap()).is_err());
    }

    #[test]
    fn engine_parse_and_apply() {
        assert_eq!(EngineMode::parse("classic").unwrap(), EngineMode::Classic);
        assert_eq!(EngineMode::parse("concurrent").unwrap(), EngineMode::Concurrent);
        assert!(EngineMode::parse("turbo").is_err());
        let mut cfg = PipelineConfig::default();
        assert_eq!(cfg.engine, EngineMode::Classic);
        cfg.apply(&parse_toml_subset("[pipeline]\nengine = concurrent").unwrap()).unwrap();
        assert_eq!(cfg.engine, EngineMode::Concurrent);
    }
}
