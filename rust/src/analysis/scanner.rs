//! Line-oriented Rust source scanner: the lexical substrate every lint
//! rule matches against.
//!
//! Rules must never fire on text inside comments or string literals
//! (`"call .unwrap() here"` in a doc comment is not a panic site), and
//! conversely must be able to *read* comments (`// SAFETY:`
//! justifications, `// lint: allow(...)` escapes) and string contents
//! (wire-op and metric-name literals). So the scanner splits every
//! source line into three channels:
//!
//! * [`ScannedLine::code`] — code with comments removed and
//!   string/char-literal *contents* removed (delimiters kept, so brace
//!   tracking still works);
//! * [`ScannedLine::code_strs`] — code with comments removed but
//!   literals intact (for rules that extract `"op"`/metric names);
//! * [`ScannedLine::comment`] — the comment text on that line,
//!   including each line's share of a multi-line `/* */` block.
//!
//! The splitter is a character-level state machine that understands
//! nested block comments, escapes inside string and char literals, raw
//! strings (`r"…"`, `r#"…"#`, any hash depth), byte literals, and the
//! char-literal/lifetime ambiguity of `'` (`'x'` and `'"'` are
//! literals; `'a` in `&'a str` is a lifetime tick). A second pass marks
//! every line covered by a `#[cfg(test)]` item via brace-depth
//! tracking, so rules can exempt test code.

/// One source line, split into its lexical channels.
pub struct ScannedLine {
    /// Code with comments stripped and literal contents blanked.
    pub code: String,
    /// Code with comments stripped, literals kept verbatim.
    pub code_strs: String,
    /// Comment text present on this line (line or block).
    pub comment: String,
    /// Whether the line is inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

/// One `// lint: allow(<rule>)` escape found in a comment.
pub struct Escape {
    /// 1-indexed line the escape comment sits on.
    pub line: usize,
    /// Rule name inside the parentheses.
    pub rule: String,
}

/// A fully scanned source file.
pub struct ScannedFile {
    /// Path relative to the crate root, forward slashes (`src/...`).
    pub path: String,
    /// Per-line channels, index 0 = line 1.
    pub lines: Vec<ScannedLine>,
    /// Every lint-allow escape in the file, in line order.
    pub escapes: Vec<Escape>,
}

/// Lexer state carried across lines.
enum St {
    Code,
    Line,
    Block(u32),
    Str,
    RawStr(u32),
    Char,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Number of `#`s between `r` at `i` and the opening quote, or `None`
/// if the characters after `i` do not start a raw string.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i + 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

/// Scan `text` into per-line channels. `path` is stored verbatim.
pub fn scan(path: &str, text: &str) -> ScannedFile {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut lines: Vec<ScannedLine> = Vec::new();
    let mut code = String::new();
    let mut code_strs = String::new();
    let mut comment = String::new();
    let mut st = St::Code;
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::Line) {
                st = St::Code;
            }
            lines.push(ScannedLine {
                code: std::mem::take(&mut code),
                code_strs: std::mem::take(&mut code_strs),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::Line;
                    comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    code_strs.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'r' && !prev_is_ident_except_b(&chars, i) {
                    if let Some(h) = raw_string_hashes(&chars, i) {
                        code.push_str("r\"");
                        for k in 0..(2 + h as usize) {
                            code_strs.push(chars[i + k]);
                        }
                        st = St::RawStr(h);
                        i += 2 + h as usize;
                    } else {
                        code.push(c);
                        code_strs.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal iff the tick is followed by an escape,
                    // or by exactly one char and a closing tick;
                    // otherwise it is a lifetime (`'a`, `'static`, `'_`).
                    let is_char_lit = match next {
                        Some('\\') => true,
                        Some(_) => chars.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    code.push('\'');
                    code_strs.push('\'');
                    if is_char_lit {
                        st = St::Char;
                    }
                    i += 1;
                } else {
                    code.push(c);
                    code_strs.push(c);
                    i += 1;
                }
            }
            St::Line => {
                comment.push(c);
                i += 1;
            }
            St::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth <= 1 { St::Code } else { St::Block(depth - 1) };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    code_strs.push(c);
                    // Keep the escaped char out of delimiter detection;
                    // a bare trailing backslash (line continuation) lets
                    // the top-of-loop newline handling run.
                    if let Some(&e) = chars.get(i + 1) {
                        if e != '\n' {
                            code_strs.push(e);
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    code_strs.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    code_strs.push(c);
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' {
                    let mut all = true;
                    for k in 0..h as usize {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            all = false;
                            break;
                        }
                    }
                    if all {
                        code.push('"');
                        for k in 0..=(h as usize) {
                            code_strs.push(chars[i + k]);
                        }
                        st = St::Code;
                        i += 1 + h as usize;
                    } else {
                        code_strs.push(c);
                        i += 1;
                    }
                } else {
                    code_strs.push(c);
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' {
                    code_strs.push(c);
                    if let Some(&e) = chars.get(i + 1) {
                        code_strs.push(e);
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '\'' {
                    code.push('\'');
                    code_strs.push('\'');
                    st = St::Code;
                    i += 1;
                } else {
                    code_strs.push(c);
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !code_strs.is_empty() || !comment.is_empty() {
        lines.push(ScannedLine { code, code_strs, comment, in_test: false });
    }

    mark_test_regions(&mut lines);
    let escapes = collect_escapes(&lines);
    ScannedFile { path: path.to_string(), lines, escapes }
}

/// Whether `chars[i-1]` is an identifier char, treating a lone `b`
/// prefix (byte/raw-byte string) as *not* one so `br#"…"#` still scans
/// as a raw string.
fn prev_is_ident_except_b(chars: &[char], i: usize) -> bool {
    if i == 0 {
        return false;
    }
    let prev = chars[i - 1];
    if !is_ident(prev) {
        return false;
    }
    // A lone `b` before `r` is the byte-string prefix, not an ident.
    prev != 'b' || (i >= 2 && is_ident(chars[i - 2]))
}

/// Mark every line covered by a `#[cfg(test)]` item (attribute line,
/// item header, body, and closing brace) via brace-depth tracking over
/// the stripped code channel.
fn mark_test_regions(lines: &mut [ScannedLine]) {
    let mut depth: i64 = 0;
    let mut region_depth: Option<i64> = None;
    let mut pending = false;
    for line in lines.iter_mut() {
        let mut flag = region_depth.is_some() || pending;
        if line.code.contains("#[cfg(test)]") {
            pending = true;
            flag = true;
        }
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    if pending && region_depth.is_none() {
                        region_depth = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if region_depth == Some(depth) {
                        region_depth = None;
                    }
                }
                _ => {}
            }
        }
        line.in_test = flag || region_depth.is_some() || pending;
    }
}

/// Pull every `lint: allow(<rule>)` escape out of the comment channel.
fn collect_escapes(lines: &[ScannedLine]) -> Vec<Escape> {
    const MARK: &str = "lint: allow(";
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        // Escapes are working comments, not documentation: a doc
        // comment (`///`, `//!`) quoting the syntax in prose must not
        // act as (or be charged as) an escape.
        let c = line.comment.trim_start();
        if c.starts_with("///") || c.starts_with("//!") {
            continue;
        }
        let mut rest = line.comment.as_str();
        while let Some(pos) = rest.find(MARK) {
            let tail = &rest[pos + MARK.len()..];
            if let Some(close) = tail.find(')') {
                let rule = tail[..close].trim().to_string();
                if !rule.is_empty() {
                    out.push(Escape { line: idx + 1, rule });
                }
                rest = &tail[close + 1..];
            } else {
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(file: &ScannedFile, line: usize) -> &str {
        &file.lines[line - 1].code
    }

    #[test]
    fn line_comments_are_stripped() {
        let f = scan("t.rs", "let x = 1; // .unwrap() in a comment\n");
        assert!(!code_of(&f, 1).contains("unwrap"));
        assert!(f.lines[0].comment.contains(".unwrap()"));
        assert!(code_of(&f, 1).contains("let x = 1;"));
    }

    #[test]
    fn nested_block_comments_strip_to_the_outer_close() {
        let src = "a(); /* outer /* inner */ still comment */ b();\n";
        let f = scan("t.rs", src);
        assert!(code_of(&f, 1).contains("a();"));
        assert!(code_of(&f, 1).contains("b();"));
        assert!(!code_of(&f, 1).contains("inner"));
        assert!(f.lines[0].comment.contains("inner"));
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let src = "x();\n/* panic!(\n   todo!( */\ny();\n";
        let f = scan("t.rs", src);
        assert!(!code_of(&f, 2).contains("panic"));
        assert!(!code_of(&f, 3).contains("todo"));
        assert!(code_of(&f, 4).contains("y();"));
    }

    #[test]
    fn string_contents_are_blanked_but_kept_in_code_strs() {
        let src = "let s = \"call .unwrap() // not a comment\"; f();\n";
        let f = scan("t.rs", src);
        assert!(!code_of(&f, 1).contains("unwrap"));
        assert!(code_of(&f, 1).contains("f();"));
        assert!(f.lines[0].code_strs.contains(".unwrap()"));
        assert!(f.lines[0].comment.is_empty(), "// inside a string is not a comment");
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let src = "let s = \"a\\\"b // c\"; g();\n";
        let f = scan("t.rs", src);
        assert!(code_of(&f, 1).contains("g();"));
        assert!(f.lines[0].comment.is_empty());
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let s = r#\"panic!(\"inner\") // x\"#; h();\n";
        let f = scan("t.rs", src);
        assert!(!code_of(&f, 1).contains("panic"));
        assert!(code_of(&f, 1).contains("h();"));
        assert!(f.lines[0].code_strs.contains("panic!"));
        assert!(f.lines[0].comment.is_empty());
    }

    #[test]
    fn multiline_raw_string_spans_lines() {
        let src = "let s = r#\"line one .unwrap()\nline two println!\n\"#; tail();\n";
        let f = scan("t.rs", src);
        assert!(!code_of(&f, 1).contains("unwrap"));
        assert!(!code_of(&f, 2).contains("println"));
        assert!(code_of(&f, 3).contains("tail();"));
    }

    #[test]
    fn quote_char_literal_does_not_open_a_string() {
        // '"' must scan as a char literal, not as a string opener that
        // would swallow the rest of the file.
        let src = "let q = '\"'; let x = '{'; real_code();\n";
        let f = scan("t.rs", src);
        assert!(code_of(&f, 1).contains("real_code();"));
        // The brace inside the char literal must not skew depth tracking.
        assert!(!code_of(&f, 1).contains('{'));
    }

    #[test]
    fn lifetime_ticks_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x } // trailing\n";
        let f = scan("t.rs", src);
        assert!(code_of(&f, 1).contains("&'a str"));
        assert!(f.lines[0].comment.contains("trailing"));
    }

    #[test]
    fn escaped_char_literals() {
        let src = "let a = '\\''; let b = '\\\\'; let c = '\\u{1F600}'; z();\n";
        let f = scan("t.rs", src);
        assert!(code_of(&f, 1).contains("z();"));
        assert!(!code_of(&f, 1).contains("1F600"));
    }

    #[test]
    fn cfg_test_region_is_marked_through_nested_braces() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn inner() { if true { x(); } }\n\
                   }\n\
                   fn also_live() {}\n";
        let f = scan("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test, "attribute line is test code");
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test, "closing brace is test code");
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn allow_escape_roundtrip() {
        let src = "a(); // lint: allow(no-stray-print) bench reporter\n\
                   // lint: allow(ordering-discipline)\n\
                   b();\n";
        let f = scan("t.rs", src);
        assert_eq!(f.escapes.len(), 2);
        assert_eq!(f.escapes[0].line, 1);
        assert_eq!(f.escapes[0].rule, "no-stray-print");
        assert_eq!(f.escapes[1].line, 2);
        assert_eq!(f.escapes[1].rule, "ordering-discipline");
    }

    #[test]
    fn doc_comments_quoting_the_syntax_are_not_escapes() {
        let src = "/// Write `// lint: allow(no-stray-print)` above the line.\n\
                   //! And `lint: allow(ordering-discipline)` in module docs.\n\
                   a(); // lint: allow(no-stray-print)\n";
        let f = scan("t.rs", src);
        assert_eq!(f.escapes.len(), 1, "doc-comment mentions must not be escapes");
        assert_eq!(f.escapes[0].line, 3);
    }

    #[test]
    fn escape_inside_string_is_not_an_escape() {
        let src = "let s = \"// lint: allow(no-stray-print)\";\n";
        let f = scan("t.rs", src);
        assert!(f.escapes.is_empty());
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let src = "let a = b\"panic!\"; let b2 = br#\"todo!\"#; k();\n";
        let f = scan("t.rs", src);
        assert!(!code_of(&f, 1).contains("panic"));
        assert!(!code_of(&f, 1).contains("todo"));
        assert!(code_of(&f, 1).contains("k();"));
    }

    #[test]
    fn last_line_without_newline_is_kept() {
        let f = scan("t.rs", "fn f() {}");
        assert_eq!(f.lines.len(), 1);
        assert!(f.lines[0].code.contains("fn f()"));
    }
}
