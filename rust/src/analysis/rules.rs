//! Per-file lint rules: invariants checkable one source file at a time.
//!
//! Every rule walks the channels produced by [`super::scanner`] and
//! emits [`Finding`]s with 1-indexed line numbers. Escape handling
//! (`// lint: allow(<rule>)`) is applied by the engine in
//! [`super::lint_set`], not here — rules always report raw hits.
//!
//! | Rule | Invariant |
//! |---|---|
//! | `safety-comment` | every `unsafe` site carries a `// SAFETY:` justification |
//! | `no-panic-paths` | no `unwrap`/`expect`/`panic!`/`todo!` in serving/persistence non-test code |
//! | `ordering-discipline` | no `Ordering::Relaxed` on filter loads/`fetch_or` in `bloom/`, `engine/`, `persist/` |
//! | `no-stray-print` | `println!`/`dbg!` only in the CLI, report, and bench layers |

use super::scanner::ScannedFile;
use super::Finding;

/// Rule name: `unsafe` sites must carry a `// SAFETY:` comment.
pub const SAFETY_COMMENT: &str = "safety-comment";
/// Rule name: panic-capable calls banned in serving/persistence paths.
pub const NO_PANIC_PATHS: &str = "no-panic-paths";
/// Rule name: relaxed ordering banned on verdict-carrying atomics.
pub const ORDERING_DISCIPLINE: &str = "ordering-discipline";
/// Rule name: `println!`/`dbg!` confined to CLI/report/bench layers.
pub const NO_STRAY_PRINT: &str = "no-stray-print";

/// Whether `code` contains `token` delimited by non-identifier chars
/// (so `unsafe_op_in_unsafe_fn` does not count as `unsafe`, and
/// `eprintln!` does not count as `println!`).
pub(crate) fn has_token(code: &str, token: &str) -> bool {
    let bytes = code.as_bytes();
    for (p, _) in code.match_indices(token) {
        let before_ok = p == 0 || {
            let b = bytes[p - 1] as char;
            !(b.is_ascii_alphanumeric() || b == '_')
        };
        let after = p + token.len();
        let after_ok = after >= bytes.len() || {
            let b = bytes[after] as char;
            !(b.is_ascii_alphanumeric() || b == '_')
        };
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

/// Run every per-file rule over one scanned file.
pub fn per_file_rules(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    safety_comment(file, &mut out);
    no_panic_paths(file, &mut out);
    ordering_discipline(file, &mut out);
    no_stray_print(file, &mut out);
    out
}

/// Count `unsafe` sites (lines holding an `unsafe` token) in a file —
/// exposed so the integration test can assert the tree-wide inventory
/// the SAFETY sweep covers.
pub fn count_unsafe_sites(file: &ScannedFile) -> usize {
    file.lines.iter().filter(|l| has_token(&l.code, "unsafe")).count()
}

/// `safety-comment`: every line with an `unsafe` token must have a
/// comment containing `SAFETY:` on the same line or in the contiguous
/// run of comment/attribute lines directly above it.
fn safety_comment(file: &ScannedFile, out: &mut Vec<Finding>) {
    for (idx, line) in file.lines.iter().enumerate() {
        if !has_token(&line.code, "unsafe") {
            continue;
        }
        let mut text = line.comment.clone();
        let mut j = idx;
        while j > 0 {
            let prev = &file.lines[j - 1];
            let code = prev.code.trim();
            let is_attr = code.starts_with("#[") || code.starts_with("#![");
            if code.is_empty() && prev.comment.trim().is_empty() {
                break; // blank line ends the run
            }
            if !code.is_empty() && !is_attr {
                break; // real code ends the run
            }
            text.push_str(&prev.comment);
            j -= 1;
        }
        if !text.contains("SAFETY:") {
            out.push(Finding::new(
                &file.path,
                idx + 1,
                SAFETY_COMMENT,
                "unsafe site without a `// SAFETY:` justification directly above it",
            ));
        }
    }
}

/// Paths where a panic would kill a serving thread or tear persistent
/// state mid-write — the zones `no-panic-paths` protects.
fn panic_free_zone(path: &str) -> bool {
    path.starts_with("src/service/")
        || path.starts_with("src/persist/")
        || path == "src/pipeline/supervisor.rs"
}

/// `no-panic-paths`: inside the panic-free zones, non-test code must
/// not call `.unwrap()`, `.expect(...)`, `panic!`, or `todo!` —
/// failures must become error replies or propagated `Result`s.
fn no_panic_paths(file: &ScannedFile, out: &mut Vec<Finding>) {
    if !panic_free_zone(&file.path) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let hit = if code.contains(".unwrap()") {
            Some(".unwrap()")
        } else if code.contains(".expect(") {
            Some(".expect(...)")
        } else if has_token(code, "panic!") {
            Some("panic!")
        } else if has_token(code, "todo!") {
            Some("todo!")
        } else {
            None
        };
        if let Some(what) = hit {
            out.push(Finding::new(
                &file.path,
                idx + 1,
                NO_PANIC_PATHS,
                &format!("{what} in a panic-free zone; return an error instead"),
            ));
        }
    }
}

/// Directories whose atomics carry dedup verdicts or checkpoint bits.
fn ordering_zone(path: &str) -> bool {
    path.starts_with("src/bloom/")
        || path.starts_with("src/engine/")
        || path.starts_with("src/persist/")
}

/// `ordering-discipline`: in `bloom/`, `engine/`, `persist/` non-test
/// code, `Ordering::Relaxed` must not appear on a line that loads or
/// `fetch_or`s an atomic — verdict-carrying filter traffic needs
/// acquire/release pairing. Monotone stat counters are annotated with
/// `// lint: allow(ordering-discipline)` instead.
fn ordering_discipline(file: &ScannedFile, out: &mut Vec<Finding>) {
    if !ordering_zone(&file.path) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        if code.contains("Ordering::Relaxed")
            && (code.contains(".load(") || code.contains(".fetch_or("))
        {
            out.push(Finding::new(
                &file.path,
                idx + 1,
                ORDERING_DISCIPLINE,
                "Ordering::Relaxed on a load/fetch_or in a verdict-carrying module; \
                 use Acquire/Release (or annotate a stat counter)",
            ));
        }
    }
}

/// Layers whose job is writing to stdout.
fn print_allowed(path: &str) -> bool {
    path == "src/main.rs"
        || path.starts_with("src/cli/")
        || path.starts_with("src/report/")
        || path.starts_with("benches/")
}

/// `no-stray-print`: `println!`/`dbg!` are debugging leftovers
/// everywhere except the CLI, report, and bench layers — library code
/// logs through `crate::logging` macros instead. Applies to test code
/// too (stray prints in integration tests pollute harness output).
fn no_stray_print(file: &ScannedFile, out: &mut Vec<Finding>) {
    if print_allowed(&file.path) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        for token in ["println!", "dbg!"] {
            if has_token(&line.code, token) {
                out.push(Finding::new(
                    &file.path,
                    idx + 1,
                    NO_STRAY_PRINT,
                    &format!("{token} outside the CLI/report/bench layers; use logging macros"),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::scan;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        per_file_rules(&scan(path, src))
    }

    fn rules_of(f: &[Finding]) -> Vec<&str> {
        f.iter().map(|x| x.rule.as_str()).collect()
    }

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "fn f(p: *const u64) -> u64 {\n    unsafe { *p }\n}\n";
        let f = findings("src/bloom/x.rs", src);
        assert!(rules_of(&f).contains(&SAFETY_COMMENT), "{f:?}");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn safety_comment_above_or_inline_satisfies_the_rule() {
        let above =
            "fn f(p: *const u64) -> u64 {\n    // SAFETY: p is valid\n    unsafe { *p }\n}\n";
        assert!(findings("src/bloom/x.rs", above).is_empty());
        let inline = "fn f(p: *const u64) -> u64 {\n    unsafe { *p } // SAFETY: p is valid\n}\n";
        assert!(findings("src/bloom/x.rs", inline).is_empty());
    }

    #[test]
    fn safety_comment_run_passes_through_attributes() {
        let src = "// SAFETY: exclusive owner\n#[allow(dead_code)]\nunsafe impl Send for X {}\n";
        assert!(findings("src/bloom/x.rs", src).is_empty());
    }

    #[test]
    fn blank_line_breaks_the_safety_comment_run() {
        let src = "// SAFETY: stale\n\nunsafe impl Send for X {}\n";
        let f = findings("src/bloom/x.rs", src);
        assert!(rules_of(&f).contains(&SAFETY_COMMENT));
    }

    #[test]
    fn unsafe_in_identifier_or_comment_is_not_a_site() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\n// unsafe in prose\nfn f() {}\n";
        assert!(findings("src/bloom/x.rs", src).is_empty());
    }

    #[test]
    fn panic_sites_flagged_only_in_zone_and_outside_tests() {
        let src = "fn f() { x.unwrap(); }\n\
                   fn g() { y.expect(\"boom\"); }\n\
                   fn h() { panic!(\"no\"); }\n\
                   fn i() { todo!() }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { z.unwrap(); }\n\
                   }\n";
        let f = findings("src/service/x.rs", src);
        assert_eq!(rules_of(&f), vec![NO_PANIC_PATHS; 4], "{f:?}");
        assert_eq!(f.iter().map(|x| x.line).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        assert!(findings("src/engine/x.rs", src).is_empty(), "engine is not a panic-free zone");
    }

    #[test]
    fn unwrap_or_variants_are_not_flagged() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(|| 1); z.expect_err(\"e\"); }\n";
        assert!(findings("src/service/x.rs", src).is_empty());
    }

    #[test]
    fn relaxed_load_and_fetch_or_flagged_in_zone() {
        let src = "fn f(w: &AtomicU64) {\n\
                       w.load(Ordering::Relaxed);\n\
                       w.fetch_or(1, Ordering::Relaxed);\n\
                       w.fetch_add(1, Ordering::Relaxed);\n\
                       w.load(Ordering::Acquire);\n\
                   }\n";
        let f = findings("src/engine/x.rs", src);
        assert_eq!(rules_of(&f), vec![ORDERING_DISCIPLINE; 2], "{f:?}");
        assert_eq!(f.iter().map(|x| x.line).collect::<Vec<_>>(), vec![2, 3]);
        assert!(findings("src/obs/x.rs", src).is_empty(), "obs is allowlisted");
    }

    #[test]
    fn stray_print_flagged_outside_allowed_layers() {
        let src = "fn f() { println!(\"x\"); dbg!(1); eprintln!(\"ok\"); }\n";
        let f = findings("src/engine/x.rs", src);
        assert_eq!(rules_of(&f), vec![NO_STRAY_PRINT; 2], "eprintln must not match");
        assert!(findings("src/cli/x.rs", src).is_empty());
        assert!(findings("src/main.rs", src).is_empty());
        assert!(findings("src/report/x.rs", src).is_empty());
    }

    #[test]
    fn stray_print_in_test_code_is_still_flagged() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { println!(\"debug\"); }\n}\n";
        let f = findings("tests/x.rs", src);
        assert_eq!(rules_of(&f), vec![NO_STRAY_PRINT]);
    }

    #[test]
    fn patterns_inside_strings_and_comments_never_fire() {
        let src = "fn f() {\n\
                       let s = \"call .unwrap() and println! now\";\n\
                       // .expect( panic! todo! println! dbg!\n\
                       let r = r#\"Ordering::Relaxed .load(\"#;\n\
                   }\n";
        assert!(findings("src/service/x.rs", src).is_empty());
        assert!(findings("src/engine/x.rs", src).is_empty());
    }
}
