//! Cross-file lint rules: invariants spanning source, docs, and the
//! build manifest — the checks no off-the-shelf linter can express.
//!
//! | Rule | Invariant |
//! |---|---|
//! | `wire-op-parity` | every `"op"` the server/router dispatches has a `DedupClient` sender and a docs row |
//! | `metric-catalog` | metric names registered in code and the OPERATIONS.md catalog table match exactly, both ways |
//! | `offline-build` | `[dependencies]` in Cargo.toml stays commented out |

use super::scanner::ScannedFile;
use super::Finding;
use std::collections::BTreeMap;

/// Rule name: server/router/client/docs wire-op parity.
pub const WIRE_OP_PARITY: &str = "wire-op-parity";
/// Rule name: code ↔ OPERATIONS.md metric-name parity.
pub const METRIC_CATALOG: &str = "metric-catalog";
/// Rule name: the crate stays dependency-free.
pub const OFFLINE_BUILD: &str = "offline-build";

/// Display path used for findings anchored in the operations manual.
pub const OPERATIONS_MD: &str = "docs/OPERATIONS.md";
/// Display path used for findings anchored in the build manifest.
pub const CARGO_TOML: &str = "Cargo.toml";

/// A metric/op name is plausible when it is dotted-snake-case; anything
/// else that happens to sit in a matched position (format arguments,
/// prose) is skipped rather than reported as a phantom name.
fn plausible_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '.' || c == '_')
}

/// Extract `"..."` directly after `pat`, or the base name (up to the
/// first `{`) of a `&format!("...")` argument.
fn name_after(line: &str, pat: &str) -> Vec<(String, bool)> {
    let mut out = Vec::new();
    for (p, _) in line.match_indices(pat) {
        let rest = &line[p + pat.len()..];
        if let Some(r) = rest.strip_prefix('"') {
            if let Some(end) = r.find('"') {
                out.push((r[..end].to_string(), false));
            }
        } else if let Some(r) = rest.strip_prefix("&format!(\"") {
            let end = r.find(['{', '"']).unwrap_or(r.len());
            out.push((r[..end].to_string(), true));
        }
    }
    out
}

/// `wire-op-parity`: collect every op string the server and router
/// dispatch on (`Some("<op>")` match arms), then require each to have a
/// `DedupClient` sender (`("op", Value::str("<op>"))` in `client.rs`)
/// and a row in the OPERATIONS.md wire-op catalog — and require the
/// client and docs to list no phantom ops the servers don't dispatch.
pub fn wire_op_parity(files: &[ScannedFile], operations_md: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    // (op -> first dispatch site) across server.rs + router.rs.
    let mut dispatched: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut client_ops: BTreeMap<String, usize> = BTreeMap::new();
    for file in files {
        let is_dispatch =
            file.path == "src/service/server.rs" || file.path == "src/service/router.rs";
        let is_client = file.path == "src/service/client.rs";
        if !is_dispatch && !is_client {
            continue;
        }
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            if is_dispatch {
                for (name, _) in name_after(&line.code_strs, "Some(") {
                    if plausible_name(&name) {
                        dispatched
                            .entry(name)
                            .or_insert_with(|| (file.path.clone(), idx + 1));
                    }
                }
            } else {
                for (name, _) in name_after(&line.code_strs, "(\"op\", Value::str(") {
                    if plausible_name(&name) {
                        client_ops.entry(name).or_insert(idx + 1);
                    }
                }
            }
        }
    }
    let docs_ops = docs_table_names(operations_md, "### Wire-op catalog");
    for (op, (file, lineno)) in &dispatched {
        if !client_ops.contains_key(op) {
            out.push(Finding::new(
                file,
                *lineno,
                WIRE_OP_PARITY,
                &format!("op \"{op}\" is dispatched but DedupClient has no sender for it"),
            ));
        }
        if !docs_ops.contains_key(op.as_str()) {
            out.push(Finding::new(
                file,
                *lineno,
                WIRE_OP_PARITY,
                &format!(
                    "op \"{op}\" is dispatched but missing from the \
                     {OPERATIONS_MD} wire-op catalog"
                ),
            ));
        }
    }
    for (op, lineno) in &client_ops {
        if !dispatched.contains_key(op) {
            out.push(Finding::new(
                "src/service/client.rs",
                *lineno,
                WIRE_OP_PARITY,
                &format!("DedupClient sends op \"{op}\" but no server dispatches it"),
            ));
        }
    }
    for (op, lineno) in &docs_ops {
        if !dispatched.contains_key(op) {
            out.push(Finding::new(
                OPERATIONS_MD,
                *lineno,
                WIRE_OP_PARITY,
                &format!("wire-op catalog documents \"{op}\" but no server dispatches it"),
            ));
        }
    }
    out
}

/// Parse backticked names out of the first cell of every table row in
/// the section headed `header` (e.g. `### Metric catalog`). Returns
/// name → 1-indexed docs line. `{label=…}` suffixes are stripped and
/// `{a,b,c}` alternations expanded, matching how the catalog compresses
/// related series into one row.
fn docs_table_names(operations_md: &str, header: &str) -> BTreeMap<String, usize> {
    let mut out: BTreeMap<String, usize> = BTreeMap::new();
    let mut in_section = false;
    let mut in_table = false;
    for (idx, line) in operations_md.lines().enumerate() {
        if line.trim_start().starts_with('#') {
            in_section = line.trim() == header;
            in_table = false;
            continue;
        }
        if !in_section {
            continue;
        }
        let t = line.trim();
        if !t.starts_with('|') {
            if in_table {
                in_section = false; // table ended; ignore trailing prose
            }
            continue;
        }
        in_table = true;
        let Some(first_cell) = t.split('|').nth(1) else { continue };
        let mut rest = first_cell;
        while let Some(open) = rest.find('`') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('`') else { break };
            for name in expand_docs_name(&tail[..close]) {
                out.entry(name).or_insert(idx + 1);
            }
            rest = &tail[close + 1..];
        }
    }
    out
}

/// Normalize one backticked docs token into zero or more metric names:
/// `x{label="v"}` → `x`; `a.{b,c}.d` → `a.b.d`, `a.c.d`; `{op=…}`
/// annotations (labels without a base) → nothing.
fn expand_docs_name(token: &str) -> Vec<String> {
    let token = token.trim();
    if token.starts_with('{') {
        return Vec::new();
    }
    let Some(open) = token.find('{') else {
        return if plausible_name(token) { vec![token.to_string()] } else { Vec::new() };
    };
    let Some(close) = token.find('}') else { return Vec::new() };
    let (prefix, inner, suffix) = (&token[..open], &token[open + 1..close], &token[close + 1..]);
    if inner.contains('=') {
        let base = format!("{prefix}{suffix}");
        return if plausible_name(&base) { vec![base] } else { Vec::new() };
    }
    inner
        .split(',')
        .map(|alt| format!("{prefix}{alt}{suffix}"))
        .filter(|n| plausible_name(n))
        .collect()
}

/// `metric-catalog`: every metric registered through `obs::global()`
/// (or timed with `obs::span`) in non-test source outside `obs/` itself
/// must appear in the OPERATIONS.md metric catalog, and every
/// documented metric must still be registered somewhere — the catalog
/// can neither rot behind the code nor advertise phantom series.
pub fn metric_catalog(files: &[ScannedFile], operations_md: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut registered: BTreeMap<String, (String, usize)> = BTreeMap::new();
    for file in files {
        // obs/ is the registry implementation (and its exposition
        // tests); analysis/ embeds the extraction patterns as literals.
        // Neither registers real series.
        if !file.path.starts_with("src/")
            || file.path.starts_with("src/obs/")
            || file.path.starts_with("src/analysis/")
        {
            continue;
        }
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for pat in [".counter(", ".gauge(", ".histogram("] {
                for (name, _) in name_after(&line.code_strs, pat) {
                    if plausible_name(&name) {
                        registered
                            .entry(name)
                            .or_insert_with(|| (file.path.clone(), idx + 1));
                    }
                }
            }
            for (name, _) in name_after(&line.code_strs, "span(") {
                if plausible_name(&name) {
                    registered
                        .entry(format!("{name}.seconds"))
                        .or_insert_with(|| (file.path.clone(), idx + 1));
                }
            }
        }
    }
    let documented = docs_table_names(operations_md, "### Metric catalog");
    for (name, (file, lineno)) in &registered {
        if !documented.contains_key(name.as_str()) {
            out.push(Finding::new(
                file,
                *lineno,
                METRIC_CATALOG,
                &format!(
                    "metric \"{name}\" is registered but missing from the \
                     {OPERATIONS_MD} metric catalog"
                ),
            ));
        }
    }
    for (name, lineno) in &documented {
        if !registered.contains_key(name) {
            out.push(Finding::new(
                OPERATIONS_MD,
                *lineno,
                METRIC_CATALOG,
                &format!("metric catalog documents \"{name}\" but nothing registers it"),
            ));
        }
    }
    out
}

/// `offline-build`: the crate's offline guarantee is structural — the
/// `[dependencies]` section (and dev/build variants) must stay
/// commented out so nothing can quietly grow a crates.io dependency.
pub fn offline_build(cargo_toml: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, line) in cargo_toml.lines().enumerate() {
        let t = line.trim();
        if t == "[dependencies]" || t == "[dev-dependencies]" || t == "[build-dependencies]" {
            out.push(Finding::new(
                CARGO_TOML,
                idx + 1,
                OFFLINE_BUILD,
                &format!(
                    "active {t} section; the crate must stay dependency-free \
                     (keep it commented out)"
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::scan;

    const DOCS: &str = "\
## Serving

### Wire-op catalog

| Op | Meaning |
|---|---|
| `check` | query + insert |
| `stats` | counters |

### Metric catalog

| Metric (internal name) | Type | Meaning |
|---|---|---|
| `server.requests.total`, `server.errors.total` | counter | requests |
| `engine.submit.{prepare_probe,reconcile}.seconds` | histogram | phases |
| `engine.band_fill_ratio{band=\"B\"}` | gauge | fill |
| `router.request.seconds` (+ `{op=…}`) | histogram | latency |
";

    #[test]
    fn docs_table_parsing_expands_and_strips() {
        let names = docs_table_names(DOCS, "### Metric catalog");
        for expect in [
            "server.requests.total",
            "server.errors.total",
            "engine.submit.prepare_probe.seconds",
            "engine.submit.reconcile.seconds",
            "engine.band_fill_ratio",
            "router.request.seconds",
        ] {
            assert!(names.contains_key(expect), "missing {expect}: {names:?}");
        }
        assert!(!names.keys().any(|k| k.contains('{')), "labels must be stripped");
        // The wire-op table must not leak into the metric set.
        assert!(!names.contains_key("check"));
    }

    #[test]
    fn wire_op_parity_catches_every_side() {
        let server = scan(
            "src/service/server.rs",
            "fn d(op: Option<&str>) { match op {\n\
                 Some(\"check\") => {}\n\
                 Some(\"flush\") => {}\n\
                 _ => {}\n\
             } }\n",
        );
        let client = scan(
            "src/service/client.rs",
            "fn c() {\n\
                 send((\"op\", Value::str(\"check\")));\n\
                 send((\"op\", Value::str(\"stats\")));\n\
             }\n",
        );
        let f = wire_op_parity(&[server, client], DOCS);
        let msgs: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
        // "flush": dispatched, but no client sender and no docs row.
        assert!(
            msgs.iter().any(|m| m.contains("\"flush\"") && m.contains("no sender")),
            "{msgs:?}"
        );
        assert!(msgs.iter().any(|m| m.contains("\"flush\"") && m.contains("wire-op catalog")));
        // "stats": client + docs, but nothing dispatches it.
        assert!(msgs.iter().any(|m| m.contains("\"stats\"") && m.contains("no server dispatches")));
        assert_eq!(f.len(), 4, "{msgs:?}"); // flush×2 + stats client + stats docs
    }

    #[test]
    fn metric_catalog_catches_both_directions() {
        let src = scan(
            "src/engine/x.rs",
            "fn f() {\n\
                 crate::obs::global().counter(\"server.requests.total\").inc();\n\
                 crate::obs::global().counter(\"engine.rogue.total\").inc();\n\
                 let _t = crate::obs::span(\"engine.submit.prepare_probe\");\n\
             }\n",
        );
        let f = metric_catalog(&[src], DOCS);
        let msgs: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("engine.rogue.total") && m.contains("missing")));
        // Documented but unregistered names are flagged on the docs side.
        assert!(msgs
            .iter()
            .any(|m| m.contains("server.errors.total") && m.contains("nothing registers")));
        // Registered + documented names are clean.
        assert!(!msgs.iter().any(|m| m.contains("server.requests.total\" is registered")));
        assert!(!msgs
            .iter()
            .any(|m| m.contains("engine.submit.prepare_probe.seconds\" is registered")));
    }

    #[test]
    fn format_built_metric_names_reduce_to_their_base() {
        let src = scan(
            "src/engine/x.rs",
            "fn f(band: usize) {\n\
                 reg.gauge(&format!(\"engine.band_fill_ratio{{band=\\\"{band}\\\"}}\")).set(0.5);\n\
             }\n",
        );
        let f = metric_catalog(&[src], DOCS);
        assert!(
            !f.iter().any(|x| x.message.contains("band_fill_ratio\" is registered")),
            "label suffix must be stripped before the docs lookup: {f:?}"
        );
    }

    #[test]
    fn offline_build_flags_active_dependency_sections() {
        let clean = "[package]\nname = \"x\"\n# [dependencies]\n# anyhow = \"1\"\n";
        assert!(offline_build(clean).is_empty());
        let dirty = "[package]\nname = \"x\"\n[dependencies]\nanyhow = \"1\"\n";
        let f = offline_build(dirty);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
        assert_eq!(f[0].rule, OFFLINE_BUILD);
    }

    #[test]
    fn test_code_registrations_are_exempt() {
        let src = scan(
            "src/engine/x.rs",
            "#[cfg(test)]\nmod tests {\n\
                 fn t() { reg.counter(\"test.only.total\").inc(); }\n}\n",
        );
        let f = metric_catalog(&[src], DOCS);
        assert!(!f.iter().any(|x| x.message.contains("test.only.total")), "{f:?}");
    }
}
