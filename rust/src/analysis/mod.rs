//! In-repo static analysis: repo-specific soundness invariants checked
//! at `cargo test` time and via the `lint` CLI subcommand.
//!
//! The tree's correctness rests on hand-kept invariants no off-the-shelf
//! tool expresses: every `unsafe` site carries a written justification,
//! the serving/persistence paths cannot panic, verdict-carrying atomics
//! follow the documented ordering discipline, and the wire protocol and
//! metric catalog stay in lockstep with their documentation. This module
//! is a dependency-free linter for exactly those rules:
//!
//! - [`scanner`] — a lexical pass that strips comments and string/char
//!   literals so rules never fire on text inside them;
//! - [`rules`] — per-file rules (`safety-comment`, `no-panic-paths`,
//!   `ordering-discipline`, `no-stray-print`);
//! - [`cross`] — cross-file rules (`wire-op-parity`, `metric-catalog`,
//!   `offline-build`).
//!
//! Escapes: a finding is suppressed by `// lint: allow(<rule>)` on the
//! same line or the line directly above. Every escape must suppress
//! something and name a real rule — dead or misspelled escapes are
//! themselves findings (`stale-allow`), so suppressions cannot rot.
//! Doc comments (`///`, `//!`) quoting the syntax are never escapes.
//!
//! Entry points: [`lint_set`] for an in-memory source set (used by the
//! fixture tests), [`lint_tree`] for the on-disk tree (used by
//! `tests/static_analysis.rs` and `lshbloom lint`).

pub mod cross;
pub mod rules;
pub mod scanner;

use scanner::ScannedFile;
use std::collections::BTreeSet;
use std::fmt;
use std::path::Path;

/// Rule name: escape hygiene (unused or unknown `lint: allow`).
pub const STALE_ALLOW: &str = "stale-allow";

/// Every rule the engine knows, including the escape-hygiene meta-rule.
pub const RULE_NAMES: &[&str] = &[
    rules::SAFETY_COMMENT,
    rules::NO_PANIC_PATHS,
    rules::ORDERING_DISCIPLINE,
    rules::NO_STRAY_PRINT,
    cross::WIRE_OP_PARITY,
    cross::METRIC_CATALOG,
    cross::OFFLINE_BUILD,
    STALE_ALLOW,
];

/// One diagnostic: a rule violation at a specific source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to `rust/` (e.g. `src/service/server.rs`), or a
    /// repo-level display path for docs/manifest findings.
    pub file: String,
    /// 1-indexed line number.
    pub line: usize,
    /// Rule name, one of [`RULE_NAMES`].
    pub rule: String,
    /// Human-readable explanation of the violation.
    pub message: String,
}

impl Finding {
    /// Build a finding; `line` is 1-indexed.
    pub fn new(file: &str, line: usize, rule: &str, message: &str) -> Self {
        Finding {
            file: file.to_string(),
            line,
            rule: rule.to_string(),
            message: message.to_string(),
        }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Everything the rule set looks at, already loaded into memory.
pub struct SourceSet {
    /// Scanned `.rs` files, paths relative to `rust/`.
    pub files: Vec<ScannedFile>,
    /// Contents of `docs/OPERATIONS.md` (wire-op + metric catalogs).
    pub operations_md: String,
    /// Contents of `rust/Cargo.toml` (offline-build rule).
    pub cargo_toml: String,
}

/// Result of a full-tree lint: the surviving findings plus how much of
/// the tree was covered (so callers can assert the walk saw the code).
pub struct LintReport {
    /// Findings after escape application, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

/// Run every rule over a source set and apply `lint: allow` escapes.
///
/// Escape semantics: an escape `(line, rule)` in file F suppresses
/// findings of `rule` in F at `line` (trailing comment) or `line + 1`
/// (comment on its own line above the offending code). Escapes that
/// suppress nothing, or name an unknown rule, produce [`STALE_ALLOW`]
/// findings — which are themselves unsuppressible.
pub fn lint_set(set: &SourceSet) -> Vec<Finding> {
    let mut raw: Vec<Finding> = Vec::new();
    for file in &set.files {
        raw.extend(rules::per_file_rules(file));
    }
    raw.extend(cross::wire_op_parity(&set.files, &set.operations_md));
    raw.extend(cross::metric_catalog(&set.files, &set.operations_md));
    raw.extend(cross::offline_build(&set.cargo_toml));

    // Apply escapes, remembering which ones earned their keep.
    let mut used: BTreeSet<(String, usize, String)> = BTreeSet::new();
    let mut findings: Vec<Finding> = Vec::new();
    for f in raw {
        let suppressed = set
            .files
            .iter()
            .find(|sf| sf.path == f.file)
            .map(|sf| {
                sf.escapes.iter().any(|e| {
                    let hit = e.rule == f.rule && (e.line == f.line || e.line + 1 == f.line);
                    if hit {
                        used.insert((sf.path.clone(), e.line, e.rule.clone()));
                    }
                    hit
                })
            })
            .unwrap_or(false);
        if !suppressed {
            findings.push(f);
        }
    }

    // Escape hygiene: every escape must name a real rule and suppress
    // at least one finding, in source and test code alike.
    for file in &set.files {
        for e in &file.escapes {
            if !RULE_NAMES.contains(&e.rule.as_str()) {
                findings.push(Finding::new(
                    &file.path,
                    e.line,
                    STALE_ALLOW,
                    &format!("lint escape names unknown rule \"{}\"", e.rule),
                ));
            } else if !used.contains(&(file.path.clone(), e.line, e.rule.clone())) {
                findings.push(Finding::new(
                    &file.path,
                    e.line,
                    STALE_ALLOW,
                    &format!("lint escape allow({}) suppresses nothing; remove it", e.rule),
                ));
            }
        }
    }

    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule, &a.message).cmp(&(&b.file, b.line, &b.rule, &b.message))
    });
    findings
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn walk_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let mut entries: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?
        .filter_map(|ent| ent.ok().map(|ent| ent.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|ext| ext == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the on-disk tree rooted at the repository root (the directory
/// containing `rust/` and `docs/`). Scans `rust/src` and `rust/tests`,
/// plus `docs/OPERATIONS.md` and `rust/Cargo.toml` for the cross rules.
pub fn lint_tree(repo_root: &Path) -> Result<LintReport, String> {
    let rust_root = repo_root.join("rust");
    let mut paths = Vec::new();
    for sub in ["src", "tests"] {
        let dir = rust_root.join(sub);
        if dir.is_dir() {
            walk_rs(&dir, &mut paths)?;
        }
    }
    if paths.is_empty() {
        return Err(format!("no .rs files found under {}", rust_root.display()));
    }
    let mut files = Vec::with_capacity(paths.len());
    for path in &paths {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(&rust_root)
            .map_err(|_| format!("path {} escapes {}", path.display(), rust_root.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        files.push(scanner::scan(&rel, &text));
    }
    let operations_md = std::fs::read_to_string(repo_root.join("docs/OPERATIONS.md"))
        .map_err(|e| format!("read docs/OPERATIONS.md: {e}"))?;
    let cargo_toml = std::fs::read_to_string(rust_root.join("Cargo.toml"))
        .map_err(|e| format!("read rust/Cargo.toml: {e}"))?;
    let files_scanned = files.len();
    let set = SourceSet { files, operations_md, cargo_toml };
    Ok(LintReport { findings: lint_set(&set), files_scanned })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set_of(files: Vec<ScannedFile>) -> SourceSet {
        SourceSet {
            files,
            operations_md: String::new(),
            cargo_toml: "# [dependencies]\n".to_string(),
        }
    }

    #[test]
    fn escape_on_line_above_suppresses_and_counts_as_used() {
        let src = "fn f() {\n\
                   // lint: allow(no-stray-print) operator-facing output\n\
                   println!(\"x\");\n\
                   }\n";
        let findings = lint_set(&set_of(vec![scanner::scan("src/engine/x.rs", src)]));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn trailing_escape_on_same_line_suppresses() {
        let src = "fn f() {\n\
                   println!(\"x\"); // lint: allow(no-stray-print) deliberate\n\
                   }\n";
        let findings = lint_set(&set_of(vec![scanner::scan("src/engine/x.rs", src)]));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn unused_escape_is_a_stale_allow_finding() {
        let src = "fn f() {\n\
                   // lint: allow(no-stray-print)\n\
                   let x = 1;\n\
                   let _ = x;\n\
                   }\n";
        let findings = lint_set(&set_of(vec![scanner::scan("src/engine/x.rs", src)]));
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, STALE_ALLOW);
        assert_eq!(findings[0].line, 2);
        assert!(findings[0].message.contains("suppresses nothing"));
    }

    #[test]
    fn unknown_rule_escape_is_rejected() {
        let src = "fn f() {\n\
                   println!(\"x\"); // lint: allow(no-printz)\n\
                   }\n";
        let findings = lint_set(&set_of(vec![scanner::scan("src/engine/x.rs", src)]));
        let rules: Vec<&str> = findings.iter().map(|f| f.rule.as_str()).collect();
        // The typo'd escape suppresses nothing, so the print finding
        // survives AND the escape itself is flagged.
        assert!(rules.contains(&STALE_ALLOW), "{findings:?}");
        assert!(rules.contains(&rules::NO_STRAY_PRINT), "{findings:?}");
        assert!(findings.iter().any(|f| f.message.contains("unknown rule")));
    }

    #[test]
    fn findings_are_sorted_and_display_as_file_line_rule() {
        let b = scanner::scan(
            "src/service/b.rs",
            "fn f() { let x: Option<u32> = None; x.unwrap(); }\n",
        );
        let a = scanner::scan("src/persist/a.rs", "fn g() { panic!(\"boom\"); }\n");
        let findings = lint_set(&set_of(vec![b, a]));
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(findings[0].file < findings[1].file);
        let shown = findings[0].to_string();
        assert!(
            shown.starts_with("src/persist/a.rs:1: [no-panic-paths]"),
            "unexpected display: {shown}"
        );
    }

    #[test]
    fn lint_tree_errors_on_missing_root() {
        let err = lint_tree(Path::new("/nonexistent-lint-root")).unwrap_err();
        assert!(err.contains("no .rs files") || err.contains("read_dir"), "{err}");
    }
}
