//! CSV emission for figure data.

use crate::error::{Error, Result};
use std::io::Write;
use std::path::Path;

/// Buffered CSV writer with quoting.
pub struct CsvWriter {
    out: std::io::BufWriter<std::fs::File>,
    columns: usize,
    path: String,
}

impl CsvWriter {
    /// Create/truncate `path` and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
        }
        let file = std::fs::File::create(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let mut w = Self {
            out: std::io::BufWriter::new(file),
            columns: header.len(),
            path: path.display().to_string(),
        };
        let cells: Vec<String> = header.iter().map(|s| s.to_string()).collect();
        w.row(&cells)?;
        Ok(w)
    }

    /// Write one row.
    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.columns, "csv row arity mismatch");
        let line = cells.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",");
        writeln!(self.out, "{line}").map_err(|e| Error::io(self.path.clone(), e))
    }

    /// Write displayable cells.
    pub fn row_disp<T: std::fmt::Display>(&mut self, cells: &[T]) -> Result<()> {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Flush to disk.
    pub fn finish(mut self) -> Result<()> {
        self.out.flush().map_err(|e| Error::io(self.path.clone(), e))
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let dir = std::env::temp_dir().join(format!("lshbloom-csv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row_disp(&["plain", "with,comma"]).unwrap();
            w.row_disp(&["with\"quote", "x"]).unwrap();
            w.finish().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "a,b\nplain,\"with,comma\"\n\"with\"\"quote\",x\n"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
