//! ASCII line plots and heatmaps for figure regeneration.

/// One labeled series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// New series from points.
    pub fn new(label: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Self { label: label.into(), points }
    }
}

const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render multiple series on one ASCII grid (linear axes).
pub fn line_plot(title: &str, x_label: &str, y_label: &str, series: &[Series]) -> String {
    let width = 72usize;
    let height = 20usize;
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if all.is_empty() {
        return format!("## {title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < f64::EPSILON {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < f64::EPSILON {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        // Plot points and connect consecutive ones with interpolation.
        let proj = |x: f64, y: f64| {
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            (cx.min(width - 1), height - 1 - cy.min(height - 1))
        };
        for w in s.points.windows(2) {
            let (ax, ay) = proj(w[0].0, w[0].1);
            let (bx, by) = proj(w[1].0, w[1].1);
            let steps = ax.abs_diff(bx).max(ay.abs_diff(by)).max(1);
            for t in 0..=steps {
                let fx = ax as f64 + (bx as f64 - ax as f64) * t as f64 / steps as f64;
                let fy = ay as f64 + (by as f64 - ay as f64) * t as f64 / steps as f64;
                grid[fy.round() as usize][fx.round() as usize] = glyph;
            }
        }
        if s.points.len() == 1 {
            let (cx, cy) = proj(s.points[0].0, s.points[0].1);
            grid[cy][cx] = glyph;
        }
    }
    let mut out = format!("## {title}\n");
    out.push_str(&format!("{y_label} (top={y1:.3}, bottom={y0:.3})\n"));
    for row in grid {
        out.push('|');
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("{x_label}: {x0:.3} .. {x1:.3}\n"));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", GLYPHS[si % GLYPHS.len()], s.label));
    }
    out
}

/// Render a heatmap of `values[row][col]` with row/col labels; the cell
/// glyph encodes value intensity over the observed range.
pub fn heatmap(
    title: &str,
    row_labels: &[String],
    col_labels: &[String],
    values: &[Vec<f64>],
) -> String {
    const SHADES: &[char] = &['.', ':', '-', '=', '+', '*', '#', '@'];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for row in values {
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() || (hi - lo).abs() < f64::EPSILON {
        hi = lo + 1.0;
    }
    let label_w = row_labels.iter().map(|l| l.len()).max().unwrap_or(0).max(4);
    let cell_w = col_labels.iter().map(|l| l.len()).max().unwrap_or(1).max(5) + 1;
    let mut out = format!("## {title}  (low {lo:.3} '.', high {hi:.3} '@')\n");
    out.push_str(&" ".repeat(label_w + 2));
    for cl in col_labels {
        out.push_str(&format!("{cl:>cell_w$}"));
    }
    out.push('\n');
    for (ri, row) in values.iter().enumerate() {
        let lbl = row_labels.get(ri).cloned().unwrap_or_default();
        out.push_str(&format!("{lbl:>label_w$}  "));
        for &v in row {
            let t = ((v - lo) / (hi - lo) * (SHADES.len() - 1) as f64).round() as usize;
            let glyph = SHADES[t.min(SHADES.len() - 1)];
            let cell = format!("{glyph}{v:.2}");
            out.push_str(&format!("{cell:>cell_w$}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_plot_renders_all_series() {
        let s = line_plot(
            "t",
            "x",
            "y",
            &[
                Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)]),
                Series::new("b", vec![(0.0, 1.0), (1.0, 0.0)]),
            ],
        );
        assert!(s.contains("## t"));
        assert!(s.contains("* = a"));
        assert!(s.contains("o = b"));
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn line_plot_empty() {
        assert!(line_plot("e", "x", "y", &[]).contains("no data"));
    }

    #[test]
    fn heatmap_shades_extremes() {
        let s = heatmap(
            "h",
            &["r1".into(), "r2".into()],
            &["c1".into(), "c2".into()],
            &[vec![0.0, 0.5], vec![0.75, 1.0]],
        );
        assert!(s.contains("## h"));
        assert!(s.contains(".0.00")); // low shade
        assert!(s.contains("@1.00")); // high shade
    }
}
