//! Report rendering: ASCII tables, line plots, heatmaps, CSV.
//!
//! Every paper figure/table is regenerated as (a) a CSV file for plotting
//! elsewhere and (b) an ASCII rendering printed by the bench binaries so
//! the shape of each result is visible directly in `cargo bench` output.

pub mod csv;
pub mod plot;
pub mod table;

pub use csv::CsvWriter;
pub use plot::{heatmap, line_plot, Series};
pub use table::Table;
