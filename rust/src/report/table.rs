//! ASCII table rendering.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.into(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of displayable items.
    pub fn row_disp<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i] - cell.chars().count();
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format an f64 with fixed decimals (table cell helper).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format bytes human-readably.
pub fn bytes(v: u64) -> String {
    let v = v as f64;
    if v >= 1e12 {
        format!("{:.2} TB", v / 1e12)
    } else if v >= 1e9 {
        format!("{:.2} GB", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2} MB", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2} kB", v / 1e3)
    } else {
        format!("{v:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row_disp(&["short", "1"]);
        t.row_disp(&["much-longer-name", "23456"]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| name "));
        assert!(s.contains("| much-longer-name | 23456 |"));
        // All body lines equal width.
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).skip(1).all(|w| w[0] == w[1] || w[0] == 0));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new("x", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(bytes(500), "500 B");
        assert_eq!(bytes(11_000_000_000), "11.00 GB");
        assert_eq!(bytes(277_680_000_000_000), "277.68 TB");
    }
}
